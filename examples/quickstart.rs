//! Quickstart: load an artifact, run one inference through the engine's
//! execution backend, and sanity-check it against the Rust reference
//! implementation.
//!
//!     cargo run --release --example quickstart
//!
//! Runs out of the box on the builtin manifest + reference backend (no
//! artifacts, no Python). With `make artifacts` (+ `--features pjrt`) the
//! same path exercises the full three-layer stack instead: Pallas kernel
//! (L1) → JAX model (L2) → HLO text → PJRT runtime (L3).

use fbia::numerics::validate;
use fbia::numerics::weights::WeightGen;
use fbia::runtime::Engine;
use fbia::serving::{test_inputs_for, WEIGHT_SEED};
use fbia::util::cli::Args;
use fbia::util::error::Result;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    // resolve artifacts/ against the repo root (one level above the rust/
    // package) so this works from any cwd
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, args.get("backend"))?);
    let manifest = engine.manifest().clone();
    println!(
        "backend {} ({} devices): manifest with {} artifacts",
        engine.backend_name(),
        engine.device_count(),
        manifest.artifacts.len()
    );

    // Pick the int8 DLRM dense partition at batch 32 — the paper's flagship
    // quantized workload.
    let name = "dlrm_dense_b32_int8";
    let art = manifest.get(name)?.clone();
    println!("artifact {name}: {} inputs, batch {}", art.inputs.len(), art.batch);

    // Generate the deterministic weights and upload them once
    // (device-resident tensors, §VI-C).
    let mut gen = WeightGen::new(WEIGHT_SEED);
    let weights = gen.weights_for(&art);
    let prepared = engine.prepare(name, weights)?;

    // One request through the compiled network.
    let inputs = test_inputs_for(&manifest, &art, 42)?;
    let t0 = std::time::Instant::now();
    let outputs = prepared.run(&inputs)?;
    let dt = t0.elapsed();
    let scores = outputs[0].as_f32().expect("scores f32");
    println!("ran 1 inference in {:.2} ms; first scores: {:?}",
             dt.as_secs_f64() * 1e3, &scores[..4.min(scores.len())]);
    if let Some(t) = prepared.modeled_run_s() {
        println!("modeled card latency: {:.3} ms (card {})", t * 1e3, prepared.device);
    }

    // Check against the independent Rust reference (§V-C numerics story).
    let mut gen2 = WeightGen::new(WEIGHT_SEED);
    let reference = validate::reference_outputs(&manifest, &art, &mut gen2, &inputs)?;
    let v = validate::compare(name, reference[0].as_f32().unwrap(), scores);
    println!("reference check: max abs err {:.2e}, cosine {:.6} -> {}",
             v.max_abs_err, v.cosine, if v.passed { "PASS" } else { "FAIL" });
    assert!(v.passed);
    Ok(())
}
