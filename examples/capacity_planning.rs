//! Capacity planning example (Fig. 1): demand growth → servers needed,
//! CPU-only vs accelerator nodes, plus the power picture that motivates the
//! whole program (§I perf/W goal).
//!
//!     cargo run --release --example capacity_planning

use fbia::capacity::{capacity_series, power_savings, GrowthScenario};
use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::util::error::Result;
use fbia::util::table::{f2, Table};

fn main() -> Result<()> {
    let cfg = Config::default();
    for (scenario, model) in [
        (GrowthScenario::recommendation(), ModelId::RecsysComplex),
        (GrowthScenario::other_ml(), ModelId::XlmR),
    ] {
        println!("\n=== Fig. 1 ({}) — serving {} ===", scenario.name, model.name());
        let pts = capacity_series(model, &scenario, &cfg)?;
        let mut t = Table::new(&[
            "quarter", "demand QPS", "CPU servers", "accel servers", "growth vs t0",
        ]);
        for p in &pts {
            t.row(&[
                p.quarter.to_string(),
                format!("{:.0}", p.demand_qps),
                format!("{:.0}", p.cpu_servers),
                format!("{:.0}", p.accel_servers),
                f2(p.cpu_norm),
            ]);
        }
        t.print();
        let last = pts.last().unwrap();
        println!(
            "growth over the window: {:.1}x (paper band: 5-7x); accel fleet is {:.0}x smaller",
            last.cpu_norm,
            last.cpu_servers / last.accel_servers.max(1.0)
        );
        println!("power saved at final quarter: {:.1} kW", power_savings(&pts, &cfg) / 1e3);
    }
    Ok(())
}
