//! Cluster failover demo: a heterogeneous three-node tier loses a node
//! mid-trace and the router re-routes around it (Fig. 1 scale, §VII
//! operational lessons).
//!
//!     cargo run --release --example cluster_failover [-- --requests 200 \
//!         --mix 70/20/10 --threads 4]
//!
//! Builds a tier of two stock nodes plus one slow vendor-mix node, routes
//! an open-loop Poisson stream under every node policy, then kills node 0
//! at 40% of the trace and shows the availability hit: in-flight work
//! shed at the failure instant, traffic re-routed to the survivors, SLA
//! admission intact. Everything is on the deterministic modeled clock;
//! the final run also executes the admitted requests' real numerics.

use fbia::config::Config;
use fbia::platform::CardSpec;
use fbia::serving::cluster::{Cluster, EventKind, NodeEvent, NodePolicy, Scenario};
use fbia::serving::fleet::{Arrival, FamilyMix, FleetConfig, RoutePolicy, TrafficGen};
use fbia::util::cli::Args;
use fbia::util::error::Result;
use fbia::util::table::{ms, pct, Table};
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.get_usize("requests", 200).max(1);
    let threads = args.get_usize("threads", 4).max(1);
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10"))?;
    let cfg = Config::default();
    let fcfg = FleetConfig { replicas: 2, ..FleetConfig::default() };
    let card_policy = RoutePolicy::LatencyAware;

    // two stock nodes + one whose cards run at a quarter of the peaks — a
    // vendor-mix *tier*, not just vendor-mix cards
    let mut slow_node = cfg.node.clone();
    slow_node.card = CardSpec {
        peak_tops_int8: cfg.node.card.peak_tops_int8 / 4.0,
        peak_tflops_fp16: cfg.node.card.peak_tflops_fp16 / 4.0,
        lpddr_bw: cfg.node.card.lpddr_bw / 4.0,
        sram_bw: cfg.node.card.sram_bw / 4.0,
        ..cfg.node.card.clone()
    };
    let specs = vec![cfg.node.clone(), cfg.node.clone(), slow_node];

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let cluster = Arc::new(Cluster::new(&dir, &cfg, &specs, fcfg.clone())?);
    println!("cluster: 3 nodes (node 2 is 4x slower), mix {} over {n} requests", mix.label());

    // open-loop stream at roughly half the healthy tier's capacity
    let mut probe_traffic =
        TrafficGen::new(7, mix, Arrival::Burst, cluster.manifest(), fcfg.recsys_batch)?;
    let probe_reqs = probe_traffic.take(n);
    let probe = cluster.route(
        &probe_reqs,
        NodePolicy::WeightedCapacity,
        card_policy,
        &Scenario::none(),
    )?;
    let rate = (probe.cluster_qps() * 0.5).max(50.0);
    let mut traffic = TrafficGen::new(
        7,
        mix,
        Arrival::Poisson { rate_qps: rate },
        cluster.manifest(),
        fcfg.recsys_batch,
    )?;
    let reqs = traffic.take(n);
    let horizon = reqs.last().map(|r| r.arrival_s()).unwrap_or(0.0);
    let drill = Scenario::new(vec![NodeEvent {
        at_s: 0.4 * horizon,
        node: 0,
        kind: EventKind::Fail,
    }]);

    println!("\nnode policies under a node-0 failure at t={:.3}s:", 0.4 * horizon);
    let mut t = Table::new(&[
        "node policy", "completed", "shed(fail)", "shed(SLA)", "cluster QPS", "p99",
    ]);
    for policy in NodePolicy::ALL {
        let m = cluster.route(&reqs, policy, card_policy, &drill)?;
        t.row(&[
            policy.name().to_string(),
            m.cluster.completed.to_string(),
            m.shed_failed.to_string(),
            m.shed_admission.to_string(),
            format!("{:.1}", m.cluster_qps()),
            ms(m.cluster.latency.p99()),
        ]);
    }
    t.print();

    // execute the weighted plan's real numerics and show the per-node view
    let m = cluster.serve(reqs, NodePolicy::WeightedCapacity, card_policy, &drill, threads)?;
    println!(
        "\nexecuted {} admitted requests' numerics (weighted, {threads} workers)",
        m.cluster.completed
    );
    let span = m.cluster.wall_s;
    let mut tn = Table::new(&["node", "completed", "shed", "busy", "availability", "state"]);
    for nm in &m.per_node {
        tn.row(&[
            nm.node.to_string(),
            nm.metrics.completed.to_string(),
            (nm.shed_admission + nm.shed_failed).to_string(),
            ms(nm.busy_s),
            pct(nm.availability(span)),
            if nm.failed_at_s.is_some() { "FAILED".into() } else { "up".to_string() },
        ]);
    }
    tn.print();
    Ok(())
}
