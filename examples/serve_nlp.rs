//! NLP serving example: mini XLM-R with the paper's static-shape sequence
//! buckets (§VI-A) and length-aware dynamic batching (§VII), over real PJRT
//! numerics. Compares length-aware vs naive batching padding waste.
//!
//!     cargo run --release --example serve_nlp [-- --requests 64 --threads 4 --backend sim]
//!
//! `--threads N` (default 1) runs N formed batches in flight.
//! `--backend {ref,sim,pjrt}` selects execution; `sim` reports modeled
//! card latencies.
//!
//! Uses the builtin manifest + reference backend when `artifacts/` has not
//! been built.

use fbia::runtime::Engine;
use fbia::serving::NlpServer;
use fbia::util::cli::Args;
use fbia::util::error::Result;
use fbia::util::table::{ms, pct, Table};
use fbia::workloads::NlpGen;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.get_usize("requests", 64);
    let max_batch = args.get_usize("max-batch", 4);
    let threads = args.get_usize("threads", 1).max(1);

    // resolve artifacts/ against the repo root (one level above the rust/
    // package) so this works from any cwd
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, args.get("backend"))?);
    println!(
        "backend: {} ({} devices, {} clock)",
        engine.backend_name(),
        engine.device_count(),
        engine.clock().name()
    );
    let server = Arc::new(NlpServer::new(engine.clone())?);
    println!(
        "XLM-R mini: {} layers, d_model {}, buckets {:?}",
        engine.manifest().config_usize("xlmr", "layers")?,
        server.d_model,
        server.buckets
    );

    let vocab = engine.manifest().config_usize("xlmr", "vocab")?;
    let mk_reqs = || {
        let mut gen = NlpGen::new(1, vocab, 128, 100.0);
        (0..n).map(|_| gen.next()).collect::<Vec<_>>()
    };

    let mut t = Table::new(&["batching", "sentences", "p50", "p95", "QPS", "pad waste"]);
    for (label, aware) in [("length-aware", true), ("naive", false)] {
        let (metrics, waste) = server.serve(mk_reqs(), max_batch, aware, threads)?;
        t.row(&[
            label.to_string(),
            metrics.items.to_string(),
            ms(metrics.latency.p50()),
            ms(metrics.latency.p95()),
            format!("{:.1}", metrics.items_per_s()),
            pct(waste),
        ]);
    }
    println!("\nbucket-switched serving (real PJRT numerics):");
    t.print();
    println!("(the paper's 'smarter batching' = the length-aware row, §VII)");
    Ok(())
}
