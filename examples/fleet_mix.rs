//! Fleet routing demo: serve a mixed recsys/nlp/cv stream across the
//! six-card node and compare dispatch policies (§IV packing, §VI-B
//! replication, Fig. 1 capacity inputs).
//!
//!     cargo run --release --example fleet_mix [-- --requests 120 \
//!         --mix 70/20/10 --replicas 4 --backend sim --threads 4]
//!
//! On `--backend sim` (recommended) the policy comparison runs on the
//! deterministic modeled clock and then executes the winning policy's plan
//! with real numerics; on wall-clock backends every policy is executed and
//! timed on the host.

use fbia::runtime::{Clock, Engine};
use fbia::serving::fleet::{
    Arrival, FamilyMix, Fleet, FleetConfig, RoutePolicy, TrafficGen,
};
use fbia::util::cli::Args;
use fbia::util::error::Result;
use fbia::util::table::{ms, pct, Table};
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.get_usize("requests", 120);
    let threads = args.get_usize("threads", 4).max(1);
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10"))?;
    let cfg = FleetConfig {
        replicas: args.get_usize("replicas", FleetConfig::default().replicas),
        ..FleetConfig::default()
    };

    // resolve artifacts/ against the repo root (one level above the rust/
    // package) so this works from any cwd
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, args.get("backend"))?);
    println!(
        "backend: {} ({} devices, {} clock)",
        engine.backend_name(),
        engine.device_count(),
        engine.clock().name()
    );
    let modeled = engine.clock() == Clock::Modeled;

    let fleet = Arc::new(Fleet::new(engine.clone(), cfg.clone())?);
    let mut traffic = TrafficGen::new(1, mix, Arrival::Burst, engine.manifest(), cfg.recsys_batch)?;
    let reqs = traffic.take(n);
    println!(
        "fleet: {} replicas/family ({}), mix {} over {n} requests",
        cfg.replicas,
        cfg.placement.name(),
        mix.label()
    );

    let mut t = Table::new(&["policy", "admitted", "shed%", "node QPS", "p50", "p99"]);
    for policy in RoutePolicy::ALL {
        let m = if modeled {
            fleet.route(&reqs, policy)?
        } else {
            fleet.serve(reqs.clone(), policy, threads)?
        };
        t.row(&[
            policy.name().to_string(),
            m.node.completed.to_string(),
            pct(m.shed_rate()),
            format!("{:.1}", m.node_qps()),
            ms(m.node.latency.p50()),
            ms(m.node.latency.p99()),
        ]);
    }
    t.print();

    if modeled {
        let m = fleet.serve(reqs, RoutePolicy::LatencyAware, threads)?;
        println!(
            "\nexecuted {} admitted requests' numerics (latency-aware, {threads} workers)",
            m.node.completed
        );
        println!("per-card utilization (modeled):");
        let mut tc = Table::new(&["card", "completed", "busy", "util"]);
        for c in &m.per_card {
            tc.row(&[
                c.card.to_string(),
                c.metrics.completed.to_string(),
                ms(c.busy_s),
                pct(c.utilization(m.node.wall_s)),
            ]);
        }
        tc.print();
    }
    Ok(())
}
