//! End-to-end driver (DESIGN.md §5, "E2E" row): serve the partitioned DLRM
//! over real PJRT numerics with the Fig. 6 scheme — SLS shards (model
//! parallel) feeding a dense partition (int8), pipelined across requests —
//! and report latency/throughput.
//!
//!     cargo run --release --example serve_recsys [-- --requests 200 --threads 4 --backend sim]
//!
//! `--threads N` (default 1) serves with N requests in flight instead of
//! the two-stage pipeline. `--backend {ref,sim,pjrt}` selects execution;
//! `sim` runs the same numerics on the modeled card clock.
//!
//! The run is recorded in EXPERIMENTS.md §E2E. Uses the builtin manifest +
//! reference backend when `artifacts/` has not been built.

use fbia::runtime::Engine;
use fbia::serving::RecsysServer;
use fbia::util::cli::Args;
use fbia::util::error::Result;
use fbia::util::table::{ms, Table};
use fbia::workloads::RecsysGen;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.get_usize("requests", 100);
    let batch = args.get_usize("batch", 32);
    let threads = args.get_usize("threads", 1).max(1);

    // resolve artifacts/ against the repo root (one level above the rust/
    // package) so this works from any cwd
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, args.get("backend"))?);
    println!(
        "backend: {} ({} devices, {} clock)",
        engine.backend_name(),
        engine.device_count(),
        engine.clock().name()
    );
    let m = engine.manifest().clone();
    let num_tables = m.config_usize("dlrm", "num_tables")?;
    println!(
        "DLRM: {} tables x {} rows x {} dim ({} M params), batch {batch}",
        num_tables,
        m.config_usize("dlrm", "rows_per_table")?,
        m.config_usize("dlrm", "embed_dim")?,
        m.config_usize("dlrm", "params")? / 1_000_000,
    );

    let mut gen = RecsysGen::from_manifest(1, batch, &m)?;
    let reqs: Vec<_> = (0..n).map(|_| gen.next()).collect();

    let mut t = Table::new(&["precision", "mode", "requests", "p50", "p95", "p99", "QPS", "items/s"]);
    for precision in ["fp32", "int8"] {
        let server = Arc::new(RecsysServer::new(engine.clone(), batch, precision)?);
        // warmup
        server.infer(&reqs[0])?;
        let (mode, metrics) = if threads > 1 {
            (format!("{threads} workers"), server.serve_workers(reqs.clone(), threads)?)
        } else {
            ("pipelined".to_string(), server.serve(reqs.clone())?)
        };
        t.row(&[
            precision.to_string(),
            mode,
            metrics.completed.to_string(),
            ms(metrics.latency.p50()),
            ms(metrics.latency.p95()),
            ms(metrics.latency.p99()),
            format!("{:.1}", metrics.qps()),
            format!("{:.0}", metrics.items_per_s()),
        ]);
    }
    println!("\nend-to-end serving (real PJRT numerics, pipelined Fig. 6 scheme):");
    t.print();
    Ok(())
}
