//! Builtin manifest generator: the same artifact family `python/compile/aot.py`
//! emits, derived in Rust from the model hyperparameters — so `Engine` no
//! longer requires `make artifacts` (or Python at all) to serve through the
//! reference backend.
//!
//! The spec builders mirror `dense_specs`/`sls_shard_specs`/`model_specs`
//! in `python/compile/models/{dlrm,xlmr,cv}.py` name-for-name and
//! shape-for-shape: an `artifacts/manifest.json` produced by the AOT driver
//! and this builtin manifest describe the identical contract, which is what
//! keeps the reference numerics comparable across backends.

use crate::runtime::artifact::{ArtDType, Artifact, InputKind, InputSpec, Manifest, OutputSpec};
use crate::util::json::Json;
use std::path::PathBuf;

// Model hyperparameters (mirrors DlrmConfig / XlmrConfig / CvConfig).
const DLRM_NUM_TABLES: usize = 8;
const DLRM_ROWS_PER_TABLE: usize = 25_000;
const DLRM_EMBED_DIM: usize = 64;
const DLRM_DENSE_IN: usize = 256;
const DLRM_BOTTOM_MLP: [usize; 3] = [256, 128, 64];
const DLRM_TOP_MLP: [usize; 3] = [512, 256, 1];
const DLRM_MAX_LOOKUPS: usize = 32;

const XLMR_LAYERS: usize = 4;
const XLMR_D_MODEL: usize = 256;
const XLMR_HEADS: usize = 8;
const XLMR_FFN: usize = 1024;
const XLMR_VOCAB: usize = 8_000;
const XLMR_MAX_POS: usize = 512;

const CV_IMAGE: usize = 64;
const CV_STEM_CH: usize = 32;
const CV_STAGES: [(usize, usize); 3] = [(32, 2), (64, 2), (128, 2)];
const CV_GROUPS: usize = 8;
const CV_CLASSES: usize = 100;

// Artifact variant grid (the paper's static-shape bucket strategy, §VI-A).
const DLRM_BATCHES: [usize; 3] = [16, 32, 64];
const SLS_CARDS: usize = 4;
const XLMR_SEQS: [usize; 3] = [32, 64, 128];
const XLMR_BATCHES: [usize; 2] = [1, 4];
const CV_BATCHES: [usize; 2] = [1, 4];

fn dlrm_interaction_dim() -> usize {
    let f = DLRM_NUM_TABLES + 1;
    DLRM_EMBED_DIM + f * (f - 1) / 2
}

// ---------------------------------------------------------------------------
// Spec builders (mirror python/compile/models/*.py)
// ---------------------------------------------------------------------------

fn w(name: String, shape: &[usize]) -> InputSpec {
    InputSpec { name, shape: shape.to_vec(), dtype: ArtDType::F32, kind: InputKind::Weight }
}

fn inp(name: &str, shape: &[usize], dtype: ArtDType) -> InputSpec {
    InputSpec { name: name.to_string(), shape: shape.to_vec(), dtype, kind: InputKind::Input }
}

fn out_f32(shape: &[usize]) -> OutputSpec {
    OutputSpec { shape: shape.to_vec(), dtype: ArtDType::F32 }
}

fn mlp_param_specs(prefix: &str, d_in: usize, widths: &[usize], quantized: bool) -> Vec<InputSpec> {
    let mut specs = Vec::new();
    let mut d = d_in;
    for (i, &h) in widths.iter().enumerate() {
        if quantized {
            specs.push(InputSpec {
                name: format!("{prefix}_wq{i}"),
                shape: vec![h, d],
                dtype: ArtDType::I8,
                kind: InputKind::WeightQ,
            });
            specs.push(w(format!("{prefix}_scale{i}"), &[h]));
            specs.push(w(format!("{prefix}_zp{i}"), &[h]));
        } else {
            specs.push(w(format!("{prefix}_w{i}"), &[h, d]));
        }
        specs.push(w(format!("{prefix}_b{i}"), &[h]));
        d = h;
    }
    specs
}

fn dlrm_dense(batch: usize, quantized: bool) -> Artifact {
    let mut inputs = mlp_param_specs("bot", DLRM_DENSE_IN, &DLRM_BOTTOM_MLP, quantized);
    inputs.extend(mlp_param_specs("top", dlrm_interaction_dim(), &DLRM_TOP_MLP, quantized));
    inputs.push(inp("dense", &[batch, DLRM_DENSE_IN], ArtDType::F32));
    inputs.push(inp("sparse", &[batch, DLRM_NUM_TABLES, DLRM_EMBED_DIM], ArtDType::F32));
    let precision = if quantized { "int8" } else { "fp32" };
    artifact(
        format!("dlrm_dense_b{batch}_{precision}"),
        "dlrm",
        "dense",
        batch,
        None,
        None,
        inputs,
        vec![out_f32(&[batch, 1])],
    )
}

fn dlrm_sls_shard(shard: usize, tables: &[usize], batch: usize) -> Artifact {
    let mut inputs = Vec::new();
    for &t in tables {
        inputs.push(w(format!("table{t}"), &[DLRM_ROWS_PER_TABLE, DLRM_EMBED_DIM]));
    }
    for &t in tables {
        inputs.push(inp(&format!("idx{t}"), &[batch, DLRM_MAX_LOOKUPS], ArtDType::I32));
        inputs.push(inp(&format!("len{t}"), &[batch], ArtDType::I32));
    }
    artifact(
        format!("dlrm_sls_shard{shard}_b{batch}"),
        "dlrm",
        "sls",
        batch,
        None,
        Some(shard),
        inputs,
        vec![out_f32(&[batch, tables.len(), DLRM_EMBED_DIM])],
    )
}

fn xlmr_full(batch: usize, seq: usize) -> Artifact {
    let (d, f) = (XLMR_D_MODEL, XLMR_FFN);
    let mut inputs = vec![
        w("tok_emb".into(), &[XLMR_VOCAB, d]),
        w("pos_emb".into(), &[XLMR_MAX_POS, d]),
        w("ln_f_g".into(), &[d]),
        w("ln_f_b".into(), &[d]),
    ];
    for l in 0..XLMR_LAYERS {
        let p = format!("l{l}_");
        for (suffix, shape) in [
            ("wq", vec![d, d]),
            ("bq", vec![d]),
            ("wk", vec![d, d]),
            ("bk", vec![d]),
            ("wv", vec![d, d]),
            ("bv", vec![d]),
            ("wo", vec![d, d]),
            ("bo", vec![d]),
            ("ln1_g", vec![d]),
            ("ln1_b", vec![d]),
            ("w1", vec![f, d]),
            ("b1", vec![f]),
            ("w2", vec![d, f]),
            ("b2", vec![d]),
            ("ln2_g", vec![d]),
            ("ln2_b", vec![d]),
        ] {
            inputs.push(w(format!("{p}{suffix}"), &shape));
        }
    }
    inputs.push(inp("ids", &[batch, seq], ArtDType::I32));
    inputs.push(inp("pad_len", &[batch], ArtDType::I32));
    artifact(
        format!("xlmr_s{seq}_b{batch}"),
        "xlmr",
        "full",
        batch,
        Some(seq),
        None,
        inputs,
        vec![out_f32(&[batch, d]), out_f32(&[batch, seq, d])],
    )
}

fn cv_trunk(batch: usize) -> Artifact {
    let mut inputs = vec![
        w("stem_w".into(), &[3, 3, 3, CV_STEM_CH]),
        w("stem_b".into(), &[CV_STEM_CH]),
    ];
    let mut cin = CV_STEM_CH;
    for (si, &(ch, blocks)) in CV_STAGES.iter().enumerate() {
        for bi in 0..blocks {
            let p = format!("s{si}b{bi}");
            inputs.push(w(format!("{p}_pw1_w"), &[1, 1, cin, ch]));
            inputs.push(w(format!("{p}_pw1_b"), &[ch]));
            inputs.push(w(format!("{p}_gw_w"), &[3, 3, ch / CV_GROUPS, ch]));
            inputs.push(w(format!("{p}_gw_b"), &[ch]));
            inputs.push(w(format!("{p}_pw2_w"), &[1, 1, ch, ch]));
            inputs.push(w(format!("{p}_pw2_b"), &[ch]));
            if cin != ch {
                inputs.push(w(format!("{p}_proj_w"), &[1, 1, cin, ch]));
                inputs.push(w(format!("{p}_proj_b"), &[ch]));
            }
            cin = ch;
        }
    }
    inputs.push(w("head_w".into(), &[CV_CLASSES, cin]));
    inputs.push(w("head_b".into(), &[CV_CLASSES]));
    inputs.push(inp("image", &[batch, CV_IMAGE, CV_IMAGE, 3], ArtDType::F32));
    artifact(
        format!("cv_trunk_b{batch}"),
        "cv",
        "full",
        batch,
        None,
        None,
        inputs,
        vec![out_f32(&[batch, CV_CLASSES]), out_f32(&[batch, cin])],
    )
}

#[allow(clippy::too_many_arguments)]
fn artifact(
    name: String,
    model: &str,
    role: &str,
    batch: usize,
    seq: Option<usize>,
    shard: Option<usize>,
    inputs: Vec<InputSpec>,
    outputs: Vec<OutputSpec>,
) -> Artifact {
    Artifact {
        file: PathBuf::from(format!("<builtin>/{name}.hlo.txt")),
        name,
        model: model.to_string(),
        role: role.to_string(),
        batch,
        seq,
        shard,
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Param counts (mirror the python configs' param_count(), kept in the
// configs section because examples report them)
// ---------------------------------------------------------------------------

fn mlp_params(mut d: usize, widths: &[usize]) -> usize {
    let mut n = 0;
    for &h in widths {
        n += d * h + h;
        d = h;
    }
    n
}

fn dlrm_params() -> usize {
    DLRM_NUM_TABLES * DLRM_ROWS_PER_TABLE * DLRM_EMBED_DIM
        + mlp_params(DLRM_DENSE_IN, &DLRM_BOTTOM_MLP)
        + mlp_params(dlrm_interaction_dim(), &DLRM_TOP_MLP)
}

fn xlmr_params() -> usize {
    let d = XLMR_D_MODEL;
    let per_layer = 4 * d * d + 4 * d + 2 * d * XLMR_FFN + XLMR_FFN + d + 4 * d;
    XLMR_VOCAB * d + XLMR_MAX_POS * d + XLMR_LAYERS * per_layer + 2 * d
}

fn cv_params() -> usize {
    let mut n = 3 * 3 * 3 * CV_STEM_CH + CV_STEM_CH;
    let mut cin = CV_STEM_CH;
    for &(ch, blocks) in CV_STAGES.iter() {
        for _ in 0..blocks {
            n += cin * ch + ch;
            n += 3 * 3 * (ch / CV_GROUPS) * ch + ch;
            n += ch * ch + ch;
            if cin != ch {
                n += cin * ch + ch;
            }
            cin = ch;
        }
    }
    n + cin * CV_CLASSES + CV_CLASSES
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn configs() -> Json {
    Json::obj(vec![
        (
            "dlrm",
            Json::obj(vec![
                ("num_tables", Json::num(DLRM_NUM_TABLES as f64)),
                ("rows_per_table", Json::num(DLRM_ROWS_PER_TABLE as f64)),
                ("embed_dim", Json::num(DLRM_EMBED_DIM as f64)),
                ("dense_in", Json::num(DLRM_DENSE_IN as f64)),
                ("bottom_mlp", usize_arr(&DLRM_BOTTOM_MLP)),
                ("top_mlp", usize_arr(&DLRM_TOP_MLP)),
                ("max_lookups", Json::num(DLRM_MAX_LOOKUPS as f64)),
                ("params", Json::num(dlrm_params() as f64)),
            ]),
        ),
        (
            "xlmr",
            Json::obj(vec![
                ("layers", Json::num(XLMR_LAYERS as f64)),
                ("d_model", Json::num(XLMR_D_MODEL as f64)),
                ("heads", Json::num(XLMR_HEADS as f64)),
                ("ffn", Json::num(XLMR_FFN as f64)),
                ("vocab", Json::num(XLMR_VOCAB as f64)),
                ("max_pos", Json::num(XLMR_MAX_POS as f64)),
                ("params", Json::num(xlmr_params() as f64)),
            ]),
        ),
        (
            "cv",
            Json::obj(vec![
                ("image", Json::num(CV_IMAGE as f64)),
                ("classes", Json::num(CV_CLASSES as f64)),
                ("stem_ch", Json::num(CV_STEM_CH as f64)),
                ("groups", Json::num(CV_GROUPS as f64)),
                (
                    "stages",
                    Json::arr(
                        CV_STAGES.iter().map(|&(ch, b)| usize_arr(&[ch, b])).collect(),
                    ),
                ),
                ("params", Json::num(cv_params() as f64)),
            ]),
        ),
    ])
}

/// Build the full builtin manifest: the same artifact grid as
/// `python -m compile.aot` (DLRM dense b{16,32,64} × {fp32,int8}, 4 SLS
/// shards × b{16,32,64}, XLM-R s{32,64,128} × b{1,4}, CV trunk b{1,4}).
pub fn builtin_manifest() -> Manifest {
    let mut artifacts = Vec::new();
    for &b in DLRM_BATCHES.iter() {
        for quantized in [false, true] {
            artifacts.push(dlrm_dense(b, quantized));
        }
    }
    let per_card = DLRM_NUM_TABLES / SLS_CARDS;
    for &b in DLRM_BATCHES.iter() {
        for c in 0..SLS_CARDS {
            let tables: Vec<usize> = (c * per_card..(c + 1) * per_card).collect();
            artifacts.push(dlrm_sls_shard(c, &tables, b));
        }
    }
    for &s in XLMR_SEQS.iter() {
        for &b in XLMR_BATCHES.iter() {
            artifacts.push(xlmr_full(b, s));
        }
    }
    for &b in CV_BATCHES.iter() {
        artifacts.push(cv_trunk(b));
    }
    Manifest { dir: PathBuf::from("<builtin>"), artifacts, configs: configs() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::InputKind;

    #[test]
    fn grid_is_complete() {
        let m = builtin_manifest();
        // 6 dense + 12 sls + 6 xlmr + 2 cv
        assert_eq!(m.artifacts.len(), 26);
        for name in [
            "dlrm_dense_b32_int8",
            "dlrm_dense_b16_fp32",
            "dlrm_sls_shard0_b16",
            "dlrm_sls_shard3_b64",
            "xlmr_s32_b1",
            "xlmr_s128_b4",
            "cv_trunk_b1",
            "cv_trunk_b4",
        ] {
            assert!(m.get(name).is_ok(), "missing builtin artifact {name}");
        }
        assert_eq!(m.select("dlrm", "sls").len(), 12);
        assert_eq!(m.select("xlmr", "full").len(), 6);
    }

    #[test]
    fn configs_match_models() {
        let m = builtin_manifest();
        assert_eq!(m.config_usize("dlrm", "num_tables").unwrap(), 8);
        assert_eq!(m.config_usize("dlrm", "embed_dim").unwrap(), 64);
        assert_eq!(m.config_usize("xlmr", "d_model").unwrap(), 256);
        assert_eq!(m.config_usize("cv", "image").unwrap(), 64);
        // param counts mirror the python configs' formulas
        assert_eq!(m.config_usize("dlrm", "params").unwrap(), 13_090_241);
        assert_eq!(m.config_usize("xlmr", "params").unwrap(), 5_338_624);
        assert!(m.config_usize("cv", "params").unwrap() > 100_000);
    }

    #[test]
    fn dense_specs_mirror_aot() {
        let m = builtin_manifest();
        let a = m.get("dlrm_dense_b16_int8").unwrap();
        // int8 MLPs: 4 specs per layer x 6 layers + dense + sparse
        assert_eq!(a.inputs.len(), 4 * 6 + 2);
        assert_eq!(a.inputs[0].name, "bot_wq0");
        assert_eq!(a.inputs[0].kind, InputKind::WeightQ);
        assert_eq!(a.inputs[0].shape, vec![256, 256]);
        let sparse = a.inputs.last().unwrap();
        assert_eq!(sparse.name, "sparse");
        assert_eq!(sparse.shape, vec![16, 8, 64]);
        assert_eq!(a.outputs[0].shape, vec![16, 1]);
        // top mlp first layer takes the interaction dim (64 + 9*8/2)
        let top = a.inputs.iter().find(|s| s.name == "top_wq0").unwrap();
        assert_eq!(top.shape, vec![512, 100]);
    }

    #[test]
    fn xlmr_and_cv_specs_mirror_aot() {
        let m = builtin_manifest();
        let x = m.get("xlmr_s64_b4").unwrap();
        // 4 globals + 16 per layer x 4 layers + ids + pad_len
        assert_eq!(x.inputs.len(), 4 + 16 * 4 + 2);
        assert_eq!(x.outputs.len(), 2);
        assert_eq!(x.outputs[1].shape, vec![4, 64, 256]);
        let c = m.get("cv_trunk_b4").unwrap();
        // stem(2) + blocks: s0(6+6) + s1(8+6) + s2(8+6) + head(2) + image
        assert_eq!(c.inputs.last().unwrap().shape, vec![4, 64, 64, 3]);
        assert_eq!(c.outputs[0].shape, vec![4, 100]);
        assert_eq!(c.outputs[1].shape, vec![4, 128]);
        // grouped conv weight shape matches the python contract
        let gw = c.inputs.iter().find(|s| s.name == "s2b0_gw_w").unwrap();
        assert_eq!(gw.shape, vec![3, 3, 128 / 8, 128]);
    }
}
