//! Device layer: the runtime's view of the accelerator node (§III).
//!
//! The paper's platform is six M.2 cards behind a PCIe switch, and its whole
//! evaluation is stated *per card* — so the runtime models the node as a
//! [`Node`] of N [`Device`]s (built from [`crate::platform::NodeSpec`] /
//! [`crate::platform::CardSpec`]) instead of one anonymous executor.
//! [`crate::runtime::Engine::prepare`] asks the node to [`Node::place`] each
//! artifact, so prepared models come back *card-pinned*: SLS shards land on
//! the card the compiler's partitioning scheme assigns them (shard `k` →
//! card `k mod N`, Fig. 6 left), everything else round-robins across cards
//! like the data-parallel dense/full replicas of §VI-B.
//!
//! Backends receive the pinned [`Device`] at prepare time; the simulated
//! backend ([`crate::runtime::SimBackend`]) costs compute on that card's
//! [`CardSpec`] and PCIe transfers on that card's link.

use crate::platform::{CardSpec, NodeSpec};
use crate::runtime::artifact::Artifact;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One accelerator card the runtime can pin work to.
#[derive(Debug, Clone)]
pub struct Device {
    /// Card index in the node (0..cards), also the PCIe endpoint id used by
    /// [`crate::platform::topology::Route`].
    pub id: usize,
    /// The card's hardware description (compute peaks, memories, link).
    pub card: CardSpec,
}

/// The accelerator node: N devices behind the PCIe switch.
#[derive(Debug)]
pub struct Node {
    spec: NodeSpec,
    devices: Vec<Device>,
    /// Round-robin cursor for unpinned (non-sharded) artifacts.
    rr: AtomicUsize,
}

impl Node {
    /// Build the device table from a node description. Each slot takes its
    /// own [`CardSpec`] — [`NodeSpec::card_spec`] resolves the vendor-mix
    /// overrides, so a heterogeneous node yields devices with different
    /// compute peaks (and the sim backend clocks each prepared model on
    /// the spec of the card it is pinned to).
    pub fn new(spec: NodeSpec) -> Node {
        let devices = (0..spec.cards.max(1))
            .map(|id| Device { id, card: spec.card_spec(id).clone() })
            .collect();
        Node { spec, devices, rr: AtomicUsize::new(0) }
    }

    /// Number of devices (paper: six).
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, id: usize) -> &Device {
        &self.devices[id]
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// The node description the devices came from.
    pub fn spec(&self) -> &NodeSpec {
        &self.spec
    }

    /// Pick the card for an artifact. Sharded artifacts (DLRM SLS shards)
    /// are pinned by the compiler's placement scheme — shard `k` lives on
    /// card `k mod N`, matching `compiler::partition`'s model-parallel table
    /// spread. Everything else (dense replicas, whole-model CV/NLP nets)
    /// round-robins, mirroring the data-parallel replication of §VI-B.
    pub fn place(&self, art: &Artifact) -> usize {
        match art.shard {
            Some(s) => s % self.devices.len(),
            None => self.rr.fetch_add(1, Ordering::Relaxed) % self.devices.len(),
        }
    }
}

impl Default for Node {
    fn default() -> Node {
        Node::new(NodeSpec::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin::builtin_manifest;

    #[test]
    fn node_has_six_default_devices() {
        let n = Node::default();
        assert_eq!(n.len(), 6);
        assert_eq!(n.device(3).id, 3);
        assert!(!n.is_empty());
    }

    #[test]
    fn shards_pin_to_their_card() {
        let n = Node::default();
        let m = builtin_manifest();
        for s in 0..4 {
            let art = m.get(&format!("dlrm_sls_shard{s}_b16")).unwrap();
            assert_eq!(n.place(art), s, "shard {s} must pin to card {s}");
            // placement of a pinned artifact is stable, not round-robin
            assert_eq!(n.place(art), s);
        }
    }

    #[test]
    fn unsharded_artifacts_round_robin() {
        let n = Node::new(NodeSpec { cards: 3, ..NodeSpec::default() });
        let m = builtin_manifest();
        let art = m.get("cv_trunk_b1").unwrap();
        let seq: Vec<usize> = (0..4).map(|_| n.place(art)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0]);
    }

    #[test]
    fn vendor_mix_overrides_reach_the_device_table() {
        use crate::platform::CardSpec;
        let mut spec = NodeSpec::default();
        spec.card_overrides
            .push((1, CardSpec { peak_tops_int8: 10.0, accel_cores: 4, ..CardSpec::default() }));
        let n = Node::new(spec);
        assert_eq!(n.device(0).card.peak_tops_int8, 37.5);
        assert_eq!(n.device(1).card.peak_tops_int8, 10.0);
        assert_eq!(n.device(1).card.accel_cores, 4);
        assert_eq!(n.device(2).card.peak_tops_int8, 37.5);
    }

    #[test]
    fn shard_wraps_when_more_shards_than_cards() {
        let n = Node::new(NodeSpec { cards: 2, ..NodeSpec::default() });
        let m = builtin_manifest();
        let art = m.get("dlrm_sls_shard3_b16").unwrap();
        assert_eq!(n.place(art), 1);
    }
}
