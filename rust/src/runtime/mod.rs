//! Runtime: load artifact manifests, bind them to a pluggable execution
//! [`Backend`], and serve inferences from the Rust hot path (§IV-A). Python
//! is never involved here.
//!
//! The paper's platform was explicitly "open to enable a variety of AI
//! accelerators from different vendors"; this module is that seam. The
//! [`Engine`] owns a manifest + backend pair and performs every
//! spec-validation step (weight names/shapes, request arity/shapes, output
//! arity/shapes) so backends implement only raw execution:
//!
//! | backend      | feature   | source of truth                      |
//! |--------------|-----------|--------------------------------------|
//! | `RefBackend` | (default) | pure-Rust reference interpreter      |
//! | `PjrtBackend`| `pjrt`    | AOT HLO text executed through PJRT   |
//!
//! Without an `artifacts/` directory, [`Engine::auto`] falls back to the
//! [`builtin`] manifest generated from the model shapes in Rust, so the
//! default build serves DLRM/XLM-R/CV out of the box, fully offline.

pub mod artifact;
pub mod backend;
pub mod builtin;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{Backend, PreparedExec, RefBackend};

use crate::numerics::HostTensor;
use crate::util::error::{bail, Result};
use artifact::{Artifact, InputKind, Manifest};
use std::path::Path;
use std::sync::Arc;

/// The backend the build selects by default: PJRT when the `pjrt` feature is
/// enabled (opt out at runtime with `FBIA_BACKEND=ref`), the reference
/// interpreter otherwise. Unknown `FBIA_BACKEND` values are an error, not a
/// silent fallback.
fn default_backend() -> Result<Arc<dyn Backend>> {
    let choice = std::env::var("FBIA_BACKEND").ok();
    #[cfg(feature = "pjrt")]
    {
        match choice.as_deref() {
            None | Some("pjrt") => return Ok(Arc::new(pjrt::PjrtBackend::new()?)),
            Some("ref") => {}
            Some(other) => bail!("unknown FBIA_BACKEND '{other}' (expected 'ref' or 'pjrt')"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    if let Some(other) = choice.as_deref() {
        if other != "ref" {
            bail!(
                "FBIA_BACKEND='{other}' requested but this build only has the 'ref' \
                 backend (rebuild with --features pjrt)"
            );
        }
    }
    Ok(Arc::new(RefBackend::new()))
}

/// Shared engine: one manifest + one execution backend.
pub struct Engine {
    manifest: Arc<Manifest>,
    backend: Arc<dyn Backend>,
}

impl Engine {
    /// Create from an artifacts directory (must contain manifest.json),
    /// using the build's default backend.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(dir)?);
        Ok(Engine { manifest, backend: default_backend()? })
    }

    /// Hermetic engine: builtin manifest + reference interpreter. Needs no
    /// files, no Python, no external dependencies.
    pub fn builtin() -> Engine {
        Engine {
            manifest: Arc::new(builtin::builtin_manifest()),
            backend: Arc::new(RefBackend::new()),
        }
    }

    /// `load(dir)` when `dir/manifest.json` exists, [`Engine::builtin`]
    /// otherwise — the entry point the CLI, examples, benches and
    /// integration tests share. An explicit `FBIA_BACKEND` request other
    /// than `ref` is an error when no artifacts exist, not a silent
    /// fallback to the interpreter.
    pub fn auto(dir: &Path) -> Result<Engine> {
        if dir.join("manifest.json").exists() {
            Engine::load(dir)
        } else {
            if let Ok(req) = std::env::var("FBIA_BACKEND") {
                if req != "ref" {
                    bail!(
                        "FBIA_BACKEND='{req}' requires AOT artifacts, but {} does not \
                         exist (run `make artifacts`)",
                        dir.join("manifest.json").display()
                    );
                }
            }
            Ok(Engine::builtin())
        }
    }

    /// Explicit manifest/backend pairing (tests, future backends).
    pub fn with_backend(manifest: Manifest, backend: Arc<dyn Backend>) -> Engine {
        Engine { manifest: Arc::new(manifest), backend }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Short backend identifier ("ref", "pjrt") for logs and the CLI.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Compile an artifact on the backend (cached backend-side).
    pub fn compile(&self, name: &str) -> Result<()> {
        let art = self.manifest.get(name)?;
        self.backend.compile(&self.manifest, art)
    }

    /// Prepare an artifact for serving: validate + compile + make its
    /// weights device-resident (in spec order). Takes the weights by value —
    /// they become backend-resident state, so no caller needs them after.
    pub fn prepare(
        &self,
        name: &str,
        weights: Vec<(String, HostTensor)>,
    ) -> Result<PreparedModel> {
        let art = self.manifest.get(name)?.clone();
        // weights must cover every non-Input spec, in order
        let expected: Vec<&str> = art
            .inputs
            .iter()
            .filter(|s| s.kind != InputKind::Input)
            .map(|s| s.name.as_str())
            .collect();
        let got: Vec<&str> = weights.iter().map(|(n, _)| n.as_str()).collect();
        if expected != got {
            bail!("weight mismatch for {name}: expected {expected:?}, got {got:?}");
        }
        for (wname, t) in &weights {
            let spec = art.inputs.iter().find(|s| &s.name == wname).unwrap();
            if t.shape() != spec.shape.as_slice() {
                bail!("weight {wname} shape {:?} != spec {:?}", t.shape(), spec.shape);
            }
        }
        let exec = self.backend.prepare(&self.manifest, &art, weights)?;
        Ok(PreparedModel { art, exec })
    }

    /// One-shot execute with all inputs host-side (no resident weights) —
    /// the "before" configuration of the §Perf device-resident ablation.
    pub fn execute_all_literals(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let art = self.manifest.get(name)?;
        if inputs.len() != art.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", art.inputs.len(), inputs.len());
        }
        for (spec, t) in art.inputs.iter().zip(inputs) {
            if t.shape() != spec.shape.as_slice() {
                bail!("input {} shape {:?} != spec {:?}", spec.name, t.shape(), spec.shape);
            }
        }
        let out = self.backend.execute_all(&self.manifest, art, inputs)?;
        check_outputs(art, &out)?;
        Ok(out)
    }
}

/// Enforce the output contract (arity + shapes) on what a backend returned.
fn check_outputs(art: &Artifact, out: &[HostTensor]) -> Result<()> {
    if out.len() != art.outputs.len() {
        bail!(
            "{}: backend returned {} outputs vs {} specs",
            art.name,
            out.len(),
            art.outputs.len()
        );
    }
    for (i, (t, spec)) in out.iter().zip(&art.outputs).enumerate() {
        if t.shape() != spec.shape.as_slice() {
            bail!("{}: output {i} shape {:?} != spec {:?}", art.name, t.shape(), spec.shape);
        }
    }
    Ok(())
}

/// A compiled artifact with device-resident weights, ready to serve.
pub struct PreparedModel {
    pub art: Artifact,
    exec: Box<dyn PreparedExec>,
}

impl PreparedModel {
    /// Execute with per-request inputs (in spec order for `kind == Input`).
    /// Weights ride along from their resident buffers.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Zero-copy variant of [`Self::run`]: the serving hot path passes
    /// borrowed request tensors, avoiding a host-side memcpy per tensor per
    /// request (§Perf item L3-1 in EXPERIMENTS.md).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let n_inputs = self
            .art
            .inputs
            .iter()
            .filter(|s| s.kind == InputKind::Input)
            .count();
        if inputs.len() != n_inputs {
            bail!("{}: expected {} request inputs, got {}", self.art.name, n_inputs, inputs.len());
        }
        let mut xi = 0usize;
        for spec in &self.art.inputs {
            if spec.kind == InputKind::Input {
                let t = &inputs[xi];
                if t.shape() != spec.shape.as_slice() {
                    bail!("input {} shape {:?} != spec {:?}", spec.name, t.shape(), spec.shape);
                }
                xi += 1;
            }
        }
        let out = self.exec.run(inputs)?;
        check_outputs(&self.art, &out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::weights::WeightGen;

    #[test]
    fn builtin_engine_prepares_and_validates() {
        let e = Engine::builtin();
        assert_eq!(e.backend_name(), "ref");
        let art = e.manifest().get("dlrm_dense_b16_fp32").unwrap().clone();
        let weights = WeightGen::new(1).weights_for(&art);
        let prepared = e.prepare(&art.name, weights).unwrap();
        // wrong request arity
        assert!(prepared.run(&[]).is_err());
        // wrong shape
        let bad = HostTensor::f32(vec![0.0; 4], &[2, 2]);
        let sparse = HostTensor::f32(vec![0.0; 16 * 8 * 64], &[16, 8, 64]);
        assert!(prepared.run_refs(&[&bad, &sparse]).is_err());
    }

    #[test]
    fn prepare_rejects_wrong_weights() {
        let e = Engine::builtin();
        let art = e.manifest().get("dlrm_dense_b16_fp32").unwrap().clone();
        // missing weights
        assert!(e.prepare(&art.name, vec![]).is_err());
        // right names, wrong shape on the first
        let mut weights = WeightGen::new(1).weights_for(&art);
        weights[0].1 = HostTensor::f32(vec![0.0; 2], &[2]);
        assert!(e.prepare(&art.name, weights).is_err());
    }

    #[test]
    fn unknown_artifact_and_missing_dir() {
        let e = Engine::builtin();
        assert!(e.compile("no_such_artifact").is_err());
        assert!(Engine::load(Path::new("/nonexistent/artifacts")).is_err());
        // auto falls back to builtin for a missing dir
        let auto = Engine::auto(Path::new("/nonexistent/artifacts")).unwrap();
        assert_eq!(auto.backend_name(), "ref");
        assert!(auto.manifest().get("cv_trunk_b1").is_ok());
    }
}
