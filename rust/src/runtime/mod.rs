//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from the
//! Rust hot path (§IV-A: "a custom binary which implements a service to
//! respond to requests and execute inferences using the previously compiled
//! network"). Python is never involved here.
//!
//! Weights are uploaded once as device-resident buffers and reused across
//! requests (`execute_b`), mirroring the paper's device-resident tensors
//! (§VI-C); per-request inputs are small fresh buffers.

pub mod artifact;

use crate::numerics::HostTensor;
use anyhow::{anyhow, bail, Context, Result};
use artifact::{ArtDType, Artifact, InputKind, Manifest};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Shared PJRT engine: one CPU client + a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// The underlying PJRT client is thread-safe; the xla crate just doesn't mark
// its wrappers Send/Sync. Executions are additionally serialized per
// prepared model by a mutex in `PreparedModel::run`.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create from an artifacts directory (must contain manifest.json).
    pub fn load(dir: &std::path::Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Engine { client, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn compile(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let art = self.manifest.get(name)?;
        let path = art
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.compiled.lock().unwrap().insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Upload a host tensor as a device buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        match t {
            HostTensor::F32(d, s) => self
                .client
                .buffer_from_host_buffer(d, s, None)
                .context("uploading f32 buffer"),
            HostTensor::I32(d, s) => self
                .client
                .buffer_from_host_buffer(d, s, None)
                .context("uploading i32 buffer"),
            HostTensor::I8(d, s) => {
                let bytes: &[u8] =
                    unsafe { std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len()) };
                self.client
                    .buffer_from_host_raw_bytes(xla::ElementType::S8, bytes, s, None)
                    .context("uploading i8 buffer")
            }
        }
    }

    /// Prepare an artifact for serving: compile + upload its weights as
    /// device-resident buffers (in spec order).
    pub fn prepare(&self, name: &str, weights: &[(String, HostTensor)]) -> Result<PreparedModel> {
        let exe = self.compile(name)?;
        let art = self.manifest.get(name)?.clone();
        // weights must cover every non-Input spec, in order
        let expected: Vec<&str> = art
            .inputs
            .iter()
            .filter(|s| s.kind != InputKind::Input)
            .map(|s| s.name.as_str())
            .collect();
        let got: Vec<&str> = weights.iter().map(|(n, _)| n.as_str()).collect();
        if expected != got {
            bail!("weight mismatch for {name}: expected {expected:?}, got {got:?}");
        }
        let mut bufs = Vec::with_capacity(weights.len());
        for (wname, t) in weights {
            let spec = art.inputs.iter().find(|s| &s.name == wname).unwrap();
            if t.shape() != spec.shape.as_slice() {
                bail!("weight {wname} shape {:?} != spec {:?}", t.shape(), spec.shape);
            }
            bufs.push(self.upload(t)?);
        }
        Ok(PreparedModel { art, exe, weight_bufs: bufs, exec_lock: Mutex::new(()) })
    }

    /// One-shot execute with all inputs as literals (no resident weights) —
    /// the "before" configuration of the §Perf device-resident ablation.
    pub fn execute_all_literals(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.compile(name)?;
        let art = self.manifest.get(name)?;
        if inputs.len() != art.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", art.inputs.len(), inputs.len());
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let out = exe.execute::<xla::Literal>(&lits)?;
        tuple_outputs(out, art)
    }
}

/// A compiled artifact with device-resident weights, ready to serve.
pub struct PreparedModel {
    pub art: Artifact,
    exe: Arc<xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>,
    exec_lock: Mutex<()>,
}

unsafe impl Send for PreparedModel {}
unsafe impl Sync for PreparedModel {}

impl PreparedModel {
    /// Execute with per-request inputs (in spec order for `kind == Input`).
    /// Weights ride along from their resident buffers.
    pub fn run(&self, engine: &Engine, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(engine, &refs)
    }

    /// Zero-copy variant of [`Self::run`]: the serving hot path passes
    /// borrowed request tensors, avoiding a host-side memcpy per tensor per
    /// request (§Perf item L3-1 in EXPERIMENTS.md).
    pub fn run_refs(&self, engine: &Engine, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let n_inputs = self
            .art
            .inputs
            .iter()
            .filter(|s| s.kind == InputKind::Input)
            .count();
        if inputs.len() != n_inputs {
            bail!("{}: expected {} request inputs, got {}", self.art.name, n_inputs, inputs.len());
        }
        // upload fresh per-request buffers, then stitch weight + input
        // buffer references together in spec order
        let mut fresh: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut xi = 0usize;
        for spec in &self.art.inputs {
            if spec.kind == InputKind::Input {
                let t = &inputs[xi];
                if t.shape() != spec.shape.as_slice() {
                    bail!("input {} shape {:?} != spec {:?}", spec.name, t.shape(), spec.shape);
                }
                fresh.push(engine.upload(t)?);
                xi += 1;
            }
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.art.inputs.len());
        let mut wi = 0usize;
        let mut fi = 0usize;
        for spec in &self.art.inputs {
            match spec.kind {
                InputKind::Input => {
                    refs.push(&fresh[fi]);
                    fi += 1;
                }
                _ => {
                    refs.push(&self.weight_bufs[wi]);
                    wi += 1;
                }
            }
        }
        let _guard = self.exec_lock.lock().unwrap();
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        drop(_guard);
        tuple_outputs(out, &self.art)
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    Ok(match t {
        HostTensor::F32(d, s) => {
            let dims: Vec<i64> = s.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(d).reshape(&dims)?
        }
        HostTensor::I32(d, s) => {
            let dims: Vec<i64> = s.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(d).reshape(&dims)?
        }
        HostTensor::I8(d, s) => {
            // no NativeType impl for i8 in the xla crate: go via raw bytes
            let bytes: &[u8] =
                unsafe { std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len()) };
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, s, bytes)?
        }
    })
}

/// Unpack the 1-tuple / n-tuple result into host tensors per output spec.
fn tuple_outputs(out: Vec<Vec<xla::PjRtBuffer>>, art: &Artifact) -> Result<Vec<HostTensor>> {
    let first = out
        .into_iter()
        .next()
        .and_then(|v| v.into_iter().next())
        .ok_or_else(|| anyhow!("no output buffer"))?;
    let lit = first.to_literal_sync()?;
    // jax lowered with return_tuple=True: decompose
    let parts = lit.to_tuple()?;
    if parts.len() != art.outputs.len() {
        bail!("{}: {} outputs vs {} specs", art.name, parts.len(), art.outputs.len());
    }
    let mut res = Vec::with_capacity(parts.len());
    for (p, spec) in parts.into_iter().zip(&art.outputs) {
        let t = match spec.dtype {
            ArtDType::F32 => HostTensor::f32(p.to_vec::<f32>()?, &spec.shape),
            ArtDType::I32 => HostTensor::i32(p.to_vec::<i32>()?, &spec.shape),
            ArtDType::F16 => {
                // upconvert for host-side use
                let c = p.convert(xla::PrimitiveType::F32)?;
                HostTensor::f32(c.to_vec::<f32>()?, &spec.shape)
            }
            ArtDType::I8 => {
                let c = p.convert(xla::PrimitiveType::S32)?;
                HostTensor::i32(c.to_vec::<i32>()?, &spec.shape)
            }
        };
        res.push(t);
    }
    Ok(res)
}
