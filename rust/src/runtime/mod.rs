//! Runtime: load artifact manifests, bind them to a pluggable execution
//! [`Backend`] over a card-aware [`device::Node`], and serve inferences from
//! the Rust hot path (§IV-A). Python is never involved here.
//!
//! The paper's platform was explicitly "open to enable a variety of AI
//! accelerators from different vendors"; this module is that seam. The
//! [`Engine`] owns a manifest + backend + device table and performs every
//! spec-validation step (weight names/shapes, request arity/shapes, output
//! arity/shapes) so backends implement only raw execution. Every prepared
//! model is *pinned to a card* by the node's placement rule (SLS shard `k`
//! → card `k`, everything else data-parallel round-robin — §VI-B):
//!
//! | backend      | feature   | numerics                   | clock           |
//! |--------------|-----------|----------------------------|-----------------|
//! | `RefBackend` | (default) | pure-Rust interpreter      | host wall time  |
//! | `SimBackend` | (default) | same interpreter kernels   | modeled card    |
//! | `PjrtBackend`| `pjrt`    | AOT HLO text through PJRT  | host wall time  |
//!
//! Selection is unified behind one name — the `--backend {ref,sim,pjrt}`
//! CLI flag or the `FBIA_BACKEND` env var ([`Engine::auto_with`]); unknown
//! names are an error listing the valid ones, never a silent fallback.
//!
//! Without an `artifacts/` directory, [`Engine::auto`] falls back to the
//! [`builtin`] manifest generated from the model shapes in Rust, so the
//! default build serves DLRM/XLM-R/CV out of the box, fully offline.

pub mod artifact;
pub mod backend;
pub mod builtin;
pub mod device;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim_backend;

pub use backend::{
    Backend, Clock, ModeledCost, Precision, PrepareOptions, PreparedExec, RefBackend,
};
pub use sim_backend::SimBackend;

use crate::numerics::HostTensor;
use crate::util::error::{bail, Result};
use artifact::{Artifact, InputKind, Manifest};
use std::path::Path;
use std::sync::Arc;

/// Backend names this build can construct (what `--backend` accepts).
#[cfg(feature = "pjrt")]
pub const BACKEND_NAMES: &[&str] = &["ref", "sim", "pjrt"];
/// Backend names this build can construct (what `--backend` accepts).
#[cfg(not(feature = "pjrt"))]
pub const BACKEND_NAMES: &[&str] = &["ref", "sim"];

/// Construct a backend by name — the single selection point behind the
/// `--backend` flag and `FBIA_BACKEND`. Unknown names (including `pjrt` on
/// a build without the feature) are an error listing the valid names.
pub fn backend_by_name(name: &str) -> Result<Arc<dyn Backend>> {
    match name {
        "ref" => Ok(Arc::new(RefBackend::new())),
        "sim" => Ok(Arc::new(SimBackend::with_default_config())),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Arc::new(pjrt::PjrtBackend::new()?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "backend 'pjrt' is not built in (rebuild with --features pjrt); \
             valid backends: {}",
            BACKEND_NAMES.join(", ")
        ),
        other => bail!(
            "unknown backend '{other}' (valid backends: {})",
            BACKEND_NAMES.join(", ")
        ),
    }
}

/// The explicitly requested backend name: the CLI flag wins, then
/// `FBIA_BACKEND`; `None` when neither asked. An env value naming an
/// unknown backend is an error here, never a silent fallback.
fn requested_backend_name(explicit: Option<&str>) -> Result<Option<String>> {
    if let Some(name) = explicit {
        // same eager validation as the env path, so `--backend pjrt` on a
        // build without the feature reports "rebuild with --features pjrt"
        // rather than a misleading missing-artifacts error
        if !BACKEND_NAMES.contains(&name) {
            backend_by_name(name)?;
        }
        return Ok(Some(name.to_string()));
    }
    if let Ok(env) = std::env::var("FBIA_BACKEND") {
        // reject a typo'd env var eagerly — by name, without constructing a
        // backend (backend_by_name never builds one for an invalid name, so
        // borrowing its error message here is free)
        if !BACKEND_NAMES.contains(&env.as_str()) {
            backend_by_name(&env)?;
        }
        return Ok(Some(env));
    }
    Ok(None)
}

/// Build default when nothing was requested: pjrt when the feature is on
/// (and artifacts exist to feed it), the reference interpreter otherwise.
fn default_backend_name() -> &'static str {
    if cfg!(feature = "pjrt") {
        "pjrt"
    } else {
        "ref"
    }
}

/// Shared engine: one manifest + one execution backend + the device table.
pub struct Engine {
    manifest: Arc<Manifest>,
    backend: Arc<dyn Backend>,
    node: device::Node,
    /// Run the static analyzer over every artifact before `prepare`
    /// (on by default; the CLI's `--no-lint` switches it off).
    lint: bool,
}

impl Engine {
    /// Create from an artifacts directory (must contain manifest.json),
    /// using the build's default backend (or `FBIA_BACKEND`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let name = requested_backend_name(None)?
            .unwrap_or_else(|| default_backend_name().to_string());
        Ok(Engine::with_backend(manifest, backend_by_name(&name)?))
    }

    /// Hermetic engine: builtin manifest + reference interpreter. Needs no
    /// files, no Python, no external dependencies.
    pub fn builtin() -> Engine {
        Engine::with_backend(builtin::builtin_manifest(), Arc::new(RefBackend::new()))
    }

    /// [`Engine::auto_with`] with no explicit backend request (the env var
    /// and build default still apply).
    pub fn auto(dir: &Path) -> Result<Engine> {
        Engine::auto_with(dir, None)
    }

    /// The entry point the CLI, examples, benches and integration tests
    /// share: `load(dir)` when `dir/manifest.json` exists, the builtin
    /// manifest otherwise. `backend` is the `--backend` request (`ref`,
    /// `sim`, `pjrt`); `None` falls back to `FBIA_BACKEND`, then the build
    /// default. An explicit request the build or the artifact situation
    /// cannot honor is an error, never a silent fallback: unknown names are
    /// rejected with the valid list, and `pjrt` without AOT artifacts is
    /// rejected with a pointer at `make artifacts`.
    pub fn auto_with(dir: &Path, backend: Option<&str>) -> Result<Engine> {
        let requested = requested_backend_name(backend)?;
        if dir.join("manifest.json").exists() {
            let name = requested.unwrap_or_else(|| default_backend_name().to_string());
            let manifest = Manifest::load(dir)?;
            return Ok(Engine::with_backend(manifest, backend_by_name(&name)?));
        }
        // no artifacts: the hermetic backends still serve the builtin
        // manifest; an explicit pjrt request cannot be honored
        let name = requested.unwrap_or_else(|| "ref".to_string());
        if name == "pjrt" {
            bail!(
                "backend 'pjrt' requires AOT artifacts, but {} does not exist \
                 (run `make artifacts`)",
                dir.join("manifest.json").display()
            );
        }
        Ok(Engine::with_backend(builtin::builtin_manifest(), backend_by_name(&name)?))
    }

    /// [`Engine::auto_with`]'s manifest resolution (AOT artifacts when
    /// `dir/manifest.json` exists, the builtin manifest otherwise) paired
    /// with an explicitly constructed backend — the entry point for
    /// config-carrying backends (`fbia fleet`/`fbia capacity`
    /// `--backend sim --config node.json`), so the resolution rule lives
    /// in one place.
    pub fn auto_with_backend(dir: &Path, backend: Arc<dyn Backend>) -> Result<Engine> {
        let manifest = if dir.join("manifest.json").exists() {
            Manifest::load(dir)?
        } else {
            builtin::builtin_manifest()
        };
        Ok(Engine::with_backend(manifest, backend))
    }

    /// Explicit manifest/backend pairing (tests, future backends). The
    /// device table comes from the backend's node model when it has one
    /// (sim), so placement and cost model agree on the card count; the
    /// paper's default six-card node otherwise.
    pub fn with_backend(manifest: Manifest, backend: Arc<dyn Backend>) -> Engine {
        let node = device::Node::new(backend.node_spec().unwrap_or_default());
        Engine { manifest: Arc::new(manifest), backend, node, lint: true }
    }

    /// Switch the pre-`prepare` static-analysis gate on or off (`fbia
    /// ... --no-lint` turns it off; it is on by default).
    pub fn set_lint(&mut self, on: bool) {
        self.lint = on;
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Short backend identifier ("ref", "sim", "pjrt") for logs and the CLI.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The clock this engine's backend reports latencies on.
    pub fn clock(&self) -> Clock {
        self.backend.clock()
    }

    /// The accelerator node's device table.
    pub fn node(&self) -> &device::Node {
        &self.node
    }

    /// Number of cards prepared models are pinned across.
    pub fn device_count(&self) -> usize {
        self.node.len()
    }

    /// Compile an artifact on the backend (cached backend-side).
    pub fn compile(&self, name: &str) -> Result<()> {
        let art = self.manifest.get(name)?;
        self.backend.compile(&self.manifest, art)
    }

    /// Prepare an artifact for serving: validate + compile + make its
    /// weights device-resident (in spec order) on the card the node's
    /// placement rule pins it to. Takes the weights by value — they become
    /// backend-resident state, so no caller needs them after.
    pub fn prepare(
        &self,
        name: &str,
        weights: Vec<(String, HostTensor)>,
    ) -> Result<PreparedModel> {
        self.prepare_with(name, weights, PrepareOptions::default())
    }

    /// [`Engine::prepare`] with explicit [`PrepareOptions`] — the
    /// `--precision int8` entry point: the backend pre-quantizes eligible
    /// weights at prepare time and gates the result against the f32
    /// reference before anything serves.
    pub fn prepare_with(
        &self,
        name: &str,
        weights: Vec<(String, HostTensor)>,
        options: PrepareOptions,
    ) -> Result<PreparedModel> {
        let art = self.manifest.get(name)?.clone();
        let device = self.node.place(&art);
        self.prepare_on_with(art, weights, device, options)
    }

    /// [`Engine::prepare`] with an explicit card (multi-card load-balancing
    /// experiments; `device` must index the node's device table).
    pub fn prepare_on(
        &self,
        art: Artifact,
        weights: Vec<(String, HostTensor)>,
        device: usize,
    ) -> Result<PreparedModel> {
        self.prepare_on_with(art, weights, device, PrepareOptions::default())
    }

    /// The full-control prepare: explicit card + [`PrepareOptions`].
    pub fn prepare_on_with(
        &self,
        art: Artifact,
        weights: Vec<(String, HostTensor)>,
        device: usize,
        options: PrepareOptions,
    ) -> Result<PreparedModel> {
        if device >= self.node.len() {
            bail!(
                "device {device} out of range for a {}-card node",
                self.node.len()
            );
        }
        // static-analysis gate: refuse artifacts that cannot fit the card
        // before any weights move (escape hatch: `--no-lint`)
        if self.lint {
            crate::analysis::lint_artifact(&art, &self.node.device(device).card, device)
                .check(&format!("prepare '{}'", art.name))?;
        }
        // weights must cover every non-Input spec, in order
        let expected: Vec<&str> = art
            .inputs
            .iter()
            .filter(|s| s.kind != InputKind::Input)
            .map(|s| s.name.as_str())
            .collect();
        let got: Vec<&str> = weights.iter().map(|(n, _)| n.as_str()).collect();
        if expected != got {
            bail!("weight mismatch for {}: expected {expected:?}, got {got:?}", art.name);
        }
        for (wname, t) in &weights {
            let spec = art.inputs.iter().find(|s| &s.name == wname).unwrap();
            if t.shape() != spec.shape.as_slice() {
                bail!("weight {wname} shape {:?} != spec {:?}", t.shape(), spec.shape);
            }
        }
        let exec = self.backend.prepare_with(
            &self.manifest,
            &art,
            weights,
            self.node.device(device),
            options,
        )?;
        Ok(PreparedModel { art, exec, device, precision: options.precision })
    }

    /// One-shot execute with all inputs host-side (no resident weights) —
    /// the "before" configuration of the §Perf device-resident ablation.
    pub fn execute_all_literals(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let art = self.manifest.get(name)?;
        if inputs.len() != art.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", art.inputs.len(), inputs.len());
        }
        for (spec, t) in art.inputs.iter().zip(inputs) {
            if t.shape() != spec.shape.as_slice() {
                bail!("input {} shape {:?} != spec {:?}", spec.name, t.shape(), spec.shape);
            }
        }
        let out = self.backend.execute_all(&self.manifest, art, inputs)?;
        check_outputs(art, &out)?;
        Ok(out)
    }
}

/// Enforce the output contract (arity + shapes) on what a backend returned.
fn check_outputs(art: &Artifact, out: &[HostTensor]) -> Result<()> {
    if out.len() != art.outputs.len() {
        bail!(
            "{}: backend returned {} outputs vs {} specs",
            art.name,
            out.len(),
            art.outputs.len()
        );
    }
    for (i, (t, spec)) in out.iter().zip(&art.outputs).enumerate() {
        if t.shape() != spec.shape.as_slice() {
            bail!("{}: output {i} shape {:?} != spec {:?}", art.name, t.shape(), spec.shape);
        }
    }
    Ok(())
}

/// A compiled artifact with device-resident weights, pinned to one card,
/// ready to serve.
pub struct PreparedModel {
    pub art: Artifact,
    exec: Box<dyn PreparedExec>,
    /// Card index this model's weights live on (node placement rule).
    pub device: usize,
    /// Numeric precision the model was prepared at (§V-B).
    pub precision: Precision,
}

impl PreparedModel {
    /// Execute with per-request inputs (in spec order for `kind == Input`).
    /// Weights ride along from their resident buffers.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Modeled per-run seconds on the pinned card ([`Clock::Modeled`]
    /// backends); `None` on wall-clock backends.
    pub fn modeled_run_s(&self) -> Option<f64> {
        self.exec.modeled_run_s()
    }

    /// The compute/transfer split behind [`Self::modeled_run_s`] — what the
    /// fleet router feeds its card/link occupancy accounting with. `None`
    /// on wall-clock backends.
    pub fn modeled_cost(&self) -> Option<ModeledCost> {
        self.exec.modeled_cost()
    }

    /// Zero-copy variant of [`Self::run`]: the serving hot path passes
    /// borrowed request tensors, avoiding a host-side memcpy per tensor per
    /// request (§Perf item L3-1 in EXPERIMENTS.md).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let n_inputs = self
            .art
            .inputs
            .iter()
            .filter(|s| s.kind == InputKind::Input)
            .count();
        if inputs.len() != n_inputs {
            bail!("{}: expected {} request inputs, got {}", self.art.name, n_inputs, inputs.len());
        }
        let mut xi = 0usize;
        for spec in &self.art.inputs {
            if spec.kind == InputKind::Input {
                let t = &inputs[xi];
                if t.shape() != spec.shape.as_slice() {
                    bail!("input {} shape {:?} != spec {:?}", spec.name, t.shape(), spec.shape);
                }
                xi += 1;
            }
        }
        let out = self.exec.run(inputs)?;
        check_outputs(&self.art, &out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::weights::WeightGen;

    #[test]
    fn builtin_engine_prepares_and_validates() {
        let e = Engine::builtin();
        assert_eq!(e.backend_name(), "ref");
        assert_eq!(e.clock(), Clock::Wall);
        assert_eq!(e.device_count(), 6);
        let art = e.manifest().get("dlrm_dense_b16_fp32").unwrap().clone();
        let weights = WeightGen::new(1).weights_for(&art);
        let prepared = e.prepare(&art.name, weights).unwrap();
        assert!(prepared.device < e.device_count());
        assert!(prepared.modeled_run_s().is_none());
        // wrong request arity
        assert!(prepared.run(&[]).is_err());
        // wrong shape
        let bad = HostTensor::f32(vec![0.0; 4], &[2, 2]);
        let sparse = HostTensor::f32(vec![0.0; 16 * 8 * 64], &[16, 8, 64]);
        assert!(prepared.run_refs(&[&bad, &sparse]).is_err());
    }

    #[test]
    fn prepare_rejects_wrong_weights() {
        let e = Engine::builtin();
        let art = e.manifest().get("dlrm_dense_b16_fp32").unwrap().clone();
        // missing weights
        assert!(e.prepare(&art.name, vec![]).is_err());
        // right names, wrong shape on the first
        let mut weights = WeightGen::new(1).weights_for(&art);
        weights[0].1 = HostTensor::f32(vec![0.0; 2], &[2]);
        assert!(e.prepare(&art.name, weights).is_err());
        // device out of range
        let weights = WeightGen::new(1).weights_for(&art);
        assert!(e.prepare_on(art, weights, 99).is_err());
    }

    #[test]
    fn sls_shards_pin_to_their_compiler_card() {
        let e = Engine::builtin();
        let mut gen = WeightGen::new(1);
        for s in 0..4 {
            let art = e.manifest().get(&format!("dlrm_sls_shard{s}_b16")).unwrap().clone();
            let weights = gen.weights_for(&art);
            let prepared = e.prepare(&art.name, weights).unwrap();
            assert_eq!(prepared.device, s, "shard {s} must pin to card {s}");
        }
    }

    #[test]
    fn unknown_artifact_and_missing_dir() {
        let e = Engine::builtin();
        assert!(e.compile("no_such_artifact").is_err());
        assert!(Engine::load(Path::new("/nonexistent/artifacts")).is_err());
        // auto falls back to builtin for a missing dir
        let auto = Engine::auto(Path::new("/nonexistent/artifacts")).unwrap();
        assert_eq!(auto.backend_name(), "ref");
        assert!(auto.manifest().get("cv_trunk_b1").is_ok());
    }

    #[test]
    fn backend_selection_is_strict() {
        let e = backend_by_name("ref").unwrap();
        assert_eq!(e.name(), "ref");
        assert_eq!(backend_by_name("sim").unwrap().name(), "sim");
        let err = backend_by_name("tpu").unwrap_err().to_string();
        assert!(err.contains("unknown backend 'tpu'"), "{err}");
        assert!(err.contains("ref") && err.contains("sim"), "{err}");
        #[cfg(not(feature = "pjrt"))]
        {
            let err = backend_by_name("pjrt").unwrap_err().to_string();
            assert!(err.contains("--features pjrt"), "{err}");
        }
        // explicit --backend request through auto_with
        let err = Engine::auto_with(Path::new("/nonexistent"), Some("gpu"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("valid backends"), "{err}");
    }

    #[test]
    fn int8_prepare_gates_and_tracks_f32() {
        let e = Engine::builtin();
        for name in ["dlrm_dense_b16_fp32", "dlrm_sls_shard0_b16", "xlmr_s32_b1", "cv_trunk_b1"] {
            let art = e.manifest().get(name).unwrap().clone();
            let q = e
                .prepare_with(
                    name,
                    WeightGen::new(7).weights_for(&art),
                    PrepareOptions { precision: Precision::Int8 },
                )
                .unwrap_or_else(|err| panic!("{name}: int8 prepare failed: {err}"));
            assert_eq!(q.precision, Precision::Int8);
            let f = e.prepare(name, WeightGen::new(7).weights_for(&art)).unwrap();
            assert_eq!(f.precision, Precision::F32);
            let inputs =
                crate::serving::test_inputs_for(e.manifest(), &art, 3).unwrap();
            let qa = q.run(&inputs).unwrap();
            let fa = f.run(&inputs).unwrap();
            for (a, b) in qa.iter().zip(&fa) {
                let rel = crate::numerics::validate::relative_l2(
                    a.as_f32().unwrap(),
                    b.as_f32().unwrap(),
                );
                assert!(rel < 0.2, "{name}: int8 drifted rel L2 {rel}");
            }
        }
    }

    #[test]
    fn sim_backend_via_auto_with() {
        let e = Engine::auto_with(Path::new("/nonexistent"), Some("sim")).unwrap();
        assert_eq!(e.backend_name(), "sim");
        assert_eq!(e.clock(), Clock::Modeled);
        let art = e.manifest().get("dlrm_dense_b16_fp32").unwrap().clone();
        let weights = WeightGen::new(1).weights_for(&art);
        let prepared = e.prepare(&art.name, weights).unwrap();
        let t = prepared.modeled_run_s().expect("sim models run time");
        assert!(t > 0.0 && t.is_finite());
    }
}
