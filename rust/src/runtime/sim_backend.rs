//! Simulated accelerator card backend: reference numerics, simulator clock.
//!
//! [`SimBackend`] executes every artifact with the same pure-Rust kernels as
//! [`crate::runtime::RefBackend`] — outputs are bit-for-bit identical — but
//! each prepared model additionally carries a **modeled per-run latency** for
//! its pinned card: on-card compute from the compiler's roofline
//! ([`crate::compiler::perf_model::op_cost`] scheduled with
//! [`crate::compiler::placement`]), PCIe request upload / result download
//! from [`crate::sim::transfer::TransferModel`]. The serving layer feeds its
//! histograms from that modeled clock ([`Clock::Modeled`]), so
//! `fbia serve --backend sim` and the fig7 bench report card-accurate
//! latency/QPS against each model's Table I budget instead of dev-CPU noise.
//!
//! What it models: per-op compute on the pinned [`CardSpec`] (int8/fp16
//! engines, SRAM residency, op parallelization, the §VI-B SLS/dense core
//! split), and per-request PCIe traffic honoring the §VI-C optimizations
//! (partial index tensors, command batching, fp16 dense features, P2P
//! delivery of pooled embeddings to the dense card). What it does not model:
//! host-side batcher/scheduler overheads and cross-request link contention —
//! those remain the wall-clock backends' domain.

use crate::compiler::{parallelize, placement};
use crate::config::Config;
use crate::graph::models::{dlrm, staged_cnn, xlmr, CnnSpec, DlrmSpec, XlmrSpec};
use crate::graph::ops::OpKind;
use crate::graph::{Graph, NodeId};
use crate::numerics::HostTensor;
use crate::platform::{CardSpec, NodeSpec};
use crate::runtime::artifact::{Artifact, InputKind, Manifest};
use crate::runtime::backend::{
    Backend, Clock, ModeledCost, Precision, PrepareOptions, PreparedExec, RefBackend,
};
use crate::runtime::device::Device;
use crate::sim::transfer::TransferModel;
use crate::util::error::{bail, err, Context, Result};
use crate::workloads::AVG_LOOKUP_FRACTION;
use std::sync::Arc;

/// The sim-clocked backend: [`RefBackend`] numerics + modeled card timing.
pub struct SimBackend {
    cfg: Config,
    inner: RefBackend,
}

impl SimBackend {
    pub fn new(cfg: Config) -> SimBackend {
        SimBackend { cfg, inner: RefBackend::new() }
    }

    /// The platform every default engine simulates (paper §III node).
    pub fn with_default_config() -> SimBackend {
        SimBackend::new(Config::default())
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Modeled seconds for one run of `art` pinned to `device`: request
    /// upload + on-card makespan + result download.
    pub fn model_run_s(&self, manifest: &Arc<Manifest>, art: &Artifact, device: &Device) -> Result<f64> {
        self.model_cost(manifest, art, device).map(|c| c.total_s())
    }

    /// [`SimBackend::model_run_s`] at an explicit serving precision.
    pub fn model_run_s_at(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        device: &Device,
        precision: Precision,
    ) -> Result<f64> {
        self.model_cost_at(manifest, art, device, precision).map(|c| c.total_s())
    }

    /// [`SimBackend::model_run_s`] with the compute/transfer split kept
    /// apart — the on-card makespan is costed on the *pinned device's own*
    /// [`CardSpec`] (vendor-mix nodes give cards different specs), the PCIe
    /// segments on its link. Multi-request schedulers consume the split so
    /// link contention can serialize transfers independently of compute.
    pub fn model_cost(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        device: &Device,
    ) -> Result<ModeledCost> {
        self.model_cost_at(manifest, art, device, Precision::F32)
    }

    /// [`SimBackend::model_cost`] at an explicit serving precision: int8
    /// serving moves the eligible GEMMs onto the card's int8 engine column
    /// ([`CardSpec::peak_ops`] with `int8 = true`) and halves their weight
    /// bytes, so the roofline shifts exactly where the runtime quantizes.
    pub fn model_cost_at(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        device: &Device,
        precision: Precision,
    ) -> Result<ModeledCost> {
        // §VI-B co-residency: in the deployed recsys scheme every card up
        // to `sls_cards` hosts an SLS shard *and* a dense replica, so a
        // DLRM partition pinned there shares the card's cores and — the
        // part the core split does not capture — its LPDDR. Both DLRM
        // partitions on such a card pay the shared-DRAM occupancy factor;
        // a DLRM partition on a card past the shard range runs isolated
        // (all cores, uncontended DRAM).
        let co_resident = art.model == "dlrm" && device.id < self.cfg.compiler.sls_cards;
        let dram_occupancy = if co_resident {
            crate::compiler::perf_model::SLS_DENSE_DRAM_OCCUPANCY
        } else {
            1.0
        };
        let (graph, nodes, cores) =
            self.cost_graph(manifest, art, &device.card, co_resident, precision)?;
        let plan = parallelize::parallelize(&graph, &device.card, self.cfg.compiler.parallelize);
        let sched = placement::schedule_shared_dram(
            &graph,
            &nodes,
            &plan,
            &device.card,
            cores,
            self.cfg.compiler.placement_hints,
            dram_occupancy,
        );
        let transfer_s = self.transfer_s(manifest, art, device)?;
        Ok(ModeledCost { compute_s: sched.makespan_s, transfer_s, dram_occupancy })
    }

    /// Build the artifact's cost graph: the op set whose roofline costs make
    /// up its on-card time, plus the core count its partition kind gets.
    /// `co_resident` says whether the §VI-B SLS/dense pair shares this
    /// card: then the two partitions split the cores 1-in-3; an isolated
    /// partition owns the whole card.
    fn cost_graph(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        card: &CardSpec,
        co_resident: bool,
        precision: Precision,
    ) -> Result<(Graph, Vec<NodeId>, usize)> {
        let cores = card.accel_cores.max(1);
        // §VI-B core split between the co-resident SLS and dense partitions;
        // degenerate one-core cards keep one core for each side
        let sls_cores = (((cores as f64) * self.cfg.compiler.sls_core_fraction).round() as usize)
            .clamp(1, cores.saturating_sub(1).max(1));
        match (art.model.as_str(), art.role.as_str()) {
            ("dlrm", "sls") => {
                let spec = dlrm_spec(manifest, art, precision)?;
                let g = dlrm(&spec, art.batch);
                // this shard runs only its own tables' SLS ops; tables are
                // homogeneous, so any `n_tables` of the graph's SLS nodes
                // cost the same as the shard's
                let n_tables = art.inputs.iter().filter(|s| s.name.starts_with("table")).count();
                if n_tables == 0 {
                    bail!("sls artifact {} declares no table inputs", art.name);
                }
                let nodes: Vec<NodeId> = g
                    .nodes
                    .iter()
                    .filter(|n| matches!(n.kind, OpKind::SparseLengthsSum { .. }))
                    .map(|n| n.id)
                    .take(n_tables)
                    .collect();
                Ok((g, nodes, if co_resident { sls_cores } else { cores }))
            }
            ("dlrm", "dense") => {
                let spec = dlrm_spec(manifest, art, precision)?;
                let g = dlrm(&spec, art.batch);
                // dense partition = everything that is not an embedding
                // lookup and not host-resident (Fig. 6 right box); it runs
                // on the cores the SLS co-resident doesn't own
                let nodes: Vec<NodeId> = g
                    .nodes
                    .iter()
                    .filter(|n| {
                        !matches!(n.kind, OpKind::SparseLengthsSum { .. }) && !n.kind.host_only()
                    })
                    .map(|n| n.id)
                    .collect();
                Ok((g, nodes, if co_resident { cores - sls_cores } else { cores }))
            }
            ("xlmr", _) => {
                let seq = art.seq.ok_or_else(|| err!("xlmr artifact {} missing seq", art.name))?;
                let spec = XlmrSpec {
                    layers: manifest.config_usize("xlmr", "layers")?,
                    d_model: manifest.config_usize("xlmr", "d_model")?,
                    heads: manifest.config_usize("xlmr", "heads")?,
                    ffn: manifest.config_usize("xlmr", "ffn")?,
                    vocab: manifest.config_usize("xlmr", "vocab")?,
                    // §V-B: "The NLP results in this paper reflect FP16"
                    fp16: true,
                    // int8 serving quantizes the d_model-contraction GEMMs
                    int8_fc: precision == Precision::Int8,
                };
                let g = xlmr(&spec, art.batch, seq);
                let nodes: Vec<NodeId> =
                    g.nodes.iter().filter(|n| !n.kind.host_only()).map(|n| n.id).collect();
                Ok((g, nodes, cores))
            }
            ("cv", _) => {
                let groups = manifest.config_usize("cv", "groups")?;
                let stages: Vec<(usize, usize, usize, usize)> = manifest
                    .configs
                    .get("cv")
                    .and_then(|m| m.get("stages"))
                    .and_then(crate::util::json::Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(|s| {
                                let ch = s.idx(0)?.as_usize()?;
                                let blocks = s.idx(1)?.as_usize()?;
                                Some((ch, ch, blocks, groups))
                            })
                            .collect()
                    })
                    .ok_or_else(|| err!("manifest configs.cv.stages missing"))?;
                let spec = CnnSpec {
                    name: "cv_cost",
                    image: manifest.config_usize("cv", "image")?,
                    stem_ch: manifest.config_usize("cv", "stem_ch")?,
                    stages,
                    classes: manifest.config_usize("cv", "classes")?,
                    quantized: true, // deployed CV runs int8 (§V-B)
                    se_blocks: false,
                };
                let g = staged_cnn(&spec, art.batch);
                let nodes: Vec<NodeId> =
                    g.nodes.iter().filter(|n| !n.kind.host_only()).map(|n| n.id).collect();
                Ok((g, nodes, cores))
            }
            other => bail!("sim backend: no cost model for {other:?}"),
        }
    }

    /// PCIe time per run: request inputs host→card (partial index tensors,
    /// command batching, fp16 dense features per §VI-C/§VI-A; the DLRM dense
    /// partition's pooled-embedding input arrives card→card P2P instead),
    /// plus outputs card→host.
    ///
    /// This is the per-artifact analogue of
    /// [`TransferModel::recsys_upload`], which accounts a whole DLRM request
    /// across all SLS cards at once — the §VI-C optimization rules (which
    /// tensors shrink, what batches into one DMA, the per-table broadcast
    /// overhead) must stay in agreement between the two.
    fn transfer_s(&self, manifest: &Arc<Manifest>, art: &Artifact, device: &Device) -> Result<f64> {
        let tm = TransferModel::new(self.cfg.node.clone(), self.cfg.transfers.clone());
        let t = &self.cfg.transfers;
        let mut host_tensors: Vec<usize> = Vec::new();
        let mut p2p_bytes = 0usize;
        for spec in art.inputs.iter().filter(|s| s.kind == InputKind::Input) {
            let mut bytes = spec.elements() * spec.dtype.bytes();
            if spec.name.starts_with("idx") && t.partial_tensors {
                // send only the used prefix of the static index slots
                let max_lookups = manifest.config_usize("dlrm", "max_lookups")?;
                let avg = ((max_lookups as f64) * AVG_LOOKUP_FRACTION).ceil() as usize;
                bytes = art.batch * avg.min(max_lookups) * spec.dtype.bytes();
            } else if spec.name == "dense" && t.fp16_dense_inputs {
                bytes /= 2;
            }
            if art.model == "dlrm" && art.role == "dense" && spec.name == "sparse" {
                // pooled embeddings gathered from the SLS cards (§VI-C)
                p2p_bytes += bytes;
            } else {
                host_tensors.push(bytes);
            }
        }
        let mut time = 0.0;
        if art.model == "dlrm" && art.role == "sls" {
            // on-card broadcast of the uploaded index tensors (§VI-A):
            // fused => one op, unfused => one per table — the same rule
            // recsys_upload applies request-wide
            let n_tables = art.inputs.iter().filter(|s| s.name.starts_with("table")).count();
            let n_broadcasts = if t.fused_broadcast { 1 } else { n_tables.max(1) };
            time += n_broadcasts as f64 * crate::compiler::perf_model::OP_OVERHEAD_S * 4.0;
        }
        if !host_tensors.is_empty() {
            let total: usize = host_tensors.iter().sum();
            time += if t.command_batching {
                tm.host_to_card(device.id, 1, total).time_s
            } else {
                host_tensors
                    .iter()
                    .map(|&b| tm.host_to_card(device.id, 1, b).time_s)
                    .sum()
            };
        }
        if p2p_bytes > 0 {
            let from = (device.id + 1) % self.cfg.node.cards.max(1);
            time += tm.card_to_card(from, device.id, p2p_bytes).time_s;
        }
        let out_bytes: usize = art
            .outputs
            .iter()
            .map(|o| o.shape.iter().product::<usize>() * o.dtype.bytes())
            .sum();
        time += tm.card_to_host(device.id, out_bytes).time_s;
        Ok(time)
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn clock(&self) -> Clock {
        Clock::Modeled
    }

    fn node_spec(&self) -> Option<NodeSpec> {
        // the engine derives its device table from this, so placement and
        // the cost/transfer models agree on the card count and specs
        Some(self.cfg.node.clone())
    }

    fn compile(&self, manifest: &Arc<Manifest>, art: &Artifact) -> Result<()> {
        self.inner.compile(manifest, art)?;
        // "compilation" additionally checks the cost model can be built
        // (co-residency only changes core counts, not constructibility)
        self.cost_graph(manifest, art, &self.cfg.node.card, true, Precision::F32).map(|_| ())
    }

    fn prepare(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        device: &Device,
    ) -> Result<Box<dyn PreparedExec>> {
        self.prepare_with(manifest, art, weights, device, PrepareOptions::default())
    }

    fn prepare_with(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        device: &Device,
        options: PrepareOptions,
    ) -> Result<Box<dyn PreparedExec>> {
        let cost = self
            .model_cost_at(manifest, art, device, options.precision)
            .with_context(|| format!("modeling artifact {} on card {}", art.name, device.id))?;
        // numerics (including int8 quantization + the accuracy gate) are
        // the reference backend's — outputs stay bit-identical to `ref`
        let exec = self.inner.prepare_with(manifest, art, weights, device, options)?;
        Ok(Box::new(SimPrepared { exec, cost }))
    }

    fn execute_all(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.inner.execute_all(manifest, art, inputs)
    }
}

/// Build the cost-model DLRM spec from the manifest configs. The cost graph
/// stores tables in their deployed quantized form (§V-B), regardless of the
/// f32 tensors the reference numerics carry.
fn dlrm_spec(manifest: &Arc<Manifest>, art: &Artifact, precision: Precision) -> Result<DlrmSpec> {
    let max_lookups = manifest.config_usize("dlrm", "max_lookups")?;
    // FCs run int8 when the artifact ships pre-quantized weights OR the
    // runtime quantizes at prepare() (--precision int8 on an fp32 artifact)
    let quantized_fc = art.inputs.iter().any(|s| s.kind == InputKind::WeightQ)
        || precision == Precision::Int8;
    Ok(DlrmSpec {
        name: "dlrm_cost",
        num_tables: manifest.config_usize("dlrm", "num_tables")?,
        rows_per_table: manifest.config_usize("dlrm", "rows_per_table")?,
        embed_dim: manifest.config_usize("dlrm", "embed_dim")?,
        mixed_int4: false,
        dense_in: manifest.config_usize("dlrm", "dense_in")?,
        bottom_mlp: config_widths(manifest, "dlrm", "bottom_mlp")?,
        top_mlp: config_widths(manifest, "dlrm", "top_mlp")?,
        avg_lookups: (max_lookups as f64) * AVG_LOOKUP_FRACTION,
        max_lookups,
        quantized_fc,
    })
}

fn config_widths(manifest: &Arc<Manifest>, model: &str, key: &str) -> Result<Vec<usize>> {
    manifest
        .configs
        .get(model)
        .and_then(|m| m.get(key))
        .and_then(crate::util::json::Json::as_arr)
        .map(|a| a.iter().filter_map(crate::util::json::Json::as_usize).collect())
        .ok_or_else(|| err!("manifest configs.{model}.{key} missing"))
}

/// Reference execution + a constant modeled cost (shapes are static, so
/// the modeled time is per-model, not per-request).
struct SimPrepared {
    exec: Box<dyn PreparedExec>,
    cost: ModeledCost,
}

impl PreparedExec for SimPrepared {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.exec.run(inputs)
    }

    fn modeled_cost(&self) -> Option<ModeledCost> {
        Some(self.cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin::builtin_manifest;
    use crate::runtime::device::Node;

    fn sim() -> SimBackend {
        SimBackend::with_default_config()
    }

    #[test]
    fn models_every_builtin_artifact() {
        let b = sim();
        let m = Arc::new(builtin_manifest());
        let node = Node::new(b.config().node.clone());
        for art in &m.artifacts {
            let dev = node.device(node.place(art));
            let t = b.model_run_s(&m, art, dev).unwrap_or_else(|e| panic!("{}: {e}", art.name));
            assert!(t > 0.0 && t.is_finite(), "{}: modeled {t}", art.name);
            // far below a second on the modeled card — these are mini models
            assert!(t < 0.5, "{}: modeled {t}s is implausibly slow", art.name);
        }
    }

    #[test]
    fn int8_dense_faster_than_fp32() {
        let b = sim();
        let m = Arc::new(builtin_manifest());
        let node = Node::new(b.config().node.clone());
        let dev = node.device(0);
        let q = b.model_run_s(&m, m.get("dlrm_dense_b32_int8").unwrap(), dev).unwrap();
        let f = b.model_run_s(&m, m.get("dlrm_dense_b32_fp32").unwrap(), dev).unwrap();
        assert!(q <= f, "int8 {q} fp32 {f}");
    }

    #[test]
    fn int8_precision_never_models_slower() {
        let b = sim();
        let m = Arc::new(builtin_manifest());
        let node = Node::new(b.config().node.clone());
        let dev = node.device(0);
        for name in ["dlrm_dense_b16_fp32", "xlmr_s32_b1"] {
            let art = m.get(name).unwrap();
            let f = b.model_run_s_at(&m, art, dev, Precision::F32).unwrap();
            let q = b.model_run_s_at(&m, art, dev, Precision::Int8).unwrap();
            assert!(q <= f, "{name}: int8 {q} fp32 {f}");
        }
        // the dense MLP is compute-bound enough that int8 strictly wins
        let art = m.get("dlrm_dense_b64_fp32").unwrap();
        let f = b.model_run_s_at(&m, art, dev, Precision::F32).unwrap();
        let q = b.model_run_s_at(&m, art, dev, Precision::Int8).unwrap();
        assert!(q < f, "b64 dense: int8 {q} fp32 {f}");
    }

    #[test]
    fn bigger_batches_and_buckets_cost_more() {
        let b = sim();
        let m = Arc::new(builtin_manifest());
        let node = Node::new(b.config().node.clone());
        let dev = node.device(0);
        let s32 = b.model_run_s(&m, m.get("xlmr_s32_b1").unwrap(), dev).unwrap();
        let s128 = b.model_run_s(&m, m.get("xlmr_s128_b4").unwrap(), dev).unwrap();
        assert!(s128 > s32, "s128b4 {s128} vs s32b1 {s32}");
        let b16 = b.model_run_s(&m, m.get("dlrm_sls_shard0_b16").unwrap(), dev).unwrap();
        let b64 = b.model_run_s(&m, m.get("dlrm_sls_shard0_b64").unwrap(), dev).unwrap();
        assert!(b64 > b16, "b64 {b64} vs b16 {b16}");
    }

    #[test]
    fn modeled_time_is_deterministic() {
        let b = sim();
        let m = Arc::new(builtin_manifest());
        let node = Node::new(b.config().node.clone());
        let dev = node.device(2);
        let art = m.get("cv_trunk_b4").unwrap();
        let a = b.model_run_s(&m, art, dev).unwrap();
        let c = b.model_run_s(&m, art, dev).unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn vendor_mix_card_clocks_with_its_own_spec() {
        // a card whose override halves the compute peaks must model slower
        // than its neighbors for the same artifact
        let mut cfg = Config::default();
        cfg.node.card_overrides.push((
            1,
            crate::platform::CardSpec {
                peak_tops_int8: cfg.node.card.peak_tops_int8 / 4.0,
                peak_tflops_fp16: cfg.node.card.peak_tflops_fp16 / 4.0,
                lpddr_bw: cfg.node.card.lpddr_bw / 4.0,
                sram_bw: cfg.node.card.sram_bw / 4.0,
                ..cfg.node.card.clone()
            },
        ));
        let b = SimBackend::new(cfg);
        let m = Arc::new(builtin_manifest());
        let node = Node::new(b.config().node.clone());
        let art = m.get("cv_trunk_b4").unwrap();
        let fast = b.model_cost(&m, art, node.device(0)).unwrap();
        let slow = b.model_cost(&m, art, node.device(1)).unwrap();
        assert!(
            slow.compute_s > fast.compute_s,
            "slow card {} vs fast {}",
            slow.compute_s,
            fast.compute_s
        );
        // total stays the sum of its parts
        assert_eq!(fast.total_s(), fast.compute_s + fast.transfer_s);
    }

    #[test]
    fn co_located_sls_dense_slower_than_isolated() {
        // sls_cards = 2: cards 0..2 host the §VI-B SLS/dense pair, cards
        // 2.. host nothing else — the same artifact modeled on card 0
        // (co-resident) must be slower than on card 5 (isolated), both via
        // the shared-DRAM occupancy and the core split
        let mut cfg = Config::default();
        cfg.compiler.sls_cards = 2;
        let b = SimBackend::new(cfg);
        let m = Arc::new(builtin_manifest());
        let node = Node::new(b.config().node.clone());

        // the SLS shard is DRAM-random-access bound: strictly slower
        let sls = m.get("dlrm_sls_shard0_b16").unwrap();
        let co = b.model_cost(&m, sls, node.device(0)).unwrap();
        let iso = b.model_cost(&m, sls, node.device(5)).unwrap();
        assert_eq!(co.dram_occupancy, crate::compiler::perf_model::SLS_DENSE_DRAM_OCCUPANCY);
        assert_eq!(iso.dram_occupancy, 1.0);
        assert!(
            co.compute_s > iso.compute_s,
            "co-resident SLS {} must exceed isolated {}",
            co.compute_s,
            iso.compute_s
        );

        // the dense partition loses cores to the co-resident shard and
        // pays the occupancy on any off-chip traffic: never faster
        let dense = m.get("dlrm_dense_b16_fp32").unwrap();
        let dco = b.model_cost(&m, dense, node.device(0)).unwrap();
        let diso = b.model_cost(&m, dense, node.device(5)).unwrap();
        assert!(
            dco.compute_s >= diso.compute_s,
            "co-resident dense {} must not beat isolated {}",
            dco.compute_s,
            diso.compute_s
        );
        // non-DLRM families never contend (they run whole-model per card)
        let cv = m.get("cv_trunk_b1").unwrap();
        assert_eq!(b.model_cost(&m, cv, node.device(0)).unwrap().dram_occupancy, 1.0);
    }

    #[test]
    fn partial_tensors_cut_modeled_upload() {
        let m = Arc::new(builtin_manifest());
        let art = m.get("dlrm_sls_shard0_b64").unwrap();
        let on = sim();
        let mut cfg = Config::default();
        cfg.transfers.partial_tensors = false;
        let off = SimBackend::new(cfg);
        let node = Node::new(on.config().node.clone());
        let dev = node.device(0);
        let a = on.transfer_s(&m, art, dev).unwrap();
        let b = off.transfer_s(&m, art, dev).unwrap();
        assert!(b > a, "partial-tensors off {b} must exceed on {a}");
    }
}
