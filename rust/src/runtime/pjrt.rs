//! PJRT backend (`--features pjrt`): load AOT HLO-text artifacts, compile
//! once, execute from the Rust hot path (§IV-A: "a custom binary which
//! implements a service to respond to requests and execute inferences using
//! the previously compiled network"). Python is never involved here.
//!
//! Weights are uploaded once as device-resident buffers and reused across
//! requests (`execute_b`), mirroring the paper's device-resident tensors
//! (§VI-C); per-request inputs are small fresh buffers.
//!
//! Offline builds link the in-repo `xla` stub crate, so this compiles
//! everywhere but fails at [`PjrtBackend::new`] until the real registry
//! `xla` crate is substituted (see rust/README.md).

use crate::numerics::HostTensor;
use crate::runtime::artifact::{ArtDType, Artifact, InputKind, Manifest};
use crate::runtime::backend::{Backend, PreparedExec};
use crate::runtime::device::Device;
use crate::util::error::{bail, err, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Shared PJRT state: one CPU client + a cache of compiled executables.
struct Inner {
    client: xla::PjRtClient,
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// The underlying PJRT client is thread-safe; the xla crate just doesn't mark
// its wrappers Send/Sync. Executions are additionally serialized per
// prepared model by a mutex in `PjrtPrepared::run`.
unsafe impl Send for Inner {}
unsafe impl Sync for Inner {}

/// PJRT-executing [`Backend`].
pub struct PjrtBackend {
    inner: Arc<Inner>,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            inner: Arc::new(Inner { client, compiled: Mutex::new(HashMap::new()) }),
        })
    }
}

impl Inner {
    /// Compile (or fetch cached) an artifact's executable.
    fn executable(&self, art: &Artifact) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(&art.name) {
            return Ok(Arc::clone(exe));
        }
        let path = art
            .file
            .to_str()
            .ok_or_else(|| err!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", art.name))?,
        );
        self.compiled.lock().unwrap().insert(art.name.clone(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Upload a host tensor as a device buffer.
    fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        match t {
            HostTensor::F32(d, s) => self
                .client
                .buffer_from_host_buffer(d, s, None)
                .context("uploading f32 buffer"),
            HostTensor::I32(d, s) => self
                .client
                .buffer_from_host_buffer(d, s, None)
                .context("uploading i32 buffer"),
            HostTensor::I8(d, s) => {
                let bytes: &[u8] =
                    unsafe { std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len()) };
                self.client
                    .buffer_from_host_raw_bytes(xla::ElementType::S8, bytes, s, None)
                    .context("uploading i8 buffer")
            }
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, _manifest: &Arc<Manifest>, art: &Artifact) -> Result<()> {
        self.inner.executable(art).map(|_| ())
    }

    fn prepare(
        &self,
        _manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        _device: &Device,
    ) -> Result<Box<dyn PreparedExec>> {
        let exe = self.inner.executable(art)?;
        let mut weight_bufs = Vec::with_capacity(weights.len());
        for (_, t) in &weights {
            weight_bufs.push(self.inner.upload(t)?);
        }
        Ok(Box::new(PjrtPrepared {
            inner: Arc::clone(&self.inner),
            art: art.clone(),
            exe,
            weight_bufs,
            exec_lock: Mutex::new(()),
        }))
    }

    fn execute_all(
        &self,
        _manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let exe = self.inner.executable(art)?;
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let out = exe.execute::<xla::Literal>(&lits)?;
        tuple_outputs(out, art)
    }
}

/// A compiled artifact with device-resident weight buffers.
struct PjrtPrepared {
    inner: Arc<Inner>,
    art: Artifact,
    exe: Arc<xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>,
    exec_lock: Mutex<()>,
}

unsafe impl Send for PjrtPrepared {}
unsafe impl Sync for PjrtPrepared {}

impl PreparedExec for PjrtPrepared {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        // upload fresh per-request buffers (inputs are pre-validated by the
        // engine), then stitch weight + input buffer references together in
        // spec order
        let mut fresh: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for t in inputs {
            fresh.push(self.inner.upload(t)?);
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.art.inputs.len());
        let mut wi = 0usize;
        let mut fi = 0usize;
        for spec in &self.art.inputs {
            match spec.kind {
                InputKind::Input => {
                    refs.push(&fresh[fi]);
                    fi += 1;
                }
                _ => {
                    refs.push(&self.weight_bufs[wi]);
                    wi += 1;
                }
            }
        }
        let _guard = self.exec_lock.lock().unwrap();
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        drop(_guard);
        tuple_outputs(out, &self.art)
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    Ok(match t {
        HostTensor::F32(d, s) => {
            let dims: Vec<i64> = s.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(d).reshape(&dims)?
        }
        HostTensor::I32(d, s) => {
            let dims: Vec<i64> = s.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(d).reshape(&dims)?
        }
        HostTensor::I8(d, s) => {
            // no NativeType impl for i8 in the xla crate: go via raw bytes
            let bytes: &[u8] =
                unsafe { std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len()) };
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, s, bytes)?
        }
    })
}

/// Unpack the 1-tuple / n-tuple result into host tensors per output spec.
fn tuple_outputs(out: Vec<Vec<xla::PjRtBuffer>>, art: &Artifact) -> Result<Vec<HostTensor>> {
    let first = out
        .into_iter()
        .next()
        .and_then(|v| v.into_iter().next())
        .ok_or_else(|| err!("no output buffer"))?;
    let lit = first.to_literal_sync()?;
    // jax lowered with return_tuple=True: decompose
    let parts = lit.to_tuple()?;
    if parts.len() != art.outputs.len() {
        bail!("{}: {} outputs vs {} specs", art.name, parts.len(), art.outputs.len());
    }
    let mut res = Vec::with_capacity(parts.len());
    for (p, spec) in parts.into_iter().zip(&art.outputs) {
        let t = match spec.dtype {
            ArtDType::F32 => HostTensor::f32(p.to_vec::<f32>()?, &spec.shape),
            ArtDType::I32 => HostTensor::i32(p.to_vec::<i32>()?, &spec.shape),
            ArtDType::F16 => {
                // upconvert for host-side use
                let c = p.convert(xla::PrimitiveType::F32)?;
                HostTensor::f32(c.to_vec::<f32>()?, &spec.shape)
            }
            ArtDType::I8 => {
                let c = p.convert(xla::PrimitiveType::S32)?;
                HostTensor::i32(c.to_vec::<i32>()?, &spec.shape)
            }
        };
        res.push(t);
    }
    Ok(res)
}
