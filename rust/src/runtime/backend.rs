//! Pluggable execution backends (the paper's platform was "open to enable a
//! variety of AI accelerators from different vendors"; the runtime abstracts
//! the device behind a common artifact/execution contract).
//!
//! A [`Backend`] compiles manifest artifacts, accepts device-resident
//! weights, and executes requests. Two implementations exist today:
//!
//! * [`RefBackend`] — a deterministic pure-Rust interpreter over
//!   [`crate::numerics::ops_ref`], via the [`crate::numerics::validate`]
//!   reference models. Zero external dependencies; the hermetic default.
//! * `PjrtBackend` (`--features pjrt`) — executes the AOT HLO-text
//!   artifacts through a PJRT client ([`crate::runtime::pjrt`]).
//!
//! The [`crate::runtime::Engine`] front end performs all spec validation
//! (weight names/shapes, request arity/shapes, output arity) so backends
//! only implement raw execution.

use crate::numerics::validate;
use crate::numerics::HostTensor;
use crate::runtime::artifact::{Artifact, Manifest};
use crate::util::error::Result;
use std::sync::Arc;

/// One execution device family behind the common artifact contract.
pub trait Backend: Send + Sync {
    /// Short identifier ("ref", "pjrt") for logs and the CLI.
    fn name(&self) -> &'static str;

    /// Compile an artifact (backends cache internally); cheap if already
    /// compiled. For the interpreter this checks the artifact is evaluable.
    fn compile(&self, manifest: &Arc<Manifest>, art: &Artifact) -> Result<()>;

    /// Make weights device-resident for an artifact and return an
    /// executable handle. `weights` is already validated against the spec
    /// (names, order, shapes) by the engine.
    fn prepare(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
    ) -> Result<Box<dyn PreparedExec>>;

    /// One-shot execution with *every* input host-side (weights + request
    /// tensors in spec order) — the "before" configuration of the §Perf
    /// device-resident ablation. Optional: backends that only serve the
    /// resident-weight hot path can keep the default.
    fn execute_all(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let _ = (manifest, art, inputs);
        Err(crate::err!(
            "backend {} does not support one-shot host-side execution",
            self.name()
        ))
    }
}

/// A compiled artifact with device-resident weights, ready to execute.
/// Inputs arrive pre-validated, in spec order for `kind == Input`.
pub trait PreparedExec: Send + Sync {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

// ---------------------------------------------------------------------------
// RefBackend: the deterministic pure-Rust interpreter
// ---------------------------------------------------------------------------

/// Reference interpreter backend. Executes every artifact family (DLRM SLS
/// shards + dense, XLM-R buckets, CV trunk) with the independent Rust
/// reference kernels — the same numerics `fbia validate-numerics` trusts, so
/// it doubles as the ground truth other backends are validated against
/// (§V-C, the FakeLowP role).
#[derive(Debug, Default)]
pub struct RefBackend;

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn compile(&self, _manifest: &Arc<Manifest>, art: &Artifact) -> Result<()> {
        // No codegen: "compilation" is checking a reference model exists.
        if validate::supports(&art.model, &art.role) {
            Ok(())
        } else {
            Err(crate::err!(
                "ref backend: no reference model for ({}, {})",
                art.model,
                art.role
            ))
        }
    }

    fn prepare(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
    ) -> Result<Box<dyn PreparedExec>> {
        self.compile(manifest, art)?;
        // Validate + index the weight half of the evaluation environment
        // once, here; every subsequent run() shares it by Arc and never
        // copies a weight buffer again (host-side "device-resident", §VI-C).
        let weights = validate::Env::weight_env(art, weights)?;
        Ok(Box::new(RefPrepared {
            manifest: Arc::clone(manifest),
            art: art.clone(),
            weights,
        }))
    }

    fn execute_all(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.compile(manifest, art)?;
        // everything arrives host-side in spec order; borrow it all
        let env = validate::Env::from_spec_order(art, inputs)?;
        validate::eval(manifest, art, &env)
    }
}

/// Weights held host-side ("device-resident" for the interpreter) + the
/// artifact spec and manifest configs needed at execution time. The weight
/// env is prebuilt at `prepare()`; `run` only binds borrowed request
/// tensors to it — no per-request weight memcpy.
struct RefPrepared {
    manifest: Arc<Manifest>,
    art: Artifact,
    weights: validate::WeightEnv,
}

impl PreparedExec for RefPrepared {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let env = validate::Env::from_weights(&self.art, &self.weights, inputs)?;
        validate::eval(&self.manifest, &self.art, &env)
    }
}
