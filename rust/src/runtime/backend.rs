//! Pluggable execution backends (the paper's platform was "open to enable a
//! variety of AI accelerators from different vendors"; the runtime abstracts
//! the device behind a common artifact/execution contract).
//!
//! A [`Backend`] compiles manifest artifacts, accepts device-resident
//! weights pinned to a [`Device`], and executes requests. Three
//! implementations exist today:
//!
//! * [`RefBackend`] — a deterministic pure-Rust interpreter over
//!   [`crate::numerics::ops_ref`], via the [`crate::numerics::validate`]
//!   reference models. Zero external dependencies; the hermetic default.
//! * [`crate::runtime::SimBackend`] — runs the same reference numerics but
//!   *clocks* with the simulator: every prepared model carries a modeled
//!   per-run latency for its pinned card ([`Clock::Modeled`]).
//! * `PjrtBackend` (`--features pjrt`) — executes the AOT HLO-text
//!   artifacts through a PJRT client ([`crate::runtime::pjrt`]).
//!
//! The [`crate::runtime::Engine`] front end performs all spec validation
//! (weight names/shapes, request arity/shapes, output arity) so backends
//! only implement raw execution.

use crate::numerics::validate;
use crate::numerics::HostTensor;
use crate::runtime::artifact::{Artifact, Manifest};
use crate::runtime::device::Device;
use crate::util::error::Result;
use std::sync::Arc;

/// What a backend's latencies mean — the clock the serving layer feeds its
/// histograms from. Wall-clock backends (ref, pjrt) measure host elapsed
/// time; a [`Clock::Modeled`] backend (sim) reports card-accurate modeled
/// seconds per run, so serving metrics describe the accelerator node rather
/// than the dev CPU the numerics happen to execute on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Clock {
    /// Histograms record host wall time around each run.
    #[default]
    Wall,
    /// Histograms record the backend's modeled per-run latency.
    Modeled,
}

impl Clock {
    /// Short label for logs and metric printouts.
    pub fn name(self) -> &'static str {
        match self {
            Clock::Wall => "wall",
            Clock::Modeled => "modeled",
        }
    }
}

/// Modeled per-run cost of a prepared model on its pinned card, split into
/// the two resources a run occupies: the card's compute engines and its
/// PCIe link. [`Clock::Modeled`] backends report both so multi-request
/// schedulers (the fleet router) can serialize transfer segments on a
/// shared link occupancy accumulator while compute segments serialize on
/// the card — folding them into one number would hide exactly the
/// contention the router models.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModeledCost {
    /// On-card compute makespan, seconds.
    pub compute_s: f64,
    /// PCIe segments (request upload + result download + P2P), seconds.
    pub transfer_s: f64,
    /// Shared-DRAM occupancy factor already folded into `compute_s`
    /// (see [`crate::compiler::perf_model::op_cost_shared_dram`]): 1.0 for
    /// an isolated partition; > 1.0 when the model's card co-hosts another
    /// partition contending for the same LPDDR (§VI-B SLS + dense).
    pub dram_occupancy: f64,
}

impl ModeledCost {
    /// The uncontended per-run latency (what a lone request pays).
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.transfer_s
    }
}

/// One execution device family behind the common artifact contract.
pub trait Backend: Send + Sync {
    /// Short identifier ("ref", "sim", "pjrt") for logs and the CLI.
    fn name(&self) -> &'static str;

    /// Which clock this backend's latencies are on. Wall by default;
    /// sim-clocked backends override to [`Clock::Modeled`].
    fn clock(&self) -> Clock {
        Clock::Wall
    }

    /// The node this backend models, when it has an opinion — the engine
    /// builds its device table from it so placement and cost model agree on
    /// the card count/specs. `None` (default) → the paper's default node.
    fn node_spec(&self) -> Option<crate::platform::NodeSpec> {
        None
    }

    /// Compile an artifact (backends cache internally); cheap if already
    /// compiled. For the interpreter this checks the artifact is evaluable.
    fn compile(&self, manifest: &Arc<Manifest>, art: &Artifact) -> Result<()>;

    /// Make weights device-resident for an artifact on the pinned card and
    /// return an executable handle. `weights` is already validated against
    /// the spec (names, order, shapes) by the engine; `device` is the card
    /// the engine's [`crate::runtime::device::Node`] placed this artifact
    /// on (backends without a device model may ignore it).
    fn prepare(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        device: &Device,
    ) -> Result<Box<dyn PreparedExec>>;

    /// One-shot execution with *every* input host-side (weights + request
    /// tensors in spec order) — the "before" configuration of the §Perf
    /// device-resident ablation. Optional: backends that only serve the
    /// resident-weight hot path can keep the default.
    fn execute_all(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let _ = (manifest, art, inputs);
        Err(crate::err!(
            "backend {} does not support one-shot host-side execution",
            self.name()
        ))
    }
}

/// A compiled artifact with device-resident weights, ready to execute.
/// Inputs arrive pre-validated, in spec order for `kind == Input`.
pub trait PreparedExec: Send + Sync {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;

    /// Modeled seconds one `run` takes on the pinned card (PCIe upload +
    /// on-card compute + download). `Some` only for [`Clock::Modeled`]
    /// backends; shapes are static, so the value is a per-model constant.
    fn modeled_run_s(&self) -> Option<f64> {
        self.modeled_cost().map(|c| c.total_s())
    }

    /// The compute/transfer split behind [`PreparedExec::modeled_run_s`].
    /// `Some` only for [`Clock::Modeled`] backends.
    fn modeled_cost(&self) -> Option<ModeledCost> {
        None
    }
}

// ---------------------------------------------------------------------------
// RefBackend: the deterministic pure-Rust interpreter
// ---------------------------------------------------------------------------

/// Reference interpreter backend. Executes every artifact family (DLRM SLS
/// shards + dense, XLM-R buckets, CV trunk) with the independent Rust
/// reference kernels — the same numerics `fbia validate-numerics` trusts, so
/// it doubles as the ground truth other backends are validated against
/// (§V-C, the FakeLowP role).
#[derive(Debug, Default)]
pub struct RefBackend;

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn compile(&self, _manifest: &Arc<Manifest>, art: &Artifact) -> Result<()> {
        // No codegen: "compilation" is checking a reference model exists.
        if validate::supports(&art.model, &art.role) {
            Ok(())
        } else {
            Err(crate::err!(
                "ref backend: no reference model for ({}, {})",
                art.model,
                art.role
            ))
        }
    }

    fn prepare(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        _device: &Device,
    ) -> Result<Box<dyn PreparedExec>> {
        self.compile(manifest, art)?;
        // Validate + index the weight half of the evaluation environment
        // once, here; every subsequent run() shares it by Arc and never
        // copies a weight buffer again (host-side "device-resident", §VI-C).
        let weights = validate::Env::weight_env(art, weights)?;
        Ok(Box::new(RefPrepared {
            manifest: Arc::clone(manifest),
            art: art.clone(),
            weights,
        }))
    }

    fn execute_all(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.compile(manifest, art)?;
        // everything arrives host-side in spec order; borrow it all
        let env = validate::Env::from_spec_order(art, inputs)?;
        validate::eval(manifest, art, &env)
    }
}

/// Weights held host-side ("device-resident" for the interpreter) + the
/// artifact spec and manifest configs needed at execution time. The weight
/// env is prebuilt at `prepare()`; `run` only binds borrowed request
/// tensors to it — no per-request weight memcpy.
struct RefPrepared {
    manifest: Arc<Manifest>,
    art: Artifact,
    weights: validate::WeightEnv,
}

impl PreparedExec for RefPrepared {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let env = validate::Env::from_weights(&self.art, &self.weights, inputs)?;
        validate::eval(&self.manifest, &self.art, &env)
    }
}
