//! Pluggable execution backends (the paper's platform was "open to enable a
//! variety of AI accelerators from different vendors"; the runtime abstracts
//! the device behind a common artifact/execution contract).
//!
//! A [`Backend`] compiles manifest artifacts, accepts device-resident
//! weights pinned to a [`Device`], and executes requests. Three
//! implementations exist today:
//!
//! * [`RefBackend`] — a deterministic pure-Rust interpreter over
//!   [`crate::numerics::ops_ref`], via the [`crate::numerics::validate`]
//!   reference models. Zero external dependencies; the hermetic default.
//! * [`crate::runtime::SimBackend`] — runs the same reference numerics but
//!   *clocks* with the simulator: every prepared model carries a modeled
//!   per-run latency for its pinned card ([`Clock::Modeled`]).
//! * `PjrtBackend` (`--features pjrt`) — executes the AOT HLO-text
//!   artifacts through a PJRT client ([`crate::runtime::pjrt`]).
//!
//! The [`crate::runtime::Engine`] front end performs all spec validation
//! (weight names/shapes, request arity/shapes, output arity) so backends
//! only implement raw execution.

use crate::numerics::validate;
use crate::numerics::HostTensor;
use crate::runtime::artifact::{Artifact, Manifest};
use crate::runtime::device::Device;
use crate::util::error::Result;
use std::sync::Arc;

/// What a backend's latencies mean — the clock the serving layer feeds its
/// histograms from. Wall-clock backends (ref, pjrt) measure host elapsed
/// time; a [`Clock::Modeled`] backend (sim) reports card-accurate modeled
/// seconds per run, so serving metrics describe the accelerator node rather
/// than the dev CPU the numerics happen to execute on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Clock {
    /// Histograms record host wall time around each run.
    #[default]
    Wall,
    /// Histograms record the backend's modeled per-run latency.
    Modeled,
}

impl Clock {
    /// Short label for logs and metric printouts.
    pub fn name(self) -> &'static str {
        match self {
            Clock::Wall => "wall",
            Clock::Modeled => "modeled",
        }
    }
}

/// Numeric precision a model is prepared at (§V-B). `F32` is the reference
/// path; `Int8` pre-quantizes eligible FC weights and embedding tables
/// row-wise at `prepare()` (quantize once, serve many) and dequantizes only
/// at family output boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    /// Short label for the CLI and bench reports.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    /// Parse a `--precision` flag value.
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" | "fp32" => Ok(Precision::F32),
            "int8" => Ok(Precision::Int8),
            other => Err(crate::err!(
                "unknown precision '{other}' (expected f32 or int8)"
            )),
        }
    }
}

/// Options for [`Backend::prepare_with`]; `Default` is the f32 path every
/// pre-existing call site gets.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrepareOptions {
    pub precision: Precision,
}

/// Modeled per-run cost of a prepared model on its pinned card, split into
/// the two resources a run occupies: the card's compute engines and its
/// PCIe link. [`Clock::Modeled`] backends report both so multi-request
/// schedulers (the fleet router) can serialize transfer segments on a
/// shared link occupancy accumulator while compute segments serialize on
/// the card — folding them into one number would hide exactly the
/// contention the router models.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModeledCost {
    /// On-card compute makespan, seconds.
    pub compute_s: f64,
    /// PCIe segments (request upload + result download + P2P), seconds.
    pub transfer_s: f64,
    /// Shared-DRAM occupancy factor already folded into `compute_s`
    /// (see [`crate::compiler::perf_model::op_cost_shared_dram`]): 1.0 for
    /// an isolated partition; > 1.0 when the model's card co-hosts another
    /// partition contending for the same LPDDR (§VI-B SLS + dense).
    pub dram_occupancy: f64,
}

impl ModeledCost {
    /// The uncontended per-run latency (what a lone request pays).
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.transfer_s
    }
}

/// One execution device family behind the common artifact contract.
pub trait Backend: Send + Sync {
    /// Short identifier ("ref", "sim", "pjrt") for logs and the CLI.
    fn name(&self) -> &'static str;

    /// Which clock this backend's latencies are on. Wall by default;
    /// sim-clocked backends override to [`Clock::Modeled`].
    fn clock(&self) -> Clock {
        Clock::Wall
    }

    /// The node this backend models, when it has an opinion — the engine
    /// builds its device table from it so placement and cost model agree on
    /// the card count/specs. `None` (default) → the paper's default node.
    fn node_spec(&self) -> Option<crate::platform::NodeSpec> {
        None
    }

    /// Compile an artifact (backends cache internally); cheap if already
    /// compiled. For the interpreter this checks the artifact is evaluable.
    fn compile(&self, manifest: &Arc<Manifest>, art: &Artifact) -> Result<()>;

    /// Make weights device-resident for an artifact on the pinned card and
    /// return an executable handle. `weights` is already validated against
    /// the spec (names, order, shapes) by the engine; `device` is the card
    /// the engine's [`crate::runtime::device::Node`] placed this artifact
    /// on (backends without a device model may ignore it).
    fn prepare(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        device: &Device,
    ) -> Result<Box<dyn PreparedExec>>;

    /// [`Backend::prepare`] with explicit [`PrepareOptions`]. The default
    /// implementation serves only the f32 path; backends with an int8
    /// serving path (ref, sim) override it.
    fn prepare_with(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        device: &Device,
        options: PrepareOptions,
    ) -> Result<Box<dyn PreparedExec>> {
        if options.precision != Precision::F32 {
            return Err(crate::err!(
                "backend {} does not support {} serving",
                self.name(),
                options.precision.name()
            ));
        }
        self.prepare(manifest, art, weights, device)
    }

    /// One-shot execution with *every* input host-side (weights + request
    /// tensors in spec order) — the "before" configuration of the §Perf
    /// device-resident ablation. Optional: backends that only serve the
    /// resident-weight hot path can keep the default.
    fn execute_all(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let _ = (manifest, art, inputs);
        Err(crate::err!(
            "backend {} does not support one-shot host-side execution",
            self.name()
        ))
    }
}

/// A compiled artifact with device-resident weights, ready to execute.
/// Inputs arrive pre-validated, in spec order for `kind == Input`.
pub trait PreparedExec: Send + Sync {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;

    /// Modeled seconds one `run` takes on the pinned card (PCIe upload +
    /// on-card compute + download). `Some` only for [`Clock::Modeled`]
    /// backends; shapes are static, so the value is a per-model constant.
    fn modeled_run_s(&self) -> Option<f64> {
        self.modeled_cost().map(|c| c.total_s())
    }

    /// The compute/transfer split behind [`PreparedExec::modeled_run_s`].
    /// `Some` only for [`Clock::Modeled`] backends.
    fn modeled_cost(&self) -> Option<ModeledCost> {
        None
    }
}

// ---------------------------------------------------------------------------
// RefBackend: the deterministic pure-Rust interpreter
// ---------------------------------------------------------------------------

/// Reference interpreter backend. Executes every artifact family (DLRM SLS
/// shards + dense, XLM-R buckets, CV trunk) with the independent Rust
/// reference kernels — the same numerics `fbia validate-numerics` trusts, so
/// it doubles as the ground truth other backends are validated against
/// (§V-C, the FakeLowP role).
#[derive(Debug, Default)]
pub struct RefBackend;

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn compile(&self, _manifest: &Arc<Manifest>, art: &Artifact) -> Result<()> {
        // No codegen: "compilation" is checking a reference model exists.
        if validate::supports(&art.model, &art.role) {
            Ok(())
        } else {
            Err(crate::err!(
                "ref backend: no reference model for ({}, {})",
                art.model,
                art.role
            ))
        }
    }

    fn prepare(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        device: &Device,
    ) -> Result<Box<dyn PreparedExec>> {
        self.prepare_with(manifest, art, weights, device, PrepareOptions::default())
    }

    fn prepare_with(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        weights: Vec<(String, HostTensor)>,
        _device: &Device,
        options: PrepareOptions,
    ) -> Result<Box<dyn PreparedExec>> {
        self.compile(manifest, art)?;
        // Validate + index the weight half of the evaluation environment
        // once, here; every subsequent run() shares it by Arc and never
        // copies a weight buffer again (host-side "device-resident", §VI-C).
        let weights = validate::Env::weight_env(art, weights)?;
        let quant = match options.precision {
            Precision::F32 => None,
            Precision::Int8 => Some(prepare_int8(manifest, art, &weights)?),
        };
        Ok(Box::new(RefPrepared {
            reserve_bytes: validate::peak_scratch_bytes(manifest, art),
            manifest: Arc::clone(manifest),
            art: art.clone(),
            weights,
            quant,
        }))
    }

    fn execute_all(
        &self,
        manifest: &Arc<Manifest>,
        art: &Artifact,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.compile(manifest, art)?;
        // everything arrives host-side in spec order; borrow it all
        let env = validate::Env::from_spec_order(art, inputs)?;
        validate::eval(manifest, art, &env)
    }
}

/// Deterministic seed for the int8 accuracy-gate inputs (distinct from the
/// weight seed so the gate does not see weight-correlated inputs).
const GATE_SEED: u64 = 0xFB1A_6A7E;

/// Build + gate the int8 serving plan at `prepare()`: quantize eligible
/// weights row-wise once, then run the quantized evaluator against the f32
/// reference on synthesized inputs and require the relative L2 error of
/// every output to fit the family budget (§V-B/V-C — no int8 model goes
/// live without clearing the accuracy gate).
fn prepare_int8(
    manifest: &Arc<Manifest>,
    art: &Artifact,
    weights: &validate::WeightEnv,
) -> Result<validate::QuantMap> {
    let quant = validate::quantize_for_serving(art, weights);
    if quant.is_empty() {
        // nothing eligible (e.g. an already-quantized WeightQ artifact):
        // serving proceeds on the artifact's own numerics, nothing to gate
        return Ok(quant);
    }
    let inputs = crate::serving::test_inputs_for(manifest, art, GATE_SEED)?;
    let refs: Vec<&HostTensor> = inputs.iter().collect();
    let env = validate::Env::from_weights(art, weights, &refs)?;
    let f32_outs = validate::eval(manifest, art, &env)?;
    let q_outs = crate::numerics::arena::with_arena(|a| {
        validate::eval_with(
            manifest,
            art,
            &env,
            &mut validate::EvalCtx { quant: Some(&quant), arena: a },
        )
    })?;
    let budget = validate::int8_family_budget(quant.len());
    for (i, (q, f)) in q_outs.iter().zip(&f32_outs).enumerate() {
        let (q, f) = match (q.as_f32(), f.as_f32()) {
            (Some(q), Some(f)) => (q, f),
            _ => continue,
        };
        let rel = validate::relative_l2(q, f);
        if rel > budget {
            return Err(crate::err!(
                "int8 accuracy gate failed for {}: output {i} relative L2 \
                 {rel:.4} exceeds budget {budget:.4} ({} quantized weights)",
                art.name,
                quant.len()
            ));
        }
    }
    Ok(quant)
}

/// Weights held host-side ("device-resident" for the interpreter) + the
/// artifact spec and manifest configs needed at execution time. The weight
/// env is prebuilt at `prepare()`; `run` only binds borrowed request
/// tensors to it — no per-request weight memcpy. `quant` is the int8
/// serving plan (present only for [`Precision::Int8`]); `reserve_bytes`
/// pre-sizes each worker's arena on first contact.
struct RefPrepared {
    manifest: Arc<Manifest>,
    art: Artifact,
    weights: validate::WeightEnv,
    quant: Option<validate::QuantMap>,
    reserve_bytes: usize,
}

impl PreparedExec for RefPrepared {
    fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        // Positional env + pooled scratch: zero heap allocations per request
        // in steady state (the arena recycles activations, name strings and
        // output shells; `reserve` is an idempotent capacity check).
        let env = validate::Env::positional(&self.art, &self.weights, inputs)?;
        crate::numerics::arena::with_arena(|a| {
            a.reserve(self.reserve_bytes);
            let mut ctx = validate::EvalCtx { quant: self.quant.as_ref(), arena: a };
            validate::eval_with(&self.manifest, &self.art, &env, &mut ctx)
        })
    }
}
