//! AOT artifact manifest — the contract between artifact producers and the
//! runtime backends. Producers are `python/compile/aot.py` (build time,
//! `artifacts/manifest.json`) and [`crate::runtime::builtin`] (the in-crate
//! generator the hermetic default build uses).

use crate::util::error::{bail, err, Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Tensor dtypes used in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtDType {
    F32,
    F16,
    I32,
    I8,
}

impl ArtDType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => ArtDType::F32,
            "f16" => ArtDType::F16,
            "i32" => ArtDType::I32,
            "i8" => ArtDType::I8,
            other => bail!("unknown dtype {other}"),
        })
    }

    pub fn bytes(self) -> usize {
        match self {
            ArtDType::F32 | ArtDType::I32 => 4,
            ArtDType::F16 => 2,
            ArtDType::I8 => 1,
        }
    }
}

/// Whether an input is a weight (uploaded once) or a request tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    /// fp weight generated deterministically from the seed.
    Weight,
    /// int8 row-wise quantized weight derived from a generated fp weight.
    WeightQ,
    /// per-request input.
    Input,
}

/// One input spec of an artifact.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: ArtDType,
    pub kind: InputKind,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One output spec.
#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub shape: Vec<usize>,
    pub dtype: ArtDType,
}

/// One AOT-compiled artifact.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub model: String,
    pub role: String,
    pub batch: usize,
    pub seq: Option<usize>,
    pub shard: Option<usize>,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<OutputSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
    /// raw "configs" section (model hyperparameters for weight generation).
    pub configs: Json,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.get("version").and_then(Json::as_i64) != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            artifacts.push(parse_artifact(a, dir)?);
        }
        let configs = j.get("configs").cloned().unwrap_or(Json::Obj(Default::default()));
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, configs })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| err!("artifact '{name}' not in manifest"))
    }

    /// All artifacts for a model/role.
    pub fn select(&self, model: &str, role: &str) -> Vec<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.model == model && a.role == role)
            .collect()
    }

    /// Config value lookup, e.g. `config_usize("dlrm", "embed_dim")`.
    pub fn config_usize(&self, model: &str, key: &str) -> Result<usize> {
        self.configs
            .get(model)
            .and_then(|m| m.get(key))
            .and_then(Json::as_usize)
            .ok_or_else(|| err!("manifest configs.{model}.{key} missing"))
    }
}

/// Parse the numeric suffix of an indexed input name (`idx3` → 3 for prefix
/// `"idx"`). Returns a proper error for malformed artifact input names
/// instead of panicking on arbitrary manifest content.
pub fn table_index(name: &str, prefix: &str) -> Result<usize> {
    name.strip_prefix(prefix)
        .and_then(|digits| digits.parse::<usize>().ok())
        .ok_or_else(|| {
            err!("malformed artifact input name '{name}' (expected {prefix}<table-id>)")
        })
}

/// Strict shape parsing: every entry must be a non-negative integer. A
/// malformed manifest must fail loudly here, not surface later as a cryptic
/// length mismatch inside a backend.
fn parse_shape(j: Option<&Json>, what: &str) -> Result<Vec<usize>> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("{what}: shape missing or not an array"))?;
    arr.iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| err!("{what}: shape entry {v} is not a non-negative integer"))
        })
        .collect()
}

fn parse_artifact(a: &Json, dir: &Path) -> Result<Artifact> {
    let name = a
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| err!("artifact missing name"))?
        .to_string();
    let file = dir.join(
        a.get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("artifact {name} missing file"))?,
    );
    let mut inputs = Vec::new();
    for i in a.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
        let kind = match i.get("kind").and_then(Json::as_str).unwrap_or("input") {
            "weight" => InputKind::Weight,
            "weight_q" => InputKind::WeightQ,
            _ => InputKind::Input,
        };
        let iname = i
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("artifact {name}: input missing name"))?
            .to_string();
        let what = format!("artifact {name} input {iname}");
        inputs.push(InputSpec {
            shape: parse_shape(i.get("shape"), &what)?,
            dtype: ArtDType::parse(i.get("dtype").and_then(Json::as_str).unwrap_or("f32"))
                .context(what)?,
            name: iname,
            kind,
        });
    }
    let mut outputs = Vec::new();
    for (oi, o) in a.get("outputs").and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate() {
        let what = format!("artifact {name} output {oi}");
        outputs.push(OutputSpec {
            shape: parse_shape(o.get("shape"), &what)?,
            dtype: ArtDType::parse(o.get("dtype").and_then(Json::as_str).unwrap_or("f32"))
                .context(what)?,
        });
    }
    Ok(Artifact {
        name,
        file,
        model: a.get("model").and_then(Json::as_str).unwrap_or("").to_string(),
        role: a.get("role").and_then(Json::as_str).unwrap_or("").to_string(),
        batch: a.get("batch").and_then(Json::as_usize).unwrap_or(1),
        seq: a.get("seq").and_then(Json::as_usize),
        shard: a.get("shard").and_then(Json::as_usize),
        inputs,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "m_b2", "file": "m_b2.hlo.txt", "model": "m", "role": "full",
         "batch": 2,
         "inputs": [
           {"name": "w", "shape": [4, 3], "dtype": "f32", "kind": "weight"},
           {"name": "x", "shape": [2, 3], "dtype": "f32", "kind": "input"}
         ],
         "outputs": [{"shape": [2, 4], "dtype": "f32"}]}
      ],
      "configs": {"m": {"dim": 3}}
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("fbia_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("m_b2").unwrap();
        assert_eq!(a.batch, 2);
        assert_eq!(a.inputs[0].kind, InputKind::Weight);
        assert_eq!(a.inputs[1].kind, InputKind::Input);
        assert_eq!(a.outputs[0].shape, vec![2, 4]);
        assert_eq!(m.config_usize("m", "dim").unwrap(), 3);
        assert!(m.get("nope").is_err());
        assert_eq!(m.select("m", "full").len(), 1);
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("fbia_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 9}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn table_index_parses_and_rejects() {
        assert_eq!(table_index("idx3", "idx").unwrap(), 3);
        assert_eq!(table_index("table12", "table").unwrap(), 12);
        assert!(table_index("idx", "idx").is_err());
        assert!(table_index("idxT", "idx").is_err());
        assert!(table_index("len3", "idx").is_err());
    }

    fn load_manifest(tag: &str, body: &str) -> crate::util::error::Result<Manifest> {
        let dir = std::env::temp_dir().join(format!("fbia_manifest_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        Manifest::load(&dir)
    }

    #[test]
    fn rejects_bad_dtype_with_context() {
        let e = load_manifest(
            "bad_dtype",
            r#"{"version": 1, "artifacts": [
                {"name": "m", "file": "m.hlo.txt",
                 "inputs": [{"name": "x", "shape": [2], "dtype": "f64", "kind": "input"}],
                 "outputs": []}]}"#,
        )
        .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("unknown dtype f64"), "{msg}");
        assert!(msg.contains("artifact m input x"), "{msg}");
    }

    #[test]
    fn rejects_bad_shape_with_context() {
        // a fractional dim must be an error, not silently dropped
        let e = load_manifest(
            "bad_shape",
            r#"{"version": 1, "artifacts": [
                {"name": "m", "file": "m.hlo.txt",
                 "inputs": [{"name": "x", "shape": [2, 3.5], "dtype": "f32", "kind": "input"}],
                 "outputs": []}]}"#,
        )
        .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("shape entry"), "{msg}");
        assert!(msg.contains("artifact m input x"), "{msg}");
        // negative output dims likewise
        let e = load_manifest(
            "neg_shape",
            r#"{"version": 1, "artifacts": [
                {"name": "m", "file": "m.hlo.txt", "inputs": [],
                 "outputs": [{"shape": [-1, 4], "dtype": "f32"}]}]}"#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("artifact m output 0"), "{e}");
    }
}
