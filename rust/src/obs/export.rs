//! Chrome trace-event JSON export (Perfetto / `chrome://tracing` loadable).
//!
//! Layout: one process per cluster node; threads are resource tracks
//! (card compute lanes, PCIe links, NIC rx/tx) plus one track per traced
//! request carrying its stage slices. Shed requests appear as instant
//! events; shared-DRAM occupancy is a counter track per node. Every event
//! carries `ph`/`ts`/`pid`/`tid` (CI's schema check relies on this), with
//! timestamps in microseconds on the modeled clock.

use std::collections::BTreeSet;

use super::{MonitorReport, SegKind, Stage, Tracer};
use crate::util::json::Json;

/// Thread-id scheme within a node's process: compute lanes are the card
/// index, PCIe links sit at 100+, the NIC at 200/201, requests at 1000+.
fn track_tid(kind: SegKind, lane: usize) -> usize {
    match kind {
        SegKind::Compute => lane,
        SegKind::Link => 100 + lane,
        SegKind::NicRx => 200,
        SegKind::NicTx => 201,
    }
}

fn track_name(kind: SegKind, lane: usize) -> String {
    match kind {
        SegKind::Compute => format!("card {lane} compute"),
        SegKind::Link => format!("card {lane} pcie"),
        SegKind::NicRx => "nic rx".to_string(),
        SegKind::NicTx => "nic tx".to_string(),
    }
}

const US: f64 = 1e6;
const REQ_TID_BASE: usize = 1000;

fn event(ph: &str, name: &str, ts_us: f64, pid: usize, tid: usize) -> Vec<(&'static str, Json)> {
    vec![
        ("name", Json::str(name)),
        ("ph", Json::str(ph)),
        ("ts", Json::num(ts_us)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
    ]
}

/// Synthetic process id for the fleet-wide SLO/telemetry tracks (real
/// node processes use their node index).
const SLO_PID: usize = 9000;

/// Render a traced run as a Chrome trace-event JSON document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn chrome_trace(t: &Tracer) -> Json {
    chrome_trace_monitored(t, None)
}

/// [`chrome_trace`] plus, when a monitor report is supplied, per-window
/// counter tracks (QPS, p99, shed, card/NIC utilization) and instant
/// events for every SLO burn-rate fire/clear, under a dedicated
/// "slo monitor" process.
pub fn chrome_trace_monitored(t: &Tracer, monitor: Option<&MonitorReport>) -> Json {
    let mut events = trace_events(t);
    if let Some(m) = monitor {
        let mut e = event("M", "process_name", 0.0, SLO_PID, 0);
        e.push(("args", Json::obj(vec![("name", Json::str("slo monitor"))])));
        events.push(Json::obj(e));
        let s = &m.series;
        for w in 0..s.windows {
            let ts = w as f64 * s.width_s * US;
            let tracks: [(&str, f64); 5] = [
                ("qps", s.qps[w]),
                ("p99_ms", s.p99_ms[w]),
                ("shed", s.shed(w) as f64),
                ("card_util", s.card_util[w]),
                ("nic_util", s.nic_util[w]),
            ];
            for (name, v) in tracks {
                let mut e = event("C", name, ts, SLO_PID, 0);
                e.push(("args", Json::obj(vec![("value", Json::num(v))])));
                events.push(Json::obj(e));
            }
        }
        for a in &m.alerts {
            let name = format!("{} {}/{}", a.kind.name(), a.objective, a.rule);
            let mut e = event("i", &name, a.t_s * US, SLO_PID, 0);
            e.push(("s", Json::str("g")));
            e.push((
                "args",
                Json::obj(vec![
                    ("burn_long", Json::num(a.burn_long)),
                    ("burn_short", Json::num(a.burn_short)),
                    ("window", Json::num(a.window as f64)),
                ]),
            ));
            events.push(Json::obj(e));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

fn trace_events(t: &Tracer) -> Vec<Json> {
    let mut events: Vec<Json> = Vec::new();

    // --- metadata: stable names for every process and thread track ------
    let mut nodes: BTreeSet<usize> = BTreeSet::new();
    let mut tracks: BTreeSet<(usize, usize, String)> = BTreeSet::new();
    for s in t.segs() {
        nodes.insert(s.node);
        tracks.insert((s.node, track_tid(s.kind, s.lane), track_name(s.kind, s.lane)));
    }
    for r in t.requests() {
        nodes.insert(r.node);
        tracks.insert((
            r.node,
            REQ_TID_BASE + r.req,
            format!("{} #{}", r.family, r.req),
        ));
    }
    for &node in &nodes {
        let mut e = event("M", "process_name", 0.0, node, 0);
        e.push(("args", Json::obj(vec![("name", Json::str(&format!("node {node}")))])));
        events.push(Json::obj(e));
    }
    for (node, tid, name) in &tracks {
        let mut e = event("M", "thread_name", 0.0, *node, *tid);
        e.push(("args", Json::obj(vec![("name", Json::str(name))])));
        events.push(Json::obj(e));
    }

    // --- occupancy segments: complete ("X") events on resource tracks --
    for s in t.segs() {
        let mut e = event(
            "X",
            s.kind.name(),
            s.start_s * US,
            s.node,
            track_tid(s.kind, s.lane),
        );
        e.push(("dur", Json::num((s.end_s - s.start_s) * US)));
        let mut args = vec![("req", Json::num(s.req as f64))];
        if s.dram > 0.0 {
            args.push(("dram", Json::num(s.dram)));
        }
        e.push(("args", Json::obj(args)));
        events.push(Json::obj(e));
    }

    // --- request lifecycles: a span per request, stage slices nested ----
    for r in t.requests() {
        let tid = REQ_TID_BASE + r.req;
        if r.completed() {
            // parent first so same-ts children nest under it
            let mut e =
                event("X", &format!("{} #{}", r.family, r.req), r.arrival_s * US, r.node, tid);
            e.push(("dur", Json::num(r.latency_s() * US)));
            e.push((
                "args",
                Json::obj(vec![
                    ("card", Json::num(r.card as f64)),
                    ("latency_ms", Json::num(r.latency_s() * 1e3)),
                ]),
            ));
            events.push(Json::obj(e));
            let mut cursor = r.arrival_s;
            for stage in Stage::ALL {
                let dur = r.stage.get(stage);
                if dur <= 0.0 {
                    continue;
                }
                let mut e = event("X", stage.name(), cursor * US, r.node, tid);
                e.push(("dur", Json::num(dur * US)));
                events.push(Json::obj(e));
                cursor += dur;
            }
        } else {
            let mut e = event("i", r.outcome, r.arrival_s * US, r.node, tid);
            e.push(("s", Json::str("t")));
            events.push(Json::obj(e));
        }
    }

    // --- shared-DRAM occupancy: counter ("C") track per node ------------
    for &node in &nodes {
        for (ts, level) in t.dram_timeline(node) {
            let mut e = event("C", "dram occupancy", ts * US, node, 0);
            e.push(("args", Json::obj(vec![("streams", Json::num(level))])));
            events.push(Json::obj(e));
        }
    }

    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{RequestTrace, SegRecord, StageBreakdown};

    #[test]
    fn every_event_has_required_fields() {
        let mut t = Tracer::new();
        t.seg(SegRecord {
            kind: SegKind::Compute,
            node: 0,
            lane: 1,
            start_s: 0.001,
            end_s: 0.002,
            req: 0,
            dram: 0.5,
        });
        t.request(RequestTrace {
            req: 0,
            family: "recsys",
            node: 0,
            card: 1,
            arrival_s: 0.0,
            finish_s: 0.002,
            stage: StageBreakdown::attribute(0.002, 0.0, 0.0005, 0.001, 0.0),
            outcome: "completed",
        });
        t.request(RequestTrace {
            req: 1,
            family: "nlp",
            node: 0,
            card: 0,
            arrival_s: 0.001,
            finish_s: 0.001,
            stage: StageBreakdown::default(),
            outcome: "shed-sla",
        });
        let doc = chrome_trace(&t);
        let parsed = Json::parse(&doc.to_string()).expect("chrome trace serializes to valid JSON");
        let evs = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
        assert!(!evs.is_empty());
        for e in evs {
            for key in ["ph", "ts", "pid", "tid"] {
                assert!(e.get(key).is_some(), "event missing {key}: {e}");
            }
        }
        // phases present: metadata, complete spans, an instant shed, a counter
        for ph in ["M", "X", "i", "C"] {
            assert!(
                evs.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some(ph)),
                "no {ph} event emitted"
            );
        }
    }

    #[test]
    fn monitored_trace_adds_counter_tracks_and_alert_instants() {
        use crate::obs::metrics::{Registry, WindowedSeries};
        use crate::obs::slo::{evaluate, MonitorReport, SloSpec};
        let mut reg = Registry::new(1.0);
        for w in 0..6usize {
            let t = w as f64 + 0.5;
            for _ in 0..100 {
                reg.inc("offered", t);
                if w == 3 {
                    reg.inc("shed_failed", t);
                } else {
                    reg.inc("completed", t);
                    reg.observe("latency_ms", t, 4.0);
                }
            }
        }
        let series = WindowedSeries::from_registry(&reg, 0, 0);
        let spec = SloSpec::deployment_default(50.0);
        let monitor =
            MonitorReport { alerts: evaluate(&series, &spec), series, spec };
        assert!(!monitor.alerts.is_empty());
        let doc = chrome_trace_monitored(&Tracer::new(), Some(&monitor));
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let count = |ph: &str, name: &str| {
            evs.iter()
                .filter(|e| {
                    e.get("ph").and_then(Json::as_str) == Some(ph)
                        && e.get("name").and_then(Json::as_str).is_some_and(|n| n.contains(name))
                })
                .count()
        };
        // one qps counter sample per window, fire + clear instants present
        assert_eq!(count("C", "qps"), 6);
        assert_eq!(count("C", "card_util"), 6);
        assert!(count("i", "fire availability") >= 1);
        assert!(count("i", "clear availability") >= 1);
        for e in evs {
            for key in ["ph", "ts", "pid", "tid"] {
                assert!(e.get(key).is_some(), "event missing {key}: {e}");
            }
        }
    }

    #[test]
    fn stage_slices_cover_the_request_span() {
        let mut t = Tracer::new();
        let stage = StageBreakdown::attribute(0.010, 0.002, 0.001, 0.004, 0.0);
        t.request(RequestTrace {
            req: 7,
            family: "cv",
            node: 2,
            card: 3,
            arrival_s: 1.0,
            finish_s: 1.010,
            stage,
            outcome: "completed",
        });
        let doc = chrome_trace(&t);
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap().to_vec();
        let slices: Vec<&Json> = evs
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("X")
                    && e.get("name").and_then(Json::as_str) != Some("cv #7")
            })
            .collect();
        let total: f64 =
            slices.iter().map(|e| e.get("dur").and_then(Json::as_f64).unwrap()).sum();
        assert!((total - 0.010 * 1e6).abs() < 1e-6, "slices sum to the latency: {total}");
    }
}
