//! The opt-in [`Tracer`]: per-request lifecycle spans plus per-card,
//! per-NIC, and shared-DRAM occupancy segments on the modeled clock.
//!
//! Routers accept an `Option<&mut Tracer>`; `None` (the default) skips all
//! recording — no allocation, no timestamp rounding, no event-heap
//! interaction — so an untraced run is bit-identical to today's reports.

use super::StageBreakdown;

/// What a recorded occupancy segment occupied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegKind {
    /// Card compute lane (`lane` = card index on the node).
    Compute,
    /// PCIe link to a card (`lane` = card index on the node).
    Link,
    /// NIC ingress serialization (`lane` unused, cluster tier only).
    NicRx,
    /// NIC egress serialization (`lane` unused, cluster tier only).
    NicTx,
}

impl SegKind {
    pub fn name(self) -> &'static str {
        match self {
            SegKind::Compute => "compute",
            SegKind::Link => "pcie",
            SegKind::NicRx => "nic rx",
            SegKind::NicTx => "nic tx",
        }
    }
}

/// One occupancy interval on a modeled resource.
#[derive(Debug, Clone, Copy)]
pub struct SegRecord {
    pub kind: SegKind,
    /// Cluster node index (0 at the fleet tier).
    pub node: usize,
    /// Card index for `Compute`/`Link`; 0 for NIC segments.
    pub lane: usize,
    pub start_s: f64,
    pub end_s: f64,
    /// Trace index of the request this work belongs to.
    pub req: usize,
    /// Shared-DRAM bandwidth occupancy held over the segment (0..=1 per
    /// stream; only compute segments carry it).
    pub dram: f64,
}

/// One request's lifecycle: arrival through completion (or shed), with its
/// stage decomposition.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub req: usize,
    pub family: &'static str,
    pub node: usize,
    pub card: usize,
    pub arrival_s: f64,
    pub finish_s: f64,
    pub stage: StageBreakdown,
    /// `"completed"` or a shed-cause name (`"shed-sla"`, ...).
    pub outcome: &'static str,
}

impl RequestTrace {
    pub fn completed(&self) -> bool {
        self.outcome == "completed"
    }

    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Recording sink for one traced run.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    segs: Vec<SegRecord>,
    requests: Vec<RequestTrace>,
}

impl Tracer {
    pub fn new() -> Self {
        Tracer::default()
    }

    pub fn seg(&mut self, seg: SegRecord) {
        self.segs.push(seg);
    }

    /// Absorb segments recorded by a node-local planner tape, stamping the
    /// cluster node index (planners don't know which node they are).
    pub fn extend_segs(&mut self, node: usize, segs: Vec<SegRecord>) {
        self.segs.extend(segs.into_iter().map(|mut s| {
            s.node = node;
            s
        }));
    }

    pub fn request(&mut self, req: RequestTrace) {
        self.requests.push(req);
    }

    pub fn segs(&self) -> &[SegRecord] {
        &self.segs
    }

    pub fn requests(&self) -> &[RequestTrace] {
        &self.requests
    }

    /// End of the modeled run: the latest timestamp any record touches.
    pub fn span_s(&self) -> f64 {
        let seg_end = self.segs.iter().map(|s| s.end_s).fold(0.0, f64::max);
        let req_end = self.requests.iter().map(|r| r.finish_s).fold(0.0, f64::max);
        seg_end.max(req_end)
    }

    /// Raw occupancy intervals for one resource track, sorted by start.
    pub fn timeline(&self, kind: SegKind, node: usize, lane: usize) -> Vec<(f64, f64)> {
        let mut iv: Vec<(f64, f64)> = self
            .segs
            .iter()
            .filter(|s| s.kind == kind && s.node == node && s.lane == lane)
            .map(|s| (s.start_s, s.end_s))
            .collect();
        iv.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        iv
    }

    /// Busy time on one resource track with overlapping intervals merged,
    /// so `busy <= span` always holds.
    pub fn busy_s(&self, kind: SegKind, node: usize, lane: usize) -> f64 {
        let mut busy = 0.0;
        let mut cur: Option<(f64, f64)> = None;
        for (s, e) in self.timeline(kind, node, lane) {
            match cur {
                Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
                Some((cs, ce)) => {
                    busy += ce - cs;
                    cur = Some((s, e));
                }
                None => cur = Some((s, e)),
            }
        }
        if let Some((cs, ce)) = cur {
            busy += ce - cs;
        }
        busy
    }

    /// Fraction of the run a resource track was busy; in [0, 1] by
    /// construction (merged busy time over the full trace span).
    pub fn utilization(&self, kind: SegKind, node: usize, lane: usize) -> f64 {
        let span = self.span_s();
        if span <= 0.0 {
            0.0
        } else {
            self.busy_s(kind, node, lane) / span
        }
    }

    /// Shared-DRAM occupancy timeline for one node: `(ts, occupancy)`
    /// steps from the dram-weighted compute segments, for counter tracks.
    pub fn dram_timeline(&self, node: usize) -> Vec<(f64, f64)> {
        let mut deltas: Vec<(f64, f64)> = Vec::new();
        for s in &self.segs {
            if s.node == node && s.dram > 0.0 {
                deltas.push((s.start_s, s.dram));
                deltas.push((s.end_s, -s.dram));
            }
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut out: Vec<(f64, f64)> = Vec::with_capacity(deltas.len());
        let mut level = 0.0;
        for (t, d) in deltas {
            level += d;
            match out.last_mut() {
                Some(last) if last.0 == t => last.1 = level,
                _ => out.push((t, level)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(kind: SegKind, lane: usize, start: f64, end: f64, dram: f64) -> SegRecord {
        SegRecord { kind, node: 0, lane, start_s: start, end_s: end, req: 0, dram }
    }

    #[test]
    fn busy_merges_overlaps_and_bounds_utilization() {
        let mut t = Tracer::new();
        t.seg(seg(SegKind::Compute, 0, 0.0, 2.0, 0.0));
        t.seg(seg(SegKind::Compute, 0, 1.0, 3.0, 0.0)); // overlaps
        t.seg(seg(SegKind::Compute, 0, 5.0, 6.0, 0.0)); // gap
        t.seg(seg(SegKind::Compute, 1, 0.0, 10.0, 0.0)); // other lane
        assert!((t.busy_s(SegKind::Compute, 0, 0) - 4.0).abs() < 1e-12);
        assert_eq!(t.span_s(), 10.0);
        let u = t.utilization(SegKind::Compute, 0, 0);
        assert!(u > 0.0 && u <= 1.0);
        assert!((t.utilization(SegKind::Compute, 0, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn extend_segs_stamps_node() {
        let mut t = Tracer::new();
        t.extend_segs(3, vec![seg(SegKind::Link, 2, 0.0, 1.0, 0.0)]);
        assert_eq!(t.segs()[0].node, 3);
        assert!((t.busy_s(SegKind::Link, 3, 2) - 1.0).abs() < 1e-12);
        assert_eq!(t.busy_s(SegKind::Link, 0, 2), 0.0);
    }

    #[test]
    fn dram_timeline_accumulates_and_releases() {
        let mut t = Tracer::new();
        t.seg(seg(SegKind::Compute, 0, 0.0, 2.0, 0.5));
        t.seg(seg(SegKind::Compute, 1, 1.0, 3.0, 0.25));
        let tl = t.dram_timeline(0);
        assert_eq!(tl.len(), 4);
        assert_eq!(tl[0], (0.0, 0.5));
        assert_eq!(tl[1], (1.0, 0.75));
        assert_eq!(tl[2], (2.0, 0.25));
        assert!((tl[3].1 - 0.0).abs() < 1e-12);
    }
}
