//! Declarative SLOs and multi-window error-budget burn-rate alerting.
//!
//! An [`Objective`] defines what fraction of requests may be "bad" (the
//! error budget): availability (shed = bad) or a p-latency budget
//! (completion over budget = bad). A [`BurnRule`] watches how fast that
//! budget burns: the event-weighted bad fraction over a trailing `long`
//! window span, divided by the budget, must reach `factor` — and the same
//! over the `short` span, so an alert both catches sustained burns and
//! resets quickly once the burn stops (the standard multi-window
//! burn-rate construction from the SRE literature).
//!
//! [`evaluate`] is a pure function of a [`WindowedSeries`] and a
//! [`SloSpec`]: alert events are emitted at window granularity in
//! chronological order, so determinism is inherited from the plan — the
//! same seed and topology produce bit-identical alert streams regardless
//! of worker count.

use crate::obs::metrics::WindowedSeries;
use crate::util::json::Json;

/// What counts as a "bad" event for an objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObjectiveKind {
    /// Good = admitted and completed; bad = shed (any cause). `target` is
    /// the availability goal, e.g. 0.99 → 1% error budget.
    Availability { target: f64 },
    /// Good = completed under `budget_ms`; bad = over it. `target` is the
    /// fraction that must be under budget, e.g. 0.95.
    LatencyBudget { budget_ms: f64, target: f64 },
}

/// A named service-level objective over the windowed series.
#[derive(Debug, Clone, PartialEq)]
pub struct Objective {
    pub name: String,
    pub kind: ObjectiveKind,
}

impl Objective {
    pub fn availability(target: f64) -> Objective {
        assert!(target > 0.0 && target < 1.0, "availability target {target} outside (0,1)");
        Objective { name: "availability".to_string(), kind: ObjectiveKind::Availability { target } }
    }

    pub fn latency_budget(budget_ms: f64, target: f64) -> Objective {
        assert!(target > 0.0 && target < 1.0, "latency target {target} outside (0,1)");
        Objective {
            name: "latency".to_string(),
            kind: ObjectiveKind::LatencyBudget { budget_ms, target },
        }
    }

    /// Allowed bad fraction (1 - target).
    pub fn budget(&self) -> f64 {
        match self.kind {
            ObjectiveKind::Availability { target } => 1.0 - target,
            ObjectiveKind::LatencyBudget { target, .. } => 1.0 - target,
        }
    }

    /// Per-window `(bad, total)` event counts for this objective.
    fn events(&self, s: &WindowedSeries) -> Vec<(u64, u64)> {
        (0..s.windows)
            .map(|w| match self.kind {
                ObjectiveKind::Availability { .. } => (s.shed(w), s.offered[w]),
                ObjectiveKind::LatencyBudget { budget_ms, .. } => {
                    let sk = &s.latency_ms[w];
                    let total = sk.count();
                    (total - sk.rank_le(budget_ms), total)
                }
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        match self.kind {
            ObjectiveKind::Availability { target } => Json::obj(vec![
                ("name", Json::str(&self.name)),
                ("kind", Json::str("availability")),
                ("target", Json::num(target)),
            ]),
            ObjectiveKind::LatencyBudget { budget_ms, target } => Json::obj(vec![
                ("name", Json::str(&self.name)),
                ("kind", Json::str("latency_budget")),
                ("budget_ms", Json::num(budget_ms)),
                ("target", Json::num(target)),
            ]),
        }
    }
}

/// One multi-window burn-rate rule: fire when the budget burns at ≥
/// `factor`× the sustainable rate over both trailing spans.
#[derive(Debug, Clone, PartialEq)]
pub struct BurnRule {
    pub label: String,
    /// Trailing window count for the sustained condition.
    pub long: usize,
    /// Trailing window count for the reset condition.
    pub short: usize,
    pub factor: f64,
}

impl BurnRule {
    pub fn new(label: &str, long: usize, short: usize, factor: f64) -> BurnRule {
        assert!(long >= short && short >= 1, "burn rule spans long {long} >= short {short} >= 1");
        assert!(factor > 0.0, "burn factor must be positive");
        BurnRule { label: label.to_string(), long, short, factor }
    }

    /// Event-weighted burn rate over the trailing `k` windows ending at
    /// `w` (clamped to run start): bad/total/budget; 0 with no events.
    fn burn(events: &[(u64, u64)], w: usize, k: usize, budget: f64) -> f64 {
        let lo = (w + 1).saturating_sub(k);
        let (mut bad, mut total) = (0u64, 0u64);
        for &(b, t) in &events[lo..=w] {
            bad += b;
            total += t;
        }
        if total == 0 {
            0.0
        } else {
            bad as f64 / total as f64 / budget
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("long_windows", Json::num(self.long as f64)),
            ("short_windows", Json::num(self.short as f64)),
            ("factor", Json::num(self.factor)),
        ])
    }
}

/// Fire/clear edge of one (objective, rule) alert state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertKind {
    Fire,
    Clear,
}

impl AlertKind {
    pub fn name(self) -> &'static str {
        match self {
            AlertKind::Fire => "fire",
            AlertKind::Clear => "clear",
        }
    }
}

/// A deterministic alert event on the run timeline, emitted at the end of
/// the window whose evaluation flipped the state.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertEvent {
    pub objective: String,
    pub rule: String,
    pub kind: AlertKind,
    pub window: usize,
    /// End of the triggering window: `(window + 1) * width_s`.
    pub t_s: f64,
    pub burn_long: f64,
    pub burn_short: f64,
}

impl AlertEvent {
    pub fn describe(&self) -> String {
        format!(
            "[{:>9.4}s] {} {}/{} at window {} (burn long {:.1}x short {:.1}x)",
            self.t_s,
            self.kind.name().to_uppercase(),
            self.objective,
            self.rule,
            self.window,
            self.burn_long,
            self.burn_short,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("objective", Json::str(&self.objective)),
            ("rule", Json::str(&self.rule)),
            ("kind", Json::str(self.kind.name())),
            ("window", Json::num(self.window as f64)),
            ("t_s", Json::num(self.t_s)),
            ("burn_long", Json::num(self.burn_long)),
            ("burn_short", Json::num(self.burn_short)),
        ])
    }
}

/// A set of objectives and the burn rules applied to each.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    pub objectives: Vec<Objective>,
    pub rules: Vec<BurnRule>,
}

impl SloSpec {
    /// The deployment default: 99% availability and 95%-under-p99-budget,
    /// each watched by a fast page rule (3-window sustain, 1-window reset,
    /// 8× burn) and a slow ticket rule (12/3 at 4×).
    pub fn deployment_default(p99_budget_ms: f64) -> SloSpec {
        SloSpec {
            objectives: vec![
                Objective::availability(0.99),
                Objective::latency_budget(p99_budget_ms, 0.95),
            ],
            rules: vec![BurnRule::new("fast", 3, 1, 8.0), BurnRule::new("slow", 12, 3, 4.0)],
        }
    }

    /// The loosest bound on detection latency: no rule needs more than
    /// this many windows of history to reach its firing condition.
    pub fn max_detection_windows(&self) -> usize {
        self.rules.iter().map(|r| r.long).max().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("objectives", Json::arr(self.objectives.iter().map(Objective::to_json).collect())),
            ("rules", Json::arr(self.rules.iter().map(BurnRule::to_json).collect())),
        ])
    }
}

/// Evaluate every (objective, rule) state machine over the series and
/// return the fire/clear edges in chronological order (window-major, then
/// spec order — fully deterministic).
pub fn evaluate(series: &WindowedSeries, spec: &SloSpec) -> Vec<AlertEvent> {
    let per_objective: Vec<Vec<(u64, u64)>> =
        spec.objectives.iter().map(|o| o.events(series)).collect();
    let mut firing = vec![false; spec.objectives.len() * spec.rules.len()];
    let mut out = Vec::new();
    for w in 0..series.windows {
        for (oi, obj) in spec.objectives.iter().enumerate() {
            let events = &per_objective[oi];
            let budget = obj.budget();
            for (ri, rule) in spec.rules.iter().enumerate() {
                let burn_long = BurnRule::burn(events, w, rule.long, budget);
                let burn_short = BurnRule::burn(events, w, rule.short, budget);
                let now = burn_long >= rule.factor && burn_short >= rule.factor;
                let state = &mut firing[oi * spec.rules.len() + ri];
                if now != *state {
                    *state = now;
                    out.push(AlertEvent {
                        objective: obj.name.clone(),
                        rule: rule.label.clone(),
                        kind: if now { AlertKind::Fire } else { AlertKind::Clear },
                        window: w,
                        t_s: (w + 1) as f64 * series.width_s,
                        burn_long,
                        burn_short,
                    });
                }
            }
        }
    }
    out
}

/// Everything a monitored run produces beyond the `SimReport`: the
/// windowed series, the spec it was judged against, and the alert stream.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorReport {
    pub series: WindowedSeries,
    pub spec: SloSpec,
    pub alerts: Vec<AlertEvent>,
}

impl MonitorReport {
    /// First Fire event for `objective` (any rule).
    pub fn first_fire(&self, objective: &str) -> Option<&AlertEvent> {
        self.alerts
            .iter()
            .find(|a| a.objective == objective && a.kind == AlertKind::Fire)
    }

    /// True when `objective` fired within `bound` windows of `from_window`.
    pub fn fires_within(&self, objective: &str, from_window: usize, bound: usize) -> bool {
        self.first_fire(objective)
            .is_some_and(|a| a.window >= from_window && a.window <= from_window + bound)
    }

    /// True when every rule of `objective` that ever fired ended cleared.
    pub fn cleared(&self, objective: &str) -> bool {
        let mut last: std::collections::BTreeMap<&str, AlertKind> =
            std::collections::BTreeMap::new();
        for a in &self.alerts {
            if a.objective == objective {
                last.insert(a.rule.as_str(), a.kind);
            }
        }
        last.values().all(|&k| k == AlertKind::Clear)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("windows", self.series.to_json()),
            ("slo", self.spec.to_json()),
            ("alerts", Json::arr(self.alerts.iter().map(AlertEvent::to_json).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::{Registry, WindowedSeries};

    /// 12 windows, 100 offered each; sheds only in window 5.
    fn shed_burst_series(shed_in_w5: u64) -> WindowedSeries {
        let mut reg = Registry::new(1.0);
        for w in 0..12usize {
            let t = w as f64 + 0.5;
            for i in 0..100u64 {
                reg.inc("offered", t);
                if w == 5 && i < shed_in_w5 {
                    reg.inc("shed_failed", t);
                } else {
                    reg.inc("completed", t);
                    reg.observe("latency_ms", t, 5.0);
                }
            }
        }
        WindowedSeries::from_registry(&reg, 0, 0)
    }

    #[test]
    fn burn_alert_fires_on_burst_and_clears_after() {
        let spec = SloSpec::deployment_default(50.0);
        let s = shed_burst_series(40);
        let report = MonitorReport { alerts: evaluate(&s, &spec), series: s, spec };
        // fast rule: burn_short at w5 = 0.4/0.01 = 40x >= 8, long covers
        // w3..w5 = 0.4/3/0.01 = 13x >= 8 -> fires exactly at the burst
        let fire = report.first_fire("availability").expect("must fire");
        assert_eq!(fire.window, 5);
        assert_eq!(fire.rule, "fast");
        assert!(report.fires_within("availability", 5, 3));
        // short window moves past the burst -> clears
        assert!(report.cleared("availability"));
        let clear = report
            .alerts
            .iter()
            .find(|a| a.kind == AlertKind::Clear && a.objective == "availability")
            .expect("must clear");
        assert!(clear.window > 5 && clear.window <= 8);
        // healthy latency objective never fires
        assert!(report.first_fire("latency").is_none());
    }

    #[test]
    fn no_alerts_below_budget_and_evaluation_is_deterministic() {
        let spec = SloSpec::deployment_default(50.0);
        let s = shed_burst_series(0);
        assert!(evaluate(&s, &spec).is_empty());
        let s = shed_burst_series(25);
        assert_eq!(evaluate(&s, &spec), evaluate(&s, &spec));
    }

    #[test]
    fn latency_budget_objective_counts_over_budget_completions() {
        let mut reg = Registry::new(1.0);
        for w in 0..6usize {
            let t = w as f64 + 0.5;
            for i in 0..50u64 {
                reg.inc("offered", t);
                reg.inc("completed", t);
                // window 2: every completion blows the 10ms budget
                let ms = if w == 2 { 80.0 + i as f64 } else { 2.0 };
                reg.observe("latency_ms", t, ms);
            }
        }
        let s = WindowedSeries::from_registry(&reg, 0, 0);
        // long span dilutes the burst by 3x: 50/150 bad / 0.05 budget = 6.7x
        let spec = SloSpec {
            objectives: vec![Objective::latency_budget(10.0, 0.95)],
            rules: vec![BurnRule::new("fast", 3, 1, 4.0)],
        };
        let alerts = evaluate(&s, &spec);
        assert!(!alerts.is_empty());
        assert_eq!(alerts[0].kind, AlertKind::Fire);
        assert_eq!(alerts[0].window, 2);
        assert_eq!(alerts.last().unwrap().kind, AlertKind::Clear);
    }

    #[test]
    fn spec_json_round_trips_shape() {
        let spec = SloSpec::deployment_default(25.0);
        let js = spec.to_json();
        assert_eq!(js.get("rules").and_then(Json::as_arr).map(|r| r.len()), Some(2));
        assert_eq!(spec.max_detection_windows(), 12);
    }
}
