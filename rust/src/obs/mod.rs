//! Observability: request-level tracing, stage latency attribution,
//! occupancy timelines, and windowed telemetry with SLO monitoring for the
//! serving tiers (the paper's §VI/§VII performance-optimization and
//! deployment-operations tooling — knowing *why* a deployment is slow, and
//! catching it degrading *as it happens*, not just that p99 regressed).
//!
//! Three layers with distinct cost contracts:
//!
//! - **Stage attribution** ([`StageBreakdown`]/[`StageStats`]) is always on.
//!   It is pure arithmetic over timestamps the routers already compute —
//!   `Copy` fields carried on each routing decision, no allocations on the
//!   planning path, no event-heap interaction — so enabling it cannot
//!   perturb any existing report bit.
//! - **Tracing** ([`Tracer`]) is opt-in. When no tracer is passed the
//!   routers skip every recording branch (`Option` checks on `Copy` data
//!   only), reports are bit-identical to an untraced run, and the planning
//!   loop performs zero additional allocations. When enabled, the tracer
//!   records per-request lifecycle spans and per-card / per-NIC / DRAM
//!   occupancy segments on the modeled clock, exportable as a Chrome
//!   trace-event JSON ([`chrome_trace`]) loadable in Perfetto.
//! - **Windowed telemetry + SLO** ([`WindowedSeries`]/[`SloSpec`]) derives
//!   fixed-width time-series (QPS, latency quantiles, utilization,
//!   shed-by-cause) *post-hoc from the trace*, then runs declarative
//!   error-budget burn-rate rules over them ([`evaluate`]) to emit
//!   deterministic alert events. Because it reads the plan rather than
//!   instrumenting the planner, it inherits tracing's cost contract: off
//!   means bit-identical and allocation-free.
//!
//! See `rust/docs/observability.md` for the span model and stage taxonomy,
//! and `rust/docs/metrics.md` for window semantics and the SLO layer.

mod export;
pub mod metrics;
pub mod slo;
mod stages;
mod trace;

pub use export::{chrome_trace, chrome_trace_monitored};
pub use metrics::{Registry, SeriesTotals, WindowFeed, WindowSpec, WindowedSeries};
pub use slo::{evaluate, AlertEvent, AlertKind, BurnRule, MonitorReport, Objective, SloSpec};
pub use stages::{Stage, StageBreakdown, StageStats, STAGE_SAMPLE_CAP};
pub use trace::{RequestTrace, SegKind, SegRecord, Tracer};
