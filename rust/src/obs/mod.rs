//! Observability: request-level tracing, stage latency attribution, and
//! occupancy timelines for the serving tiers (the paper's §VI/§VII
//! performance-optimization tooling — knowing *why* a deployment is slow,
//! not just *that* p99 regressed).
//!
//! Two layers with very different cost contracts:
//!
//! - **Stage attribution** ([`StageBreakdown`]/[`StageStats`]) is always on.
//!   It is pure arithmetic over timestamps the routers already compute —
//!   `Copy` fields carried on each routing decision, no allocations on the
//!   planning path, no event-heap interaction — so enabling it cannot
//!   perturb any existing report bit.
//! - **Tracing** ([`Tracer`]) is opt-in. When no tracer is passed the
//!   routers skip every recording branch (`Option` checks on `Copy` data
//!   only), reports are bit-identical to an untraced run, and the planning
//!   loop performs zero additional allocations. When enabled, the tracer
//!   records per-request lifecycle spans and per-card / per-NIC / DRAM
//!   occupancy segments on the modeled clock, exportable as a Chrome
//!   trace-event JSON ([`chrome_trace`]) loadable in Perfetto.
//!
//! See `rust/docs/observability.md` for the span model and stage taxonomy.

mod export;
mod stages;
mod trace;

pub use export::chrome_trace;
pub use stages::{Stage, StageBreakdown, StageStats};
pub use trace::{RequestTrace, SegKind, SegRecord, Tracer};
