//! Typed metric registry and fixed-width windowed telemetry.
//!
//! The paper's deployment sections treat load as a *time series* — the
//! diurnal demand curve, degradation under failure, utilization headroom —
//! not a point-in-time aggregate. This module turns a run into fixed-width
//! windows: per-window QPS, latency quantiles (via
//! [`QuantileSketch`](crate::util::stats::QuantileSketch)), card/NIC
//! utilization, and shed-by-cause counts.
//!
//! Two feeds exist:
//!
//! - **Modeled clock** — [`WindowedSeries::from_tracer`] derives every
//!   window post-hoc from the [`Tracer`](crate::obs::trace::Tracer) the DES
//!   routers already populate. Deriving from the plan (instead of
//!   instrumenting the planner) keeps the PR 9 cost contract intact: with
//!   observability off the hot loop is bit-identical and allocation-free.
//! - **Wall clock** — the real servers push completions through a
//!   [`WindowFeed`] as they stream (`ServeOptions::window_s`).
//!
//! Window semantics: window `w` covers `[w*width, (w+1)*width)`. Offered
//! and shed requests are attributed to their **arrival** window (both
//! routers stamp shed requests with `finish_s == arrival_s`); completions
//! and their latency samples to their **finish** window. Summing any count
//! series over all windows therefore reconciles bit-exactly with the
//! corresponding `SimReport` total — a property the integration suite pins.

use crate::obs::trace::{SegKind, Tracer};
use crate::util::json::Json;
use crate::util::stats::QuantileSketch;
use std::collections::BTreeMap;

/// Fixed window geometry: width in (modeled or wall) seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowSpec {
    pub width_s: f64,
}

impl WindowSpec {
    pub fn new(width_s: f64) -> WindowSpec {
        assert!(width_s > 0.0 && width_s.is_finite(), "window width {width_s} must be positive");
        WindowSpec { width_s }
    }

    /// Window index covering time `t_s` (clamped at zero).
    pub fn index(&self, t_s: f64) -> usize {
        let w = (t_s / self.width_s).floor();
        if w > 0.0 {
            w as usize
        } else {
            0
        }
    }

    /// Start time of window `w`.
    pub fn start_s(&self, w: usize) -> f64 {
        w as f64 * self.width_s
    }
}

/// Monotone per-window event counts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CounterSeries {
    per_window: Vec<u64>,
    total: u64,
}

impl CounterSeries {
    pub fn inc(&mut self, w: usize) {
        self.add(w, 1);
    }

    pub fn add(&mut self, w: usize, k: u64) {
        if self.per_window.len() <= w {
            self.per_window.resize(w + 1, 0);
        }
        self.per_window[w] += k;
        self.total += k;
    }

    pub fn window(&self, w: usize) -> u64 {
        self.per_window.get(w).copied().unwrap_or(0)
    }

    /// Sum over all windows — reconciles with the run total by
    /// construction (every increment lands in exactly one window).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn windows(&self) -> usize {
        self.per_window.len()
    }
}

/// Per-window accumulated quantity (e.g. busy-seconds for utilization).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GaugeSeries {
    per_window: Vec<f64>,
}

impl GaugeSeries {
    pub fn add(&mut self, w: usize, v: f64) {
        if self.per_window.len() <= w {
            self.per_window.resize(w + 1, 0.0);
        }
        self.per_window[w] += v;
    }

    pub fn window(&self, w: usize) -> f64 {
        self.per_window.get(w).copied().unwrap_or(0.0)
    }

    pub fn windows(&self) -> usize {
        self.per_window.len()
    }
}

/// Per-window value distribution, one quantile sketch per window.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSeries {
    eps: f64,
    per_window: Vec<QuantileSketch>,
}

impl HistogramSeries {
    pub fn new(eps: f64) -> HistogramSeries {
        HistogramSeries { eps, per_window: Vec::new() }
    }

    pub fn observe(&mut self, w: usize, v: f64) {
        while self.per_window.len() <= w {
            self.per_window.push(QuantileSketch::new(self.eps));
        }
        self.per_window[w].add(v);
    }

    pub fn window(&self, w: usize) -> Option<&QuantileSketch> {
        self.per_window.get(w)
    }

    pub fn windows(&self) -> usize {
        self.per_window.len()
    }
}

/// Rank-error fraction of per-window latency sketches. Smoke-sized windows
/// hold well under `1/eps` samples, so their quantiles are exact.
pub const WINDOW_SKETCH_EPS: f64 = 0.005;

/// Typed metric registry: named counters, gauges, and windowed histograms
/// sharing one [`WindowSpec`]. Time-stamped feed calls map to window
/// indices internally; names are `BTreeMap`-keyed so iteration (and hence
/// every derived report) is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub struct Registry {
    spec: WindowSpec,
    counters: BTreeMap<String, CounterSeries>,
    gauges: BTreeMap<String, GaugeSeries>,
    hists: BTreeMap<String, HistogramSeries>,
}

impl Registry {
    pub fn new(width_s: f64) -> Registry {
        Registry {
            spec: WindowSpec::new(width_s),
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
        }
    }

    pub fn spec(&self) -> WindowSpec {
        self.spec
    }

    /// Count one event at time `t_s`.
    pub fn inc(&mut self, name: &str, t_s: f64) {
        let w = self.spec.index(t_s);
        self.counters.entry(name.to_string()).or_default().inc(w);
    }

    /// Accumulate `v` into the gauge window covering `t_s`.
    pub fn gauge_add(&mut self, name: &str, t_s: f64, v: f64) {
        let w = self.spec.index(t_s);
        self.gauges.entry(name.to_string()).or_default().add(w, v);
    }

    /// Distribute the span `[start_s, end_s)` across the windows it
    /// overlaps, accumulating the overlap seconds into each — the feed
    /// behind busy-seconds/utilization gauges.
    pub fn add_span(&mut self, name: &str, start_s: f64, end_s: f64) {
        if end_s <= start_s {
            return;
        }
        let gauge = self.gauges.entry(name.to_string()).or_default();
        let (w0, w1) = (self.spec.index(start_s), self.spec.index(end_s));
        for w in w0..=w1 {
            let ws = self.spec.start_s(w);
            let overlap = end_s.min(ws + self.spec.width_s) - start_s.max(ws);
            if overlap > 0.0 {
                gauge.add(w, overlap);
            }
        }
    }

    /// Record a distribution sample at time `t_s`.
    pub fn observe(&mut self, name: &str, t_s: f64, v: f64) {
        let w = self.spec.index(t_s);
        self.hists
            .entry(name.to_string())
            .or_insert_with(|| HistogramSeries::new(WINDOW_SKETCH_EPS))
            .observe(w, v);
    }

    pub fn counter(&self, name: &str) -> Option<&CounterSeries> {
        self.counters.get(name)
    }

    pub fn gauge(&self, name: &str) -> Option<&GaugeSeries> {
        self.gauges.get(name)
    }

    pub fn hist(&self, name: &str) -> Option<&HistogramSeries> {
        self.hists.get(name)
    }

    /// Number of windows spanned by any registered series.
    pub fn windows(&self) -> usize {
        let c = self.counters.values().map(CounterSeries::windows).max().unwrap_or(0);
        let g = self.gauges.values().map(GaugeSeries::windows).max().unwrap_or(0);
        let h = self.hists.values().map(HistogramSeries::windows).max().unwrap_or(0);
        c.max(g).max(h)
    }
}

/// Integer totals of a [`WindowedSeries`] — the quantities that must
/// reconcile bit-exactly with `SimReport`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeriesTotals {
    pub offered: u64,
    pub completed: u64,
    pub shed_queue_full: u64,
    pub shed_sla: u64,
    pub shed_no_bucket: u64,
    pub shed_failed: u64,
    pub shed_unroutable: u64,
}

impl SeriesTotals {
    pub fn shed(&self) -> u64 {
        self.shed_queue_full
            + self.shed_sla
            + self.shed_no_bucket
            + self.shed_failed
            + self.shed_unroutable
    }
}

/// The fixed-schema product of a monitored run: every per-window series the
/// SLO layer, the CLI tables, the chrome-trace counter tracks, and the
/// bench extras consume. All vectors have length `windows`.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowedSeries {
    pub width_s: f64,
    pub windows: usize,
    /// Arrivals per window (completed + shed, attributed at arrival).
    pub offered: Vec<u64>,
    /// Completions per window (attributed at finish).
    pub completed: Vec<u64>,
    pub shed_queue_full: Vec<u64>,
    pub shed_sla: Vec<u64>,
    pub shed_no_bucket: Vec<u64>,
    pub shed_failed: Vec<u64>,
    pub shed_unroutable: Vec<u64>,
    /// Completions per window / width.
    pub qps: Vec<f64>,
    pub p50_ms: Vec<f64>,
    pub p99_ms: Vec<f64>,
    /// Latency sketch per window (ms) — the SLO layer reads budget
    /// exceedance fractions off these.
    pub latency_ms: Vec<QuantileSketch>,
    /// Compute busy-seconds / (width × cards); 0 when card count unknown.
    pub card_util: Vec<f64>,
    /// NIC rx+tx busy-seconds / (width × ports); 0 at the fleet tier.
    pub nic_util: Vec<f64>,
}

impl WindowedSeries {
    /// Derive the full windowed series from a run trace. `cards` and
    /// `nic_ports` normalize the utilization gauges (0 disables one).
    pub fn from_tracer(
        tracer: &Tracer,
        width_s: f64,
        cards: usize,
        nic_ports: usize,
    ) -> WindowedSeries {
        let mut reg = Registry::new(width_s);
        for r in tracer.requests() {
            reg.inc("offered", r.arrival_s);
            if r.completed() {
                reg.inc("completed", r.finish_s);
                reg.observe("latency_ms", r.finish_s, r.latency_s() * 1e3);
            } else {
                // both routers stamp shed requests finish_s == arrival_s,
                // so cause counts attribute to the arrival window
                let name = match r.outcome {
                    "shed-queue-full" => "shed_queue_full",
                    "shed-sla" => "shed_sla",
                    "shed-no-bucket" => "shed_no_bucket",
                    "shed-failed" => "shed_failed",
                    _ => "shed_unroutable",
                };
                reg.inc(name, r.arrival_s);
            }
        }
        for s in tracer.segs() {
            match s.kind {
                SegKind::Compute => reg.add_span("card_busy_s", s.start_s, s.end_s),
                SegKind::NicRx | SegKind::NicTx => reg.add_span("nic_busy_s", s.start_s, s.end_s),
                SegKind::Link => {}
            }
        }
        WindowedSeries::from_registry(&reg, cards, nic_ports)
    }

    /// Assemble the fixed schema out of a fed [`Registry`], padding every
    /// series to the common window count.
    pub fn from_registry(reg: &Registry, cards: usize, nic_ports: usize) -> WindowedSeries {
        let windows = reg.windows();
        let width_s = reg.spec().width_s;
        let counts = |name: &str| -> Vec<u64> {
            (0..windows).map(|w| reg.counter(name).map_or(0, |c| c.window(w))).collect()
        };
        let offered = counts("offered");
        let completed = counts("completed");
        let latency_ms: Vec<QuantileSketch> = (0..windows)
            .map(|w| {
                reg.hist("latency_ms")
                    .and_then(|h| h.window(w))
                    .cloned()
                    .unwrap_or_else(|| QuantileSketch::new(WINDOW_SKETCH_EPS))
            })
            .collect();
        let qps = completed.iter().map(|&c| c as f64 / width_s).collect();
        let p50_ms = latency_ms.iter().map(|sk| sk.quantile(0.5)).collect();
        let p99_ms = latency_ms.iter().map(|sk| sk.quantile(0.99)).collect();
        let util = |name: &str, n: usize| -> Vec<f64> {
            (0..windows)
                .map(|w| {
                    if n == 0 {
                        0.0
                    } else {
                        reg.gauge(name).map_or(0.0, |g| g.window(w)) / (width_s * n as f64)
                    }
                })
                .collect()
        };
        WindowedSeries {
            width_s,
            windows,
            offered,
            completed,
            shed_queue_full: counts("shed_queue_full"),
            shed_sla: counts("shed_sla"),
            shed_no_bucket: counts("shed_no_bucket"),
            shed_failed: counts("shed_failed"),
            shed_unroutable: counts("shed_unroutable"),
            qps,
            p50_ms,
            p99_ms,
            latency_ms,
            card_util: util("card_busy_s", cards),
            nic_util: util("nic_busy_s", nic_ports),
        }
    }

    /// Total sheds in window `w`, across all causes.
    pub fn shed(&self, w: usize) -> u64 {
        self.shed_queue_full[w]
            + self.shed_sla[w]
            + self.shed_no_bucket[w]
            + self.shed_failed[w]
            + self.shed_unroutable[w]
    }

    /// Sum every count series over all windows.
    pub fn totals(&self) -> SeriesTotals {
        let sum = |xs: &[u64]| xs.iter().sum::<u64>();
        SeriesTotals {
            offered: sum(&self.offered),
            completed: sum(&self.completed),
            shed_queue_full: sum(&self.shed_queue_full),
            shed_sla: sum(&self.shed_sla),
            shed_no_bucket: sum(&self.shed_no_bucket),
            shed_failed: sum(&self.shed_failed),
            shed_unroutable: sum(&self.shed_unroutable),
        }
    }

    pub fn to_json(&self) -> Json {
        let nums_u = |xs: &[u64]| Json::arr(xs.iter().map(|&x| Json::num(x as f64)).collect());
        let nums_f = |xs: &[f64]| Json::arr(xs.iter().map(|&x| Json::num(x)).collect());
        Json::obj(vec![
            ("width_ms", Json::num(self.width_s * 1e3)),
            ("windows", Json::num(self.windows as f64)),
            ("offered", nums_u(&self.offered)),
            ("completed", nums_u(&self.completed)),
            (
                "shed",
                Json::obj(vec![
                    ("queue_full", nums_u(&self.shed_queue_full)),
                    ("sla", nums_u(&self.shed_sla)),
                    ("no_bucket", nums_u(&self.shed_no_bucket)),
                    ("failed", nums_u(&self.shed_failed)),
                    ("unroutable", nums_u(&self.shed_unroutable)),
                ]),
            ),
            ("qps", nums_f(&self.qps)),
            ("p50_ms", nums_f(&self.p50_ms)),
            ("p99_ms", nums_f(&self.p99_ms)),
            ("card_util", nums_f(&self.card_util)),
            ("nic_util", nums_f(&self.nic_util)),
        ])
    }
}

/// Incremental completion feed for the real servers on the wall (or
/// modeled) clock: push each completion as it happens, then [`finish`]
/// into a [`WindowedSeries`]. Closed-loop servers admit every request, so
/// offered == completed and both attribute at completion time.
///
/// [`finish`]: WindowFeed::finish
#[derive(Debug, Clone)]
pub struct WindowFeed {
    reg: Registry,
}

impl WindowFeed {
    pub fn new(width_s: f64) -> WindowFeed {
        WindowFeed { reg: Registry::new(width_s) }
    }

    pub fn complete(&mut self, t_s: f64, latency_s: f64) {
        self.reg.inc("offered", t_s);
        self.reg.inc("completed", t_s);
        self.reg.observe("latency_ms", t_s, latency_s * 1e3);
    }

    pub fn finish(self) -> WindowedSeries {
        WindowedSeries::from_registry(&self.reg, 0, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{RequestTrace, SegRecord};
    use crate::obs::StageBreakdown;

    #[test]
    fn window_spec_maps_times_to_windows() {
        let spec = WindowSpec::new(0.5);
        assert_eq!(spec.index(0.0), 0);
        assert_eq!(spec.index(0.49), 0);
        assert_eq!(spec.index(0.5), 1);
        assert_eq!(spec.index(-1.0), 0);
        assert_eq!(spec.start_s(3), 1.5);
    }

    #[test]
    fn counter_series_totals_reconcile() {
        let mut c = CounterSeries::default();
        c.inc(0);
        c.inc(2);
        c.add(2, 3);
        assert_eq!(c.windows(), 3);
        assert_eq!(c.window(1), 0);
        assert_eq!(c.window(2), 4);
        assert_eq!(c.total(), 5);
        assert_eq!((0..c.windows()).map(|w| c.window(w)).sum::<u64>(), c.total());
    }

    #[test]
    fn span_distributes_busy_seconds_across_windows() {
        let mut reg = Registry::new(1.0);
        reg.add_span("busy", 0.5, 2.5); // 0.5s in w0, 1.0s in w1, 0.5s in w2
        let g = reg.gauge("busy").unwrap();
        assert!((g.window(0) - 0.5).abs() < 1e-12);
        assert!((g.window(1) - 1.0).abs() < 1e-12);
        assert!((g.window(2) - 0.5).abs() < 1e-12);
        // span ending exactly on a boundary adds nothing past it
        reg.add_span("edge", 0.0, 1.0);
        assert_eq!(reg.gauge("edge").unwrap().windows(), 1);
    }

    fn req(arrival_s: f64, finish_s: f64, outcome: &'static str) -> RequestTrace {
        RequestTrace {
            req: 0,
            family: "recsys",
            node: 0,
            card: 0,
            arrival_s,
            finish_s,
            stage: StageBreakdown::default(),
            outcome,
        }
    }

    #[test]
    fn tracer_series_reconciles_and_attributes_windows() {
        let mut t = Tracer::new();
        t.request(req(0.1, 0.2, "completed")); // w0 -> w0
        t.request(req(0.9, 1.4, "completed")); // offered w0, completed w1
        t.request(req(1.1, 1.1, "shed-queue-full")); // w1
        t.request(req(2.2, 2.2, "shed-failed")); // w2
        t.seg(SegRecord {
            kind: SegKind::Compute,
            node: 0,
            lane: 0,
            start_s: 0.0,
            end_s: 1.5,
            req: 0,
            dram: 0.0,
        });
        let s = WindowedSeries::from_tracer(&t, 1.0, 1, 0);
        assert_eq!(s.windows, 3);
        assert_eq!(s.offered, vec![2, 1, 1]);
        assert_eq!(s.completed, vec![1, 1, 0]);
        assert_eq!(s.shed_queue_full, vec![0, 1, 0]);
        assert_eq!(s.shed_failed, vec![0, 0, 1]);
        let tot = s.totals();
        assert_eq!(tot.offered, 4);
        assert_eq!(tot.completed + tot.shed(), tot.offered);
        assert!((s.qps[0] - 1.0).abs() < 1e-12);
        // 100ms completion in w0; 500ms in w1
        assert!((s.p99_ms[0] - 100.0).abs() < 1e-9);
        assert!((s.p99_ms[1] - 500.0).abs() < 1e-9);
        assert!((s.card_util[0] - 1.0).abs() < 1e-12);
        assert!((s.card_util[1] - 0.5).abs() < 1e-12);
        assert_eq!(s.nic_util, vec![0.0, 0.0, 0.0]);
        // every series padded to the same length
        assert_eq!(s.p50_ms.len(), s.windows);
        assert_eq!(s.latency_ms.len(), s.windows);
    }

    #[test]
    fn window_feed_matches_series_schema() {
        let mut f = WindowFeed::new(0.25);
        for i in 0..8 {
            f.complete(i as f64 * 0.1, 0.005);
        }
        let s = f.finish();
        assert_eq!(s.totals().offered, 8);
        assert_eq!(s.totals().completed, 8);
        assert_eq!(s.windows, 3);
        assert!((s.p50_ms[0] - 5.0).abs() < 1e-9);
        let js = s.to_json();
        assert_eq!(js.get("windows").and_then(Json::as_usize), Some(3));
        assert_eq!(js.get("offered").and_then(Json::as_arr).map(|a| a.len()), Some(3));
    }
}
