//! Stage taxonomy and per-stage latency attribution.
//!
//! Every completed request's end-to-end latency decomposes into five
//! stages on the modeled clock:
//!
//! - `queue` — waiting for admission/dispatch (head-of-line blocking,
//!   NIC ingress queueing at the cluster tier). Computed as the residual
//!   `latency - (batch_wait + transfer + compute + network)`, clamped at
//!   zero, so the components always sum exactly to the reported latency.
//! - `batch_wait` — time parked in an open dynamic-batch window before
//!   the batch dispatched.
//! - `transfer` — PCIe link time on the request's critical path (the
//!   slowest SLS shard's transfer plus the dense segment's, for recsys).
//! - `compute` — card compute on the critical path, including any
//!   retroactive extension from late dynamic-batch joiners.
//! - `network` — NIC wire time (ingress + egress serialization); zero at
//!   the single-node fleet tier.

use crate::util::json::Json;
use crate::util::stats::{exact_quantile, QuantileSketch};

/// One stage of the request lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Queue,
    BatchWait,
    Transfer,
    Compute,
    Network,
}

impl Stage {
    /// All stages, in lifecycle order.
    pub const ALL: [Stage; 5] =
        [Stage::Queue, Stage::BatchWait, Stage::Transfer, Stage::Compute, Stage::Network];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::BatchWait => "batch_wait",
            Stage::Transfer => "transfer",
            Stage::Compute => "compute",
            Stage::Network => "network",
        }
    }

    fn index(self) -> usize {
        match self {
            Stage::Queue => 0,
            Stage::BatchWait => 1,
            Stage::Transfer => 2,
            Stage::Compute => 3,
            Stage::Network => 4,
        }
    }
}

/// Per-request stage decomposition in seconds. The invariant
/// [`StageBreakdown::attribute`] maintains: the five components sum to the
/// end-to-end latency (queue is the clamped residual).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageBreakdown {
    pub queue_s: f64,
    pub batch_wait_s: f64,
    pub transfer_s: f64,
    pub compute_s: f64,
    pub network_s: f64,
}

impl StageBreakdown {
    /// Build a breakdown from the modeled costs on the critical path,
    /// attributing whatever the explicit stages don't cover to queueing.
    pub fn attribute(
        latency_s: f64,
        batch_wait_s: f64,
        transfer_s: f64,
        compute_s: f64,
        network_s: f64,
    ) -> Self {
        let queue_s = (latency_s - batch_wait_s - transfer_s - compute_s - network_s).max(0.0);
        StageBreakdown { queue_s, batch_wait_s, transfer_s, compute_s, network_s }
    }

    pub fn get(&self, stage: Stage) -> f64 {
        match stage {
            Stage::Queue => self.queue_s,
            Stage::BatchWait => self.batch_wait_s,
            Stage::Transfer => self.transfer_s,
            Stage::Compute => self.compute_s,
            Stage::Network => self.network_s,
        }
    }

    /// Sum of all five stages — equals the end-to-end latency when built
    /// via [`StageBreakdown::attribute`] and the residual was non-negative.
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.batch_wait_s + self.transfer_s + self.compute_s + self.network_s
    }
}

/// Raw samples kept per stage before [`StageStats`] spills into a
/// [`QuantileSketch`]. Below the cap every statistic is exact (the bucketed
/// [`crate::util::stats::Histogram`] is too coarse for sub-millisecond
/// transfer stages); above it, memory stays `O(1/eps)` per stage however
/// long the run, at the cost of an `eps/2` rank error on the p99.
pub const STAGE_SAMPLE_CAP: usize = 8192;

/// Rank-error fraction of the spill sketches: p99 within ±0.1% rank.
const STAGE_SKETCH_EPS: f64 = 0.002;

/// Aggregated stage samples: exact mean, and a p99 that is exact up to
/// [`STAGE_SAMPLE_CAP`] requests and sketch-approximate beyond it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageStats {
    samples: [Vec<f64>; 5],
    /// Engaged once `count` passes [`STAGE_SAMPLE_CAP`]; raw samples are
    /// drained into it and later adds bypass `samples` entirely.
    spill: Option<Box<[QuantileSketch; 5]>>,
    count: usize,
    sums: [f64; 5],
}

impl StageStats {
    pub fn add(&mut self, b: &StageBreakdown) {
        self.count += 1;
        for stage in Stage::ALL {
            self.sums[stage.index()] += b.get(stage);
        }
        if let Some(spill) = &mut self.spill {
            for stage in Stage::ALL {
                spill[stage.index()].add(b.get(stage));
            }
        } else {
            for stage in Stage::ALL {
                self.samples[stage.index()].push(b.get(stage));
            }
            if self.count > STAGE_SAMPLE_CAP {
                self.spill_to_sketch();
            }
        }
    }

    pub fn merge(&mut self, other: &StageStats) {
        self.count += other.count;
        for i in 0..self.sums.len() {
            self.sums[i] += other.sums[i];
        }
        if self.spill.is_none() && other.spill.is_none() && self.count <= STAGE_SAMPLE_CAP {
            for (mine, theirs) in self.samples.iter_mut().zip(&other.samples) {
                mine.extend_from_slice(theirs);
            }
            return;
        }
        if self.spill.is_none() {
            self.spill_to_sketch();
        }
        let spill = self.spill.as_mut().expect("spilled above");
        if let Some(theirs) = &other.spill {
            for (sk, other_sk) in spill.iter_mut().zip(theirs.iter()) {
                sk.merge(other_sk);
            }
        } else {
            for (sk, xs) in spill.iter_mut().zip(&other.samples) {
                for &x in xs {
                    sk.add(x);
                }
            }
        }
    }

    fn spill_to_sketch(&mut self) {
        let mut sketches: Box<[QuantileSketch; 5]> =
            Box::new(std::array::from_fn(|_| QuantileSketch::new(STAGE_SKETCH_EPS)));
        for (sk, xs) in sketches.iter_mut().zip(self.samples.iter_mut()) {
            for &x in xs.iter() {
                sk.add(x);
            }
            xs.clear();
            xs.shrink_to_fit();
        }
        self.spill = Some(sketches);
    }

    /// Number of requests sampled.
    pub fn count(&self) -> usize {
        self.count
    }

    /// True once raw samples spilled into the sketch (p99 now approximate).
    pub fn capped(&self) -> bool {
        self.spill.is_some()
    }

    /// Raw samples + sketch items currently held — bounded by
    /// `STAGE_SAMPLE_CAP` per stage regardless of run length.
    pub fn footprint(&self) -> usize {
        self.samples.iter().map(Vec::len).sum::<usize>()
            + self.spill.as_ref().map_or(0, |sp| sp.iter().map(QuantileSketch::footprint).sum())
    }

    pub fn mean(&self, stage: Stage) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sums[stage.index()] / self.count as f64
        }
    }

    pub fn p99(&self, stage: Stage) -> f64 {
        match &self.spill {
            Some(spill) => spill[stage.index()].quantile(0.99),
            None => exact_quantile(&self.samples[stage.index()], 0.99),
        }
    }

    /// The stage with the largest mean — the regime label ("NIC-bound",
    /// "compute-bound", ...). `None` until a sample lands.
    pub fn dominant(&self) -> Option<Stage> {
        if self.count() == 0 {
            return None;
        }
        let mut best = Stage::Queue;
        for stage in Stage::ALL {
            if self.mean(stage) > self.mean(best) {
                best = stage;
            }
        }
        Some(best)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::with_capacity(6);
        pairs.push(("samples", Json::num(self.count() as f64)));
        for stage in Stage::ALL {
            pairs.push((
                stage.name(),
                Json::obj(vec![
                    ("mean_ms", Json::num(self.mean(stage) * 1e3)),
                    ("p99_ms", Json::num(self.p99(stage) * 1e3)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribute_clamps_residual_and_sums_to_latency() {
        let b = StageBreakdown::attribute(1.0, 0.1, 0.2, 0.3, 0.1);
        assert!((b.queue_s - 0.3).abs() < 1e-12);
        assert!((b.total_s() - 1.0).abs() < 1e-12);
        // over-attributed components clamp queue at zero, not negative
        let b = StageBreakdown::attribute(0.5, 0.2, 0.2, 0.2, 0.2);
        assert_eq!(b.queue_s, 0.0);
    }

    #[test]
    fn stats_mean_and_p99_are_exact() {
        let mut s = StageStats::default();
        for i in 1..=100 {
            s.add(&StageBreakdown {
                queue_s: i as f64,
                batch_wait_s: 0.0,
                transfer_s: 0.0,
                compute_s: 2.0 * i as f64,
                network_s: 0.0,
            });
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean(Stage::Queue) - 50.5).abs() < 1e-12);
        assert_eq!(s.p99(Stage::Queue), 99.0);
        assert_eq!(s.p99(Stage::Compute), 198.0);
        assert_eq!(s.dominant(), Some(Stage::Compute));
    }

    #[test]
    fn stats_merge_equals_combined() {
        let b1 = StageBreakdown::attribute(1.0, 0.0, 0.25, 0.5, 0.0);
        let b2 = StageBreakdown::attribute(2.0, 0.5, 0.25, 1.0, 0.0);
        let mut all = StageStats::default();
        all.add(&b1);
        all.add(&b2);
        let mut a = StageStats::default();
        a.add(&b1);
        let mut b = StageStats::default();
        b.add(&b2);
        a.merge(&b);
        assert_eq!(a, all);
    }

    #[test]
    fn stats_cap_bounds_memory_and_keeps_p99_close() {
        let n = 4 * STAGE_SAMPLE_CAP;
        let mut s = StageStats::default();
        for i in 0..n {
            // deterministic shuffle of 0..n, one distinct value per request
            let v = ((i * 104_729) % n) as f64;
            s.add(&StageBreakdown { queue_s: v, ..StageBreakdown::default() });
        }
        assert!(s.capped());
        assert_eq!(s.count(), n);
        assert!(s.footprint() <= 5 * STAGE_SAMPLE_CAP, "footprint {}", s.footprint());
        // mean stays exact (running sum), p99 within the sketch rank bound
        let exact_mean = (n - 1) as f64 / 2.0;
        assert!((s.mean(Stage::Queue) - exact_mean).abs() < 1e-9);
        let p99 = s.p99(Stage::Queue);
        let target = (0.99 * n as f64).ceil();
        assert!((p99 - target).abs() <= 0.002 * n as f64, "p99 {p99} vs {target}");
        // merging a raw-sample batch into a capped one routes via the sketch
        let mut extra = StageStats::default();
        for _ in 0..10 {
            extra.add(&StageBreakdown { queue_s: 1e9, ..StageBreakdown::default() });
        }
        s.merge(&extra);
        assert_eq!(s.count(), n + 10);
        assert_eq!(s.p99(Stage::Compute), 0.0);
    }

    #[test]
    fn empty_stats_are_inert() {
        let s = StageStats::default();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(Stage::Network), 0.0);
        assert_eq!(s.p99(Stage::Network), 0.0);
        assert_eq!(s.dominant(), None);
    }
}
