//! Hardware description of the accelerator node (§III).
//!
//! One node = host CPU (Xeon-D, 64 GB) + PCIe switch + six M.2 accelerator
//! cards. Per card: Accel Cores with local SRAM, a shared cache, 16 GB
//! LPDDR; 30–45 TOPS int8 / 4–6 TFLOPS fp16 at 13 W. The switch gives
//! card↔card peer-to-peer without touching the host (§III-A).

pub mod topology;

/// One accelerator card (§III-B, Figure 4).
#[derive(Debug, Clone, PartialEq)]
pub struct CardSpec {
    /// Number of Accel Cores.
    pub accel_cores: usize,
    /// Peak int8 tera-ops/sec across all cores (30–45 depending on freq).
    pub peak_tops_int8: f64,
    /// Peak fp16 tera-flops/sec (4–6).
    pub peak_tflops_fp16: f64,
    /// LPDDR capacity, bytes (16 GB).
    pub lpddr_bytes: usize,
    /// LPDDR bandwidth, bytes/sec.
    pub lpddr_bw: f64,
    /// Per-core local SRAM, bytes.
    pub sram_per_core: usize,
    /// Shared on-chip cache, bytes.
    pub shared_cache: usize,
    /// On-chip (SRAM) bandwidth, bytes/sec.
    pub sram_bw: f64,
    /// Card power, watts.
    pub power_w: f64,
    /// PCIe lanes to the switch (x4).
    pub pcie_lanes: usize,
}

impl Default for CardSpec {
    fn default() -> Self {
        CardSpec {
            accel_cores: 12,
            peak_tops_int8: 37.5,        // midpoint of 30-45
            peak_tflops_fp16: 5.0,       // midpoint of 4-6
            lpddr_bytes: 16 << 30,
            lpddr_bw: 60e9,              // LPDDR4x-class aggregate
            sram_per_core: 2 << 20,
            shared_cache: 24 << 20,
            sram_bw: 400e9,
            power_w: 13.0,
            pcie_lanes: 4,
        }
    }
}

impl CardSpec {
    /// Peak compute for a precision class, ops/sec.
    pub fn peak_ops(&self, int8: bool) -> f64 {
        if int8 {
            self.peak_tops_int8 * 1e12
        } else {
            self.peak_tflops_fp16 * 1e12
        }
    }

    /// Total on-chip memory usable for weights (§III-B).
    pub fn onchip_bytes(&self) -> usize {
        self.accel_cores * self.sram_per_core + self.shared_cache
    }
}

/// Host CPU (§III-A: Intel Xeon D, 64 GB).
#[derive(Debug, Clone, PartialEq)]
pub struct HostSpec {
    pub cores: usize,
    pub mem_bytes: usize,
    pub mem_bw: f64,
    /// Sustained host GFLOPs for the net portions kept on CPU (§VI-A).
    pub gflops: f64,
}

impl Default for HostSpec {
    fn default() -> Self {
        HostSpec {
            cores: 16,
            mem_bytes: 64 << 30,
            mem_bw: 80e9,
            gflops: 600.0,
        }
    }
}

/// PCIe fabric (§III-A): x4 per card to the switch, x16 switch to host.
#[derive(Debug, Clone, PartialEq)]
pub struct PcieSpec {
    /// Effective bytes/sec per lane (PCIe gen3 ~0.985 GB/s).
    pub lane_bw: f64,
    pub host_lanes: usize,
    pub switch_power_w: f64,
    /// Per-transfer fixed latency (doorbell + DMA setup), seconds.
    pub transfer_overhead_s: f64,
}

impl Default for PcieSpec {
    fn default() -> Self {
        PcieSpec {
            lane_bw: 0.985e9,
            host_lanes: 16,
            switch_power_w: 13.0,
            transfer_overhead_s: 6e-6,
        }
    }
}

/// NIC (§III-A: upgraded 50 Gbps multi-host NIC).
#[derive(Debug, Clone, PartialEq)]
pub struct NicSpec {
    pub bw_bits: f64,
}

impl Default for NicSpec {
    fn default() -> Self {
        NicSpec { bw_bits: 50e9 }
    }
}

/// The whole node.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub cards: usize,
    /// The base card every slot carries unless overridden below.
    pub card: CardSpec,
    /// Per-slot card overrides — a *vendor-mix* node (the paper's platform
    /// was "open to enable a variety of AI accelerators from different
    /// vendors", §I). `(card index, spec)`; slots not listed use `card`.
    pub card_overrides: Vec<(usize, CardSpec)>,
    pub host: HostSpec,
    pub pcie: PcieSpec,
    pub nic: NicSpec,
}

impl Default for NodeSpec {
    fn default() -> Self {
        NodeSpec {
            cards: 6,
            card: CardSpec::default(),
            card_overrides: Vec::new(),
            host: HostSpec::default(),
            pcie: PcieSpec::default(),
            nic: NicSpec::default(),
        }
    }
}

impl NodeSpec {
    /// The spec of one card slot: the override when the vendor-mix table
    /// names it, the node's base card otherwise.
    pub fn card_spec(&self, id: usize) -> &CardSpec {
        self.card_overrides
            .iter()
            .find(|(i, _)| *i == id)
            .map(|(_, c)| c)
            .unwrap_or(&self.card)
    }

    /// Aggregate peak int8 TOPS (paper: 180–270 on the homogeneous node).
    pub fn total_tops_int8(&self) -> f64 {
        (0..self.cards).map(|i| self.card_spec(i).peak_tops_int8).sum()
    }

    /// Aggregate peak fp16 TFLOPS (paper: 24–36).
    pub fn total_tflops_fp16(&self) -> f64 {
        (0..self.cards).map(|i| self.card_spec(i).peak_tflops_fp16).sum()
    }

    /// Total accelerator LPDDR (paper: 96 GB).
    pub fn total_lpddr(&self) -> usize {
        (0..self.cards).map(|i| self.card_spec(i).lpddr_bytes).sum()
    }

    /// Memory visible to a model: cards + host (paper: "about 160 GB").
    pub fn total_memory(&self) -> usize {
        self.total_lpddr() + self.host.mem_bytes
    }

    /// Accelerator subsystem power: cards + switch (paper: 91 W).
    pub fn accel_power_w(&self) -> f64 {
        (0..self.cards).map(|i| self.card_spec(i).power_w).sum::<f64>()
            + self.pcie.switch_power_w
    }

    /// Peak efficiency, TOPS/W (paper: 2.0–3.0).
    pub fn tops_per_watt(&self) -> f64 {
        self.total_tops_int8() / self.accel_power_w()
    }

    /// PCIe bandwidth card<->switch, bytes/sec.
    pub fn card_link_bw(&self) -> f64 {
        self.card.pcie_lanes as f64 * self.pcie.lane_bw
    }

    /// PCIe bandwidth switch<->host, bytes/sec.
    pub fn host_link_bw(&self) -> f64 {
        self.pcie.host_lanes as f64 * self.pcie.lane_bw
    }
}

/// A datacenter serving tier: N whole nodes behind a node-level router
/// (Fig. 1 sizes exactly this — how many servers a demand curve needs).
///
/// Nodes may be heterogeneous (a vendor-mix *fleet*, not just vendor-mix
/// cards within one node): each entry carries its own card count, card
/// overrides and NIC. `headroom` is the failure margin the capacity
/// planner adds on top of the load-driven node count, so the tier still
/// meets its SLA with that many nodes down (§VII's operational lesson).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
    /// Extra nodes beyond the load-driven count — must be smaller than the
    /// node count (a tier that is all headroom serves nothing).
    pub headroom: usize,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec::uniform(3, NodeSpec::default(), 1)
    }
}

impl ClusterSpec {
    /// `n` identical nodes plus `headroom` failure margin.
    pub fn uniform(n: usize, node: NodeSpec, headroom: usize) -> ClusterSpec {
        ClusterSpec { nodes: vec![node; n.max(1)], headroom }
    }

    /// Aggregate NIC line rate, bits/sec — the tier's ingress ceiling.
    pub fn total_nic_bw_bits(&self) -> f64 {
        self.nodes.iter().map(|n| n.nic.bw_bits).sum()
    }

    /// Aggregate peak int8 TOPS across all nodes.
    pub fn total_tops_int8(&self) -> f64 {
        self.nodes.iter().map(NodeSpec::total_tops_int8).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_spec_aggregates() {
        let c = ClusterSpec::default();
        assert_eq!(c.nodes.len(), 3);
        assert_eq!(c.headroom, 1);
        assert!((c.total_nic_bw_bits() - 3.0 * 50e9).abs() < 1e-3);
        assert!((c.total_tops_int8() - 3.0 * NodeSpec::default().total_tops_int8()).abs() < 1e-9);
        // heterogeneous tiers aggregate per node
        let mut small = NodeSpec { cards: 2, ..NodeSpec::default() };
        small.nic.bw_bits = 25e9;
        let mixed = ClusterSpec { nodes: vec![NodeSpec::default(), small], headroom: 0 };
        assert!((mixed.total_nic_bw_bits() - 75e9).abs() < 1e-3);
        // uniform clamps a zero count to one node
        assert_eq!(ClusterSpec::uniform(0, NodeSpec::default(), 0).nodes.len(), 1);
    }

    #[test]
    fn paper_headline_numbers() {
        let n = NodeSpec::default();
        // §I: 180-270 TOPS int8, 24-36 TFLOPS fp16, 96 GB, 91 W, 2.0-3.0 TOPS/W
        assert!(n.total_tops_int8() >= 180.0 && n.total_tops_int8() <= 270.0);
        assert!(n.total_tflops_fp16() >= 24.0 && n.total_tflops_fp16() <= 36.0);
        assert_eq!(n.total_lpddr(), 96 << 30);
        assert!((n.accel_power_w() - 91.0).abs() < 1e-9);
        let eff = n.tops_per_watt();
        assert!(eff >= 2.0 && eff <= 3.0, "{eff}");
    }

    #[test]
    fn total_memory_about_160gb() {
        let n = NodeSpec::default();
        let gb = n.total_memory() as f64 / (1u64 << 30) as f64;
        assert!((gb - 160.0).abs() < 1.0, "{gb}");
    }

    #[test]
    fn link_bandwidths() {
        let n = NodeSpec::default();
        assert!(n.card_link_bw() < n.host_link_bw());
        // x4 gen3 ~ 3.9 GB/s
        assert!((n.card_link_bw() - 3.94e9).abs() / 3.94e9 < 0.01);
    }

    #[test]
    fn card_overrides_build_a_vendor_mix_node() {
        let mut n = NodeSpec::default();
        let slow = CardSpec { peak_tops_int8: 15.0, power_w: 7.0, ..CardSpec::default() };
        n.card_overrides.push((2, slow));
        assert_eq!(n.card_spec(0).peak_tops_int8, 37.5);
        assert_eq!(n.card_spec(2).peak_tops_int8, 15.0);
        // aggregates account for the mixed slot
        assert!((n.total_tops_int8() - (5.0 * 37.5 + 15.0)).abs() < 1e-9);
        assert!((n.accel_power_w() - (5.0 * 13.0 + 7.0 + 13.0)).abs() < 1e-9);
    }

    #[test]
    fn onchip_memory_tens_of_mb() {
        // §III-B: weights of tens of MB should fit on-chip
        let c = CardSpec::default();
        let mb = c.onchip_bytes() as f64 / (1 << 20) as f64;
        assert!(mb >= 30.0 && mb <= 100.0, "{mb}");
    }
}
