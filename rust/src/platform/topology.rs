//! Node topology: who talks to whom over which link (§III-A).
//!
//! Transfers are routed host↔card (via switch + host x16 link) or card↔card
//! peer-to-peer (switch only — the §VI-C optimization that halves PCIe
//! traffic for the recsys partitioning scheme).

use super::NodeSpec;

/// Endpoints in the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Endpoint {
    Host,
    Card(usize),
}

/// A route between endpoints: the set of links a transfer occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// host x16 link + one card x4 link.
    HostCard { card: usize },
    /// two card x4 links through the switch, host uninvolved.
    PeerToPeer { from: usize, to: usize },
    /// same device; free.
    Local,
}

impl Route {
    pub fn between(a: Endpoint, b: Endpoint) -> Route {
        match (a, b) {
            (Endpoint::Host, Endpoint::Card(c)) | (Endpoint::Card(c), Endpoint::Host) => {
                Route::HostCard { card: c }
            }
            (Endpoint::Card(x), Endpoint::Card(y)) if x != y => {
                Route::PeerToPeer { from: x, to: y }
            }
            _ => Route::Local,
        }
    }

    /// Bottleneck bandwidth of the route, bytes/sec.
    pub fn bandwidth(&self, node: &NodeSpec) -> f64 {
        match self {
            Route::HostCard { .. } => node.card_link_bw().min(node.host_link_bw()),
            Route::PeerToPeer { .. } => node.card_link_bw(),
            Route::Local => f64::INFINITY,
        }
    }

    /// Ideal (uncontended) transfer time for `bytes`.
    pub fn transfer_time(&self, node: &NodeSpec, bytes: usize) -> f64 {
        match self {
            Route::Local => 0.0,
            _ => node.pcie.transfer_overhead_s + bytes as f64 / self.bandwidth(node),
        }
    }

    /// Links occupied, as (card link ids, uses host link). The switch is
    /// non-blocking; only the x4 card links and x16 host link contend.
    pub fn links(&self) -> (Vec<usize>, bool) {
        match self {
            Route::HostCard { card } => (vec![*card], true),
            Route::PeerToPeer { from, to } => (vec![*from, *to], false),
            Route::Local => (vec![], false),
        }
    }
}

/// Host-mediated equivalent of a card↔card transfer — what the system did
/// *before* the P2P optimization of §VI-C: card→host then host→card, two
/// traversals of the host link.
pub fn host_mediated_time(node: &NodeSpec, bytes: usize) -> f64 {
    let up = Route::HostCard { card: 0 }.transfer_time(node, bytes);
    let down = Route::HostCard { card: 1 }.transfer_time(node, bytes);
    up + down
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_rules() {
        assert_eq!(
            Route::between(Endpoint::Host, Endpoint::Card(2)),
            Route::HostCard { card: 2 }
        );
        assert_eq!(
            Route::between(Endpoint::Card(1), Endpoint::Card(3)),
            Route::PeerToPeer { from: 1, to: 3 }
        );
        assert_eq!(Route::between(Endpoint::Card(1), Endpoint::Card(1)), Route::Local);
        assert_eq!(Route::between(Endpoint::Host, Endpoint::Host), Route::Local);
    }

    #[test]
    fn p2p_beats_host_mediated() {
        let node = NodeSpec::default();
        let bytes = 1 << 20;
        let p2p = Route::PeerToPeer { from: 0, to: 1 }.transfer_time(&node, bytes);
        let via_host = host_mediated_time(&node, bytes);
        assert!(via_host > 1.9 * p2p, "p2p {p2p} via_host {via_host}");
    }

    #[test]
    fn local_is_free() {
        let node = NodeSpec::default();
        assert_eq!(Route::Local.transfer_time(&node, 123456), 0.0);
    }

    #[test]
    fn links_accounting() {
        let (cards, host) = Route::HostCard { card: 4 }.links();
        assert_eq!(cards, vec![4]);
        assert!(host);
        let (cards, host) = Route::PeerToPeer { from: 0, to: 5 }.links();
        assert_eq!(cards, vec![0, 5]);
        assert!(!host);
    }
}
