//! Per-op shape & dtype inference over [`Graph`] (lint layer 2).
//!
//! Every [`OpKind`] gets a rule that re-derives the output tensor from the
//! inputs and op attributes and compares it against what the graph
//! declares — the legality checking Glow does at compile time (§V). Rules
//! are calibrated against the Table I builders in [`crate::graph::models`]:
//! all seven builtin models must lint clean (a CI gate), so a rule is only
//! as strict as the layouts those builders actually produce (e.g. pooling
//! windows may overlap, so pooled spatial dims are checked as `<=` rather
//! than recomputed; `Transpose` doubles as a reshape, so it checks element
//! count, not a permutation).
//!
//! Host-only ops (`RoiAlign`, `NonMaxSuppression`) are opaque: the paper
//! runs proposal generation on the host CPU (§VI-A) and their output
//! shapes are data-dependent, so nothing is inferred for them.

use super::{Diagnostic, Report, RuleId, Span};
use crate::graph::ops::OpKind;
use crate::graph::{DType, Graph, GraphError, Node, TensorId, TensorKind};

/// Run the structural + per-op + graph-level passes, collecting (never
/// fail-fast) every finding.
pub fn lint_graph(g: &Graph) -> Report {
    let mut r = Report::new();

    // --- structural: dangling ids first, so later passes can index safely
    let mut dangling = vec![false; g.nodes.len()];
    for (ni, n) in g.nodes.iter().enumerate() {
        for &t in n.inputs.iter().chain(n.outputs.iter()) {
            if t >= g.tensors.len() {
                dangling[ni] = true;
                r.push(
                    Diagnostic::new(
                        RuleId::StructuralInvalid,
                        node_span(g, n),
                        format!(
                            "references dangling tensor id {t} (graph has {} tensors)",
                            g.tensors.len()
                        ),
                    )
                    .suggest("add the tensor with Graph::add_tensor before wiring the node"),
                );
            }
        }
    }
    let any_dangling = dangling.iter().any(|&d| d);

    // remaining structural invariants (cycle, multiple producers, write to
    // constant) — Graph::validate's own dangling check would fire first,
    // so only consult it once ids are known to be in range
    if !any_dangling {
        if let Err(e) = g.validate() {
            let span = match &e {
                GraphError::DanglingTensor { node, .. } | GraphError::WriteToConstant { node, .. } => {
                    node_span(g, g.node(*node))
                }
                GraphError::MultipleProducers { tensor } => tensor_span(g, *tensor),
                GraphError::Cycle => Span::Model { model: g.name.clone() },
            };
            r.push(Diagnostic::new(RuleId::StructuralInvalid, span, e.to_string()));
        }
    }

    // zero-sized dims are never legal and would poison element-count math
    for t in &g.tensors {
        if t.shape.0.iter().any(|&d| d == 0) {
            r.push(Diagnostic::new(
                RuleId::ShapeMismatch,
                tensor_span(g, t.id),
                format!("shape {:?} has a zero-sized dimension", t.shape.0),
            ));
        }
    }

    // --- per-op inference
    for (ni, n) in g.nodes.iter().enumerate() {
        if !dangling[ni] {
            check_node(g, n, &mut r);
        }
    }

    // --- graph-level passes (need producers/consumers; unsafe with
    // out-of-range ids)
    if !any_dangling {
        let consumers = g.consumers();
        for t in &g.tensors {
            if t.kind == TensorKind::Activation && consumers[t.id].is_empty() {
                r.push(
                    Diagnostic::new(
                        RuleId::UnconsumedIntermediate,
                        tensor_span(g, t.id),
                        "activation is produced but never consumed",
                    )
                    .suggest("drop the dead tensor, or mark it TensorKind::Output if it is a result"),
                );
            }
        }
        // reverse reachability from the Output tensors; a graph with no
        // Output tensors has no anchor, so the pass is skipped
        let outputs: Vec<TensorId> =
            g.tensors.iter().filter(|t| t.kind == TensorKind::Output).map(|t| t.id).collect();
        if !outputs.is_empty() {
            let producers = g.producers();
            let mut live_t = vec![false; g.tensors.len()];
            let mut live_n = vec![false; g.nodes.len()];
            let mut work = outputs;
            for &t in &work {
                live_t[t] = true;
            }
            while let Some(t) = work.pop() {
                if let Some(ni) = producers[t] {
                    if !live_n[ni] {
                        live_n[ni] = true;
                        for &i in &g.nodes[ni].inputs {
                            if !live_t[i] {
                                live_t[i] = true;
                                work.push(i);
                            }
                        }
                    }
                }
            }
            for (ni, n) in g.nodes.iter().enumerate() {
                if !live_n[ni] {
                    r.push(
                        Diagnostic::new(
                            RuleId::UnreachableNode,
                            node_span(g, n),
                            "no path from this node to any Output tensor",
                        )
                        .suggest("remove the dead subgraph or wire its result into an output"),
                    );
                }
            }
        }
    }
    r
}

fn node_span(g: &Graph, n: &Node) -> Span {
    Span::Node { graph: g.name.clone(), node: n.id, name: n.name.clone() }
}

fn tensor_span(g: &Graph, t: TensorId) -> Span {
    Span::Tensor { graph: g.name.clone(), tensor: t, name: g.tensor(t).name.clone() }
}

fn diag(g: &Graph, n: &Node, rule: RuleId, msg: String) -> Diagnostic {
    Diagnostic::new(rule, node_span(g, n), msg)
}

fn is_float(dt: DType) -> bool {
    matches!(dt, DType::F32 | DType::F16 | DType::Bf16)
}

fn is_int(dt: DType) -> bool {
    matches!(dt, DType::I8 | DType::I4)
}

/// Arity gate: wrong input/output counts get one diagnostic and skip the
/// shape rules (which would index out of the io lists).
fn arity_ok(g: &Graph, n: &Node, r: &mut Report, ins: usize) -> bool {
    if n.inputs.len() != ins || n.outputs.len() != 1 {
        r.push(diag(
            g,
            n,
            RuleId::ArityMismatch,
            format!(
                "{} expects {ins} input(s) and 1 output, got {} and {}",
                n.kind.table_name(),
                n.inputs.len(),
                n.outputs.len()
            ),
        ));
        return false;
    }
    true
}

/// Compare a declared tensor against the inferred shape.
fn expect_shape(g: &Graph, n: &Node, r: &mut Report, declared: TensorId, want: &[usize]) {
    let t = g.tensor(declared);
    if t.shape.0 != want {
        r.push(
            diag(
                g,
                n,
                RuleId::ShapeMismatch,
                format!("declared '{}' shape {:?} but inferred {:?}", t.name, t.shape.0, want),
            )
            .suggest("fix the declared tensor shape or the op attributes"),
        );
    }
}

fn expect_float_out(g: &Graph, n: &Node, r: &mut Report, out: TensorId) {
    let t = g.tensor(out);
    if !is_float(t.dtype) {
        r.push(diag(
            g,
            n,
            RuleId::DtypeMismatch,
            format!(
                "{} output '{}' must be floating point, got {}",
                n.kind.table_name(),
                t.name,
                t.dtype.name()
            ),
        ));
    }
}

/// Elementwise/same-layout rule: one input, output mirrors its shape and
/// dtype exactly.
fn same_shape_unary(g: &Graph, n: &Node, r: &mut Report) {
    if !arity_ok(g, n, r, 1) {
        return;
    }
    let x = g.tensor(n.inputs[0]);
    let want = x.shape.0.clone();
    expect_shape(g, n, r, n.outputs[0], &want);
    let y = g.tensor(n.outputs[0]);
    if y.dtype != x.dtype {
        r.push(diag(
            g,
            n,
            RuleId::DtypeMismatch,
            format!(
                "{} output dtype {} disagrees with input dtype {}",
                n.kind.table_name(),
                y.dtype.name(),
                x.dtype.name()
            ),
        ));
    }
}

#[allow(clippy::too_many_lines)]
fn check_node(g: &Graph, n: &Node, r: &mut Report) {
    // host ops run on the CPU (§VI-A); their output shapes are
    // data-dependent (NMS keeps a variable proposal set) — opaque here
    if n.kind.host_only() {
        return;
    }
    match n.kind {
        OpKind::Fc | OpKind::QuantizedFc => {
            if !arity_ok(g, n, r, 3) {
                return;
            }
            let (x, w, b) =
                (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]), g.tensor(n.inputs[2]));
            if x.shape.rank() != 2 || w.shape.rank() != 2 || b.shape.rank() != 1 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "FC expects x [m,k], w [out,k], b [out]; got ranks {}/{}/{}",
                        x.shape.rank(),
                        w.shape.rank(),
                        b.shape.rank()
                    ),
                ));
                return;
            }
            if w.shape.dim(1) != x.shape.dim(1) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "reduction dim mismatch: x {:?} vs w {:?} (w must be [out, k])",
                        x.shape.0, w.shape.0
                    ),
                ));
            }
            if b.shape.dim(0) != w.shape.dim(0) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("bias {:?} disagrees with w out dim {}", b.shape.0, w.shape.dim(0)),
                ));
            }
            expect_shape(g, n, r, n.outputs[0], &[x.shape.dim(0), w.shape.dim(0)]);
            if n.kind == OpKind::QuantizedFc && w.dtype != DType::I8 {
                r.push(
                    diag(
                        g,
                        n,
                        RuleId::DtypeMismatch,
                        format!("quantized FC weight '{}' must be int8, got {}", w.name, w.dtype.name()),
                    )
                    .suggest("quantize the weight or use OpKind::Fc"),
                );
            }
            if n.kind == OpKind::Fc && !is_float(w.dtype) {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("FC weight '{}' must be floating point, got {}", w.name, w.dtype.name()),
                ));
            }
            expect_float_out(g, n, r, n.outputs[0]);
        }
        OpKind::SparseLengthsSum { .. } | OpKind::SparseLengthsSumSingle => {
            if !arity_ok(g, n, r, 3) {
                return;
            }
            let (tab, idx, len) =
                (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]), g.tensor(n.inputs[2]));
            if tab.shape.rank() != 2 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("embedding table '{}' must be rank-2 (rows, dim), got {:?}", tab.name, tab.shape.0),
                ));
                return;
            }
            if tab.kind != TensorKind::Weight {
                r.push(diag(
                    g,
                    n,
                    RuleId::StructuralInvalid,
                    format!("embedding table '{}' must be a Weight tensor", tab.name),
                ));
            }
            for (what, t) in [("indices", idx), ("lengths", len)] {
                if t.dtype != DType::I32 {
                    r.push(diag(
                        g,
                        n,
                        RuleId::DtypeMismatch,
                        format!("SLS {what} '{}' must be int32, got {}", t.name, t.dtype.name()),
                    ));
                }
            }
            if len.shape.rank() != 1 || !(1..=2).contains(&idx.shape.rank()) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "SLS expects indices [batch, lookups] and lengths [batch]; got {:?} and {:?}",
                        idx.shape.0, len.shape.0
                    ),
                ));
                return;
            }
            let batch = len.shape.dim(0);
            if idx.shape.dim(0) != batch {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("indices batch dim {} disagrees with lengths {:?}", idx.shape.dim(0), len.shape.0),
                ));
            }
            expect_shape(g, n, r, n.outputs[0], &[batch, tab.shape.dim(1)]);
            expect_float_out(g, n, r, n.outputs[0]);
        }
        OpKind::MatMul => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            let (x, w) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            if x.shape.rank() != 2 || w.shape.rank() != 2 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("MatMul expects rank-2 operands, got {:?} and {:?}", x.shape.0, w.shape.0),
                ));
                return;
            }
            if w.shape.dim(1) != x.shape.dim(1) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "reduction dim mismatch: x {:?} vs w {:?} (w is stored [rows, k])",
                        x.shape.0, w.shape.0
                    ),
                ));
            }
            expect_shape(g, n, r, n.outputs[0], &[x.shape.dim(0), w.shape.dim(0)]);
            expect_float_out(g, n, r, n.outputs[0]);
        }
        OpKind::BatchMatMul => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            let (a, b) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            if a.shape.rank() != 3 || b.shape.rank() != 3 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("BatchMatMul expects rank-3 operands, got {:?} and {:?}", a.shape.0, b.shape.0),
                ));
                return;
            }
            if b.shape.dim(0) != a.shape.dim(0) || b.shape.dim(1) != a.shape.dim(2) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "operands {:?} x {:?} do not contract as [b,m,k] x [b,k,n]",
                        a.shape.0, b.shape.0
                    ),
                ));
                return;
            }
            expect_shape(
                g,
                n,
                r,
                n.outputs[0],
                &[a.shape.dim(0), a.shape.dim(1), b.shape.dim(2)],
            );
            expect_float_out(g, n, r, n.outputs[0]);
        }
        OpKind::Conv { groups, stride, kh, kw, quantized }
        | OpKind::ConvAddFused { groups, stride, kh, kw, quantized } => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            if groups == 0 || stride == 0 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("conv groups ({groups}) and stride ({stride}) must be >= 1"),
                ));
                return;
            }
            let (x, w) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            if x.shape.rank() != 4 || w.shape.rank() != 4 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "conv expects NHWC x and [kh,kw,cin/g,cout] w; got ranks {} and {}",
                        x.shape.rank(),
                        w.shape.rank()
                    ),
                ));
                return;
            }
            if w.shape.dim(0) != kh || w.shape.dim(1) != kw {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("weight {:?} disagrees with kernel attrs {kh}x{kw}", w.shape.0),
                ));
            }
            if w.shape.dim(2) * groups != x.shape.dim(3) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "weight channel dim {} x groups {groups} != input channels {}",
                        w.shape.dim(2),
                        x.shape.dim(3)
                    ),
                ));
            }
            expect_shape(
                g,
                n,
                r,
                n.outputs[0],
                &[
                    x.shape.dim(0),
                    x.shape.dim(1).div_ceil(stride),
                    x.shape.dim(2).div_ceil(stride),
                    w.shape.dim(3),
                ],
            );
            if quantized && w.dtype != DType::I8 {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("quantized conv weight '{}' must be int8, got {}", w.name, w.dtype.name()),
                ));
            }
            if !quantized && !is_float(w.dtype) {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("conv weight '{}' must be floating point, got {}", w.name, w.dtype.name()),
                ));
            }
            expect_float_out(g, n, r, n.outputs[0]);
        }
        OpKind::Conv3D { groups, kt, kh, kw } => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            if groups == 0 {
                r.push(diag(g, n, RuleId::ShapeMismatch, "conv3d groups must be >= 1".into()));
                return;
            }
            let (x, w) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            if x.shape.rank() != 5 || w.shape.rank() != 5 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "conv3d expects [n,f,h,w,c] x and [kt,kh,kw,cin/g,cout] w; got ranks {} and {}",
                        x.shape.rank(),
                        w.shape.rank()
                    ),
                ));
                return;
            }
            if w.shape.dim(0) != kt || w.shape.dim(1) != kh || w.shape.dim(2) != kw {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("weight {:?} disagrees with kernel attrs {kt}x{kh}x{kw}", w.shape.0),
                ));
            }
            if w.shape.dim(3) * groups != x.shape.dim(4) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "weight channel dim {} x groups {groups} != input channels {}",
                        w.shape.dim(3),
                        x.shape.dim(4)
                    ),
                ));
            }
            let y = g.tensor(n.outputs[0]);
            if y.shape.rank() != 5 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("conv3d output must be rank-5, got {:?}", y.shape.0),
                ));
                return;
            }
            if y.shape.dim(0) != x.shape.dim(0)
                || y.shape.dim(1) != x.shape.dim(1)
                || y.shape.dim(4) != w.shape.dim(4)
            {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "output {:?} must keep batch/frames {:?} and take {} channels from the weight",
                        y.shape.0,
                        &x.shape.0[..2],
                        w.shape.dim(4)
                    ),
                ));
            }
            if y.shape.dim(2) > x.shape.dim(2) || y.shape.dim(3) > x.shape.dim(3) {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("output spatial dims {:?} exceed input {:?}", y.shape.0, x.shape.0),
                ));
            }
            expect_float_out(g, n, r, n.outputs[0]);
        }
        OpKind::Add => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            let (a, b) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            if a.shape != b.shape {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("Add operands disagree: {:?} vs {:?}", a.shape.0, b.shape.0),
                ));
            }
            let want = a.shape.0.clone();
            expect_shape(g, n, r, n.outputs[0], &want);
            let y = g.tensor(n.outputs[0]);
            if y.dtype != a.dtype || a.dtype != b.dtype {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!(
                        "Add dtypes disagree: {} + {} -> {}",
                        a.dtype.name(),
                        b.dtype.name(),
                        y.dtype.name()
                    ),
                ));
            }
        }
        OpKind::Mul => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            let (a, b) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            // either elementwise, or the SE channel-gate broadcast:
            // [n, ..., c] * [n, c]
            let broadcast = b.shape.rank() == 2
                && a.shape.rank() >= 2
                && b.shape.dim(0) == a.shape.dim(0)
                && b.shape.dim(1) == a.shape.dim(a.shape.rank() - 1);
            if a.shape != b.shape && !broadcast {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "Mul operands {:?} x {:?} are neither elementwise nor a [n,c] channel gate",
                        a.shape.0, b.shape.0
                    ),
                ));
            }
            let want = a.shape.0.clone();
            expect_shape(g, n, r, n.outputs[0], &want);
            let y = g.tensor(n.outputs[0]);
            if y.dtype != a.dtype {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("Mul output dtype {} disagrees with input {}", y.dtype.name(), a.dtype.name()),
                ));
            }
        }
        OpKind::Concat => {
            if n.inputs.is_empty() || n.outputs.len() != 1 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ArityMismatch,
                    format!(
                        "Concat expects >=1 inputs and 1 output, got {} and {}",
                        n.inputs.len(),
                        n.outputs.len()
                    ),
                ));
                return;
            }
            let y = g.tensor(n.outputs[0]);
            let mut supply = 0usize;
            for &i in &n.inputs {
                let t = g.tensor(i);
                supply += t.shape.elements();
                if t.dtype != y.dtype {
                    r.push(diag(
                        g,
                        n,
                        RuleId::DtypeMismatch,
                        format!(
                            "Concat input '{}' dtype {} disagrees with output {}",
                            t.name,
                            t.dtype.name(),
                            y.dtype.name()
                        ),
                    ));
                }
            }
            // builders use Concat both to stack and to slice-and-pack
            // (DLRM's interaction concat, XLM-R's pool), so the output may
            // keep fewer elements than the inputs supply — never more
            if y.shape.elements() > supply {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "Concat output {:?} ({} elements) exceeds the {} elements its inputs supply",
                        y.shape.0,
                        y.shape.elements(),
                        supply
                    ),
                ));
            }
        }
        OpKind::Transpose | OpKind::Softmax => {
            if !arity_ok(g, n, r, 1) {
                return;
            }
            let (x, y) = (g.tensor(n.inputs[0]), g.tensor(n.outputs[0]));
            if x.shape.elements() != y.shape.elements() {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "{} must preserve element count: {:?} -> {:?}",
                        n.kind.table_name(),
                        x.shape.0,
                        y.shape.0
                    ),
                ));
            }
            if x.dtype != y.dtype {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!(
                        "{} must preserve dtype: {} -> {}",
                        n.kind.table_name(),
                        x.dtype.name(),
                        y.dtype.name()
                    ),
                ));
            }
        }
        OpKind::Tile => {
            if !arity_ok(g, n, r, 1) {
                return;
            }
            let (x, y) = (g.tensor(n.inputs[0]), g.tensor(n.outputs[0]));
            let (xe, ye) = (x.shape.elements(), y.shape.elements());
            if xe == 0 {
                return; // zero-dim already reported
            }
            if ye < xe || ye % xe != 0 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("Tile output {:?} is not a whole multiple of input {:?}", y.shape.0, x.shape.0),
                ));
            }
            if x.dtype != y.dtype {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("Tile must preserve dtype: {} -> {}", x.dtype.name(), y.dtype.name()),
                ));
            }
        }
        OpKind::Quantize => {
            if !arity_ok(g, n, r, 1) {
                return;
            }
            let (x, y) = (g.tensor(n.inputs[0]), g.tensor(n.outputs[0]));
            let want = x.shape.0.clone();
            expect_shape(g, n, r, n.outputs[0], &want);
            if !is_float(x.dtype) || !is_int(y.dtype) {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("Quantize must map float -> int8/int4, got {} -> {}", x.dtype.name(), y.dtype.name()),
                ));
            }
        }
        OpKind::Dequantize => {
            if !arity_ok(g, n, r, 1) {
                return;
            }
            let (x, y) = (g.tensor(n.inputs[0]), g.tensor(n.outputs[0]));
            let want = x.shape.0.clone();
            expect_shape(g, n, r, n.outputs[0], &want);
            if !is_int(x.dtype) || !is_float(y.dtype) {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("Dequantize must map int8/int4 -> float, got {} -> {}", x.dtype.name(), y.dtype.name()),
                ));
            }
        }
        OpKind::ConvertTo => {
            if !arity_ok(g, n, r, 1) {
                return;
            }
            let x = g.tensor(n.inputs[0]);
            let want = x.shape.0.clone();
            expect_shape(g, n, r, n.outputs[0], &want);
        }
        OpKind::AvgPool { .. } | OpKind::MaxPool { .. } => {
            if !arity_ok(g, n, r, 1) {
                return;
            }
            let (x, y) = (g.tensor(n.inputs[0]), g.tensor(n.outputs[0]));
            let rank = x.shape.rank();
            if !(rank == 4 || rank == 5) || y.shape.rank() != rank {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "pool expects rank-4 (NHWC) or rank-5 (NFHWC) in and out, got {:?} -> {:?}",
                        x.shape.0, y.shape.0
                    ),
                ));
                return;
            }
            // batch (and frames, rank-5) and channels pass through; pooled
            // spatial dims shrink or stay (windows may overlap, so `<=`)
            let fixed: &[usize] = if rank == 4 { &[0, 3] } else { &[0, 1, 4] };
            for &d in fixed {
                if y.shape.dim(d) != x.shape.dim(d) {
                    r.push(diag(
                        g,
                        n,
                        RuleId::ShapeMismatch,
                        format!("pool must preserve dim {d}: {:?} -> {:?}", x.shape.0, y.shape.0),
                    ));
                }
            }
            let spatial: &[usize] = if rank == 4 { &[1, 2] } else { &[2, 3] };
            for &d in spatial {
                if y.shape.dim(d) > x.shape.dim(d) {
                    r.push(diag(
                        g,
                        n,
                        RuleId::ShapeMismatch,
                        format!("pooled spatial dim {d} grows: {:?} -> {:?}", x.shape.0, y.shape.0),
                    ));
                }
            }
            if x.dtype != y.dtype {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("pool must preserve dtype: {} -> {}", x.dtype.name(), y.dtype.name()),
                ));
            }
        }
        OpKind::AdaptiveAvgPool { .. } => {
            if !arity_ok(g, n, r, 1) {
                return;
            }
            let x = g.tensor(n.inputs[0]);
            if x.shape.rank() < 2 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("adaptive pool needs a batched channels-last input, got {:?}", x.shape.0),
                ));
                return;
            }
            // global pool to [batch, channels]
            let want = [x.shape.dim(0), x.shape.dim(x.shape.rank() - 1)];
            expect_shape(g, n, r, n.outputs[0], &want);
            let y = g.tensor(n.outputs[0]);
            if x.dtype != y.dtype {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("pool must preserve dtype: {} -> {}", x.dtype.name(), y.dtype.name()),
                ));
            }
        }
        OpKind::LayerNorm => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            let (x, gain) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            if x.shape.rank() < 1 {
                return;
            }
            let d = x.shape.dim(x.shape.rank() - 1);
            // gain packs scale+shift: 2 * d_model parameters
            if gain.shape.elements() != 2 * d {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!(
                        "LayerNorm gain '{}' has {} params, expected 2 x {d} (scale + shift)",
                        gain.name,
                        gain.shape.elements()
                    ),
                ));
            }
            let want = x.shape.0.clone();
            expect_shape(g, n, r, n.outputs[0], &want);
            let y = g.tensor(n.outputs[0]);
            if y.dtype != x.dtype {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("LayerNorm output dtype {} disagrees with input {}", y.dtype.name(), x.dtype.name()),
                ));
            }
        }
        OpKind::Gather => {
            if !arity_ok(g, n, r, 2) {
                return;
            }
            let (emb, ids) = (g.tensor(n.inputs[0]), g.tensor(n.inputs[1]));
            if emb.shape.rank() != 2 {
                r.push(diag(
                    g,
                    n,
                    RuleId::ShapeMismatch,
                    format!("Gather table '{}' must be rank-2 (vocab, dim), got {:?}", emb.name, emb.shape.0),
                ));
                return;
            }
            if ids.dtype != DType::I32 {
                r.push(diag(
                    g,
                    n,
                    RuleId::DtypeMismatch,
                    format!("Gather ids '{}' must be int32, got {}", ids.name, ids.dtype.name()),
                ));
            }
            expect_shape(g, n, r, n.outputs[0], &[ids.shape.elements(), emb.shape.dim(1)]);
            expect_float_out(g, n, r, n.outputs[0]);
        }
        OpKind::Relu | OpKind::Gelu | OpKind::Swish | OpKind::Sigmoid | OpKind::BatchNorm => {
            same_shape_unary(g, n, r);
        }
        // host ops handled by the early return; kept for exhaustiveness
        OpKind::RoiAlign | OpKind::NonMaxSuppression => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Shape;

    fn fc_graph() -> Graph {
        let mut g = Graph::new("lint-fc");
        let x = g.add_tensor("x", Shape::new(&[4, 16]), DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", Shape::new(&[8, 16]), DType::F16, TensorKind::Weight);
        let b = g.add_tensor("b", Shape::new(&[8]), DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", Shape::new(&[4, 8]), DType::F32, TensorKind::Output);
        g.add_node("fc", OpKind::Fc, vec![x, w, b], vec![y]);
        g
    }

    #[test]
    fn clean_fc_passes() {
        let r = lint_graph(&fc_graph());
        assert!(r.is_empty(), "unexpected diagnostics:\n{}", r.render());
    }

    #[test]
    fn fc_output_shape_mismatch_names_the_node() {
        let mut g = fc_graph();
        g.tensors[3].shape = Shape::new(&[4, 9]);
        let r = lint_graph(&g);
        assert!(r.has_errors());
        let hits = r.by_rule(RuleId::ShapeMismatch);
        assert!(!hits.is_empty());
        match &hits[0].span {
            Span::Node { node, name, .. } => {
                assert_eq!(*node, 0);
                assert_eq!(name, "fc");
            }
            other => panic!("expected node span, got {other:?}"),
        }
    }

    #[test]
    fn fc_reduction_dim_mismatch_caught() {
        let mut g = fc_graph();
        g.tensors[1].shape = Shape::new(&[8, 12]); // w k-dim disagrees with x
        let r = lint_graph(&g);
        assert_eq!(r.by_rule(RuleId::ShapeMismatch).len(), 1);
    }

    #[test]
    fn quantized_fc_requires_int8_weight() {
        let mut g = fc_graph();
        g.nodes[0].kind = OpKind::QuantizedFc;
        let r = lint_graph(&g);
        assert!(!r.by_rule(RuleId::DtypeMismatch).is_empty(), "{}", r.render());
        g.tensors[1].dtype = DType::I8;
        assert!(lint_graph(&g).is_empty());
    }

    #[test]
    fn arity_mismatch_caught() {
        let mut g = fc_graph();
        g.nodes[0].inputs.pop();
        let r = lint_graph(&g);
        assert_eq!(r.by_rule(RuleId::ArityMismatch).len(), 1);
    }

    #[test]
    fn dangling_id_caught_without_panicking() {
        let mut g = fc_graph();
        g.nodes[0].inputs[0] = 99;
        let r = lint_graph(&g);
        let hits = r.by_rule(RuleId::StructuralInvalid);
        assert_eq!(hits.len(), 1);
        assert!(matches!(hits[0].span, Span::Node { node: 0, .. }));
    }

    #[test]
    fn dead_activation_and_unreachable_node_warned() {
        let mut g = fc_graph();
        let y0 = 3; // the fc output feeds a side branch that goes nowhere
        let dead = g.add_tensor("dead", Shape::new(&[4, 8]), DType::F32, TensorKind::Activation);
        g.add_node("dead_relu", OpKind::Relu, vec![y0], vec![dead]);
        let r = lint_graph(&g);
        assert!(!r.has_errors(), "{}", r.render());
        assert_eq!(r.by_rule(RuleId::UnconsumedIntermediate).len(), 1);
        assert_eq!(r.by_rule(RuleId::UnreachableNode).len(), 1);
    }

    #[test]
    fn zero_dim_tensor_is_an_error() {
        let mut g = fc_graph();
        g.tensors[0].shape = Shape::new(&[0, 16]);
        assert!(lint_graph(&g).has_errors());
    }

    #[test]
    fn all_builtin_models_infer_clean() {
        for id in crate::graph::models::ModelId::ALL {
            let r = lint_graph(&id.build());
            assert!(r.is_empty(), "{}: \n{}", id.name(), r.render());
        }
    }

    // ---- property tests ---------------------------------------------------

    use crate::util::prop::{check, Gen as PropGen};
    use crate::util::rng::Rng;

    /// A random FC chain plus a corruption plan: which node to damage
    /// (`target`) and how (`mode` 0 = output dim, 1 = weight dtype,
    /// 2 = dangling input id).
    #[derive(Clone, Debug)]
    struct ChainSpec {
        batch: usize,
        widths: Vec<usize>,
        target: usize,
        mode: u64,
    }

    struct ChainGen;
    impl PropGen for ChainGen {
        type Value = ChainSpec;
        fn generate(&self, rng: &mut Rng) -> ChainSpec {
            let depth = rng.range(1, 5) as usize;
            let widths = (0..=depth).map(|_| rng.range(1, 32) as usize).collect();
            ChainSpec {
                batch: rng.range(1, 8) as usize,
                widths,
                target: rng.below(depth as u64) as usize,
                mode: rng.below(3),
            }
        }
    }

    /// Build the chain; returns the graph plus, per layer, its (node id,
    /// weight tensor id, output tensor id).
    fn build_chain(spec: &ChainSpec) -> (Graph, Vec<(usize, usize, usize)>) {
        let mut g = Graph::new("prop-chain");
        let mut x =
            g.add_tensor("x", Shape::new(&[spec.batch, spec.widths[0]]), DType::F32, TensorKind::Input);
        let mut layers = Vec::new();
        let depth = spec.widths.len() - 1;
        for i in 0..depth {
            let (fan_in, fan_out) = (spec.widths[i], spec.widths[i + 1]);
            let w = g.add_tensor(
                &format!("w{i}"),
                Shape::new(&[fan_out, fan_in]),
                DType::F16,
                TensorKind::Weight,
            );
            let b = g.add_tensor(&format!("b{i}"), Shape::new(&[fan_out]), DType::F32, TensorKind::Weight);
            let kind =
                if i + 1 == depth { TensorKind::Output } else { TensorKind::Activation };
            let y = g.add_tensor(&format!("y{i}"), Shape::new(&[spec.batch, fan_out]), DType::F32, kind);
            let n = g.add_node(&format!("fc{i}"), OpKind::Fc, vec![x, w, b], vec![y]);
            layers.push((n, w, y));
            x = y;
        }
        (g, layers)
    }

    #[test]
    fn prop_random_valid_chains_lint_clean() {
        check("valid chains lint clean", 40, &ChainGen, |spec| {
            let (g, _) = build_chain(spec);
            let r = lint_graph(&g);
            if r.is_empty() {
                Ok(())
            } else {
                Err(format!("clean graph flagged:\n{}", r.render()))
            }
        });
    }

    #[test]
    fn prop_single_field_corruptions_always_caught() {
        check("corruptions caught and attributed", 60, &ChainGen, |spec| {
            let (mut g, layers) = build_chain(spec);
            let (node, w, y) = layers[spec.target];
            match spec.mode {
                0 => g.tensors[y].shape.0[0] += 1, // declared output dim drifts
                1 => g.tensors[w].dtype = DType::I32, // illegal weight dtype
                _ => g.nodes[node].inputs[0] = g.tensors.len() + 7, // dangling id
            }
            let r = lint_graph(&g);
            if !r.has_errors() {
                return Err(format!("corruption mode {} not caught", spec.mode));
            }
            let named = r.diagnostics.iter().any(
                |d| matches!(&d.span, Span::Node { node: n, .. } if *n == node),
            );
            if named {
                Ok(())
            } else {
                Err(format!(
                    "offending node {node} not named (mode {}):\n{}",
                    spec.mode,
                    r.render()
                ))
            }
        });
    }
}
