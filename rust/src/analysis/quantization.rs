//! `quantization-accuracy-budget` (`fbia lint --precision int8`): the
//! static side of the runtime's int8 serving plan.
//!
//! `Engine::prepare` at [`crate::runtime::Precision::Int8`] quantizes
//! eligible weights row-wise and gates the result against an f32 reference
//! ([`crate::numerics::validate::int8_plan`]). This lint runs the *same*
//! per-layer decision procedure statically — no weights materialized,
//! nothing prepared — so a deployment can see, before serving, which
//! layers will quantize and which fall back to f32 because their estimated
//! error ([`crate::compiler::quantize::estimate_int8_error`] over the
//! contraction dim) exceeds the budget
//! ([`crate::compiler::quantize::DEFAULT_ERROR_BUDGET`]).
//!
//! Fallbacks are `Warn`, not `Error`: the runtime serves them at f32
//! within the accuracy gate, so nothing is broken — but each one costs the
//! int8 engine's throughput advantage, which is exactly what a capacity
//! plan wants surfaced.

use crate::analysis::{Diagnostic, Report, RuleId, Span};
use crate::compiler::quantize::DEFAULT_ERROR_BUDGET;
use crate::numerics::validate::int8_plan;
use crate::runtime::artifact::Manifest;

/// Lint every artifact's int8 serving plan: one `Warn` per weight whose
/// estimated quantization error exceeds the budget (it will serve at f32).
pub fn lint_quantization(manifest: &Manifest) -> Report {
    let mut r = Report::new();
    for art in &manifest.artifacts {
        for d in int8_plan(art) {
            if d.quantize {
                continue;
            }
            r.push(
                Diagnostic::new(
                    RuleId::QuantizationAccuracyBudget,
                    Span::Model { model: art.name.clone() },
                    format!(
                        "weight '{}' (k={}) estimated int8 error {:.4} exceeds the \
                         {DEFAULT_ERROR_BUDGET} budget; it serves at f32",
                        d.name, d.k, d.est_error
                    ),
                )
                .suggest(
                    "shrink the contraction dim (shard the FC) or accept the f32 fallback",
                ),
            );
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Severity;
    use crate::runtime::builtin::builtin_manifest;

    #[test]
    fn builtin_manifest_fallbacks_are_warnings_only() {
        let m = builtin_manifest();
        let r = lint_quantization(&m);
        // the builtin nets contain known over-budget contractions (xlmr
        // ffn2 k=1024, dlrm top_w1 k=512), so the rule must fire...
        assert!(!r.is_empty(), "expected f32-fallback findings");
        // ...but only ever as warnings: fallbacks serve correctly at f32
        assert_eq!(r.errors(), 0);
        assert!(r.warnings() > 0);
        for d in &r.diagnostics {
            assert_eq!(d.rule, RuleId::QuantizationAccuracyBudget);
            assert_eq!(d.severity, Severity::Warn);
        }
        // every xlmr variant's ffn2 is over budget at d_model 256 / ffn 1024
        let msgs = r.render();
        assert!(msgs.contains("w2"), "missing ffn2 fallback: {msgs}");
    }

    #[test]
    fn pre_quantized_artifacts_have_no_findings() {
        // pre-quantized artifacts carry WeightQ FC weights (plus 1-D
        // scale/zp vectors), all outside the prepare-time plan — nothing to
        // warn about
        let m = builtin_manifest();
        let art = m.artifacts.iter().find(|a| a.name.ends_with("_int8")).unwrap();
        assert!(int8_plan(art).is_empty(), "plan not empty for {}", art.name);
    }
}
