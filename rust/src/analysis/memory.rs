//! Static memory-fit proof (lint layer 3).
//!
//! The paper sizes models against the card up front: a DLRM whose
//! embedding tables exceed the six cards' 16 GB LPDDR each simply cannot
//! deploy on the node (§VI-B motivates the Fig. 6 model-parallel split
//! with exactly this bound). [`lint_memory`] proves the bound statically:
//! it runs the partitioner, computes each partition's peak *activation*
//! footprint by liveness analysis over the topological order, and checks
//! weights + activations against every card's DRAM — including vendor-mix
//! slots ([`NodeSpec::card_overrides`]), which the partitioner's own
//! capacity check ([`Plan::check`]) sizes against the base card only.
//!
//! [`lint_artifact`] is the same proof at the artifact level, run by
//! [`crate::runtime::Engine::prepare_on`] before any weight upload.
//!
//! [`NodeSpec::card_overrides`]: crate::platform::NodeSpec
//! [`Plan::check`]: crate::compiler::partition::Plan::check

use super::{Diagnostic, Report, RuleId, Span};
use crate::compiler::partition::{partition, PartitionKind};
use crate::config::Config;
use crate::graph::{Graph, NodeId, TensorKind};
use crate::platform::CardSpec;
use crate::runtime::artifact::{Artifact, InputKind};
use std::collections::{BTreeSet, HashMap, HashSet};

/// Partition the model and prove every card's DRAM/SRAM budget holds.
pub fn lint_memory(g: &Graph, cfg: &Config) -> Report {
    let mut r = Report::new();
    let plan = match partition(g, &cfg.compiler, &cfg.node) {
        Ok(p) => p,
        Err(e) => {
            r.push(
                Diagnostic::new(
                    RuleId::PartitionFailed,
                    Span::Model { model: g.name.clone() },
                    format!("model cannot be partitioned onto this node spec: {e}"),
                )
                .suggest(
                    "give the node more/larger cards, raise compiler.sls_cards, or shrink the model",
                ),
            );
            return r;
        }
    };
    let Ok(order) = g.topo_order() else {
        return r; // cycle: already an Error from the structural pass
    };

    // peak live activation bytes per device partition
    let peaks: Vec<usize> = plan
        .partitions
        .iter()
        .map(|p| if p.card.is_some() { peak_activation_bytes(g, &order, &p.nodes) } else { 0 })
        .collect();

    // Per-card DRAM: SLS shards live on their assigned card; Dense/Full
    // partitions are data-parallel *replicas on every card* (Fig. 6), so
    // their weights and activations count against each card, not just the
    // canonical slot the plan records.
    let cards = cfg.node.cards;
    let mut card_total = vec![0usize; cards];
    let mut card_top: Vec<Option<(usize, usize)>> = vec![None; cards]; // (bytes, partition id)
    fn add(card: usize, bytes: usize, pid: usize, tot: &mut [usize], top: &mut [Option<(usize, usize)>]) {
        if card >= tot.len() {
            return;
        }
        tot[card] += bytes;
        match top[card] {
            Some((b, _)) if bytes <= b => {}
            _ => top[card] = Some((bytes, pid)),
        }
    }
    for (p, &peak) in plan.partitions.iter().zip(&peaks) {
        let bytes = p.weight_bytes + peak;
        match (p.kind, p.card) {
            (PartitionKind::Sls, Some(c)) => add(c, bytes, p.id, &mut card_total, &mut card_top),
            (PartitionKind::Dense | PartitionKind::Full, Some(_)) => {
                for c in 0..cards {
                    add(c, bytes, p.id, &mut card_total, &mut card_top);
                }
            }
            _ => {} // host partition: host DRAM, not card DRAM
        }
    }
    for c in 0..cards {
        let cap = cfg.node.card_spec(c).lpddr_bytes;
        if card_total[c] > cap {
            let (_, pid) = card_top[c].unwrap_or((0, 0));
            r.push(
                Diagnostic::new(
                    RuleId::PartitionDramOverflow,
                    Span::Partition { model: g.name.clone(), partition: pid, card: Some(c) },
                    format!(
                        "card {c} needs {} of weights+activations but has {} LPDDR",
                        fmt_bytes(card_total[c]),
                        fmt_bytes(cap)
                    ),
                )
                .suggest("spread SLS shards over more cards or use a larger-memory card spec"),
            );
        }
    }

    // Per-node SRAM: the op's working set (all non-weight operands live at
    // once) should fit on-chip, else it streams through LPDDR (§III-B says
    // weights of tens of MB fit on-chip; activations share that budget).
    for p in &plan.partitions {
        let Some(c) = p.card else { continue };
        let onchip = cfg.node.card_spec(c).onchip_bytes();
        for &nid in &p.nodes {
            let n = g.node(nid);
            let distinct: BTreeSet<usize> = n
                .inputs
                .iter()
                .chain(&n.outputs)
                .copied()
                .filter(|&t| g.tensor(t).kind != TensorKind::Weight)
                .collect();
            let working: usize = distinct.iter().map(|&t| g.tensor(t).bytes()).sum();
            if working > onchip {
                r.push(
                    Diagnostic::new(
                        RuleId::ActivationSramSpill,
                        Span::Node { graph: g.name.clone(), node: nid, name: n.name.clone() },
                        format!(
                            "activation working set {} exceeds card {c}'s {} on-chip memory; \
                             the op will stream through LPDDR",
                            fmt_bytes(working),
                            fmt_bytes(onchip)
                        ),
                    )
                    .suggest("reduce the batch size or split the op"),
                );
            }
        }
    }
    r
}

/// Peak bytes of simultaneously-live non-weight tensors while executing
/// `nodes` in topological order (classic interval liveness: each tensor is
/// live from its producer to its last in-partition consumer; tensors that
/// escape the partition — outputs, cross-partition reads — stay live to
/// the end).
pub fn peak_activation_bytes(g: &Graph, topo: &[NodeId], nodes: &[NodeId]) -> usize {
    let members: HashSet<NodeId> = nodes.iter().copied().collect();
    let order: Vec<NodeId> = topo.iter().copied().filter(|n| members.contains(n)).collect();
    if order.is_empty() {
        return 0;
    }
    let pos: HashMap<NodeId, usize> = order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
    let end = order.len() - 1;
    let producers = g.producers();
    let consumers = g.consumers();

    // tensors touched by this partition
    let mut touched: BTreeSet<usize> = BTreeSet::new();
    for &nid in &order {
        let n = g.node(nid);
        touched.extend(n.inputs.iter().chain(&n.outputs).copied());
    }

    let mut diff = vec![0i64; order.len() + 1];
    for &t in &touched {
        let tn = g.tensor(t);
        if tn.kind == TensorKind::Weight {
            continue; // counted via Partition::weight_bytes
        }
        let def = producers[t].and_then(|p| pos.get(&p).copied()).unwrap_or(0);
        let escapes = tn.kind == TensorKind::Output
            || consumers[t].is_empty()
            || consumers[t].iter().any(|c| !members.contains(c));
        let last = if escapes {
            end
        } else {
            consumers[t].iter().filter_map(|c| pos.get(c).copied()).max().unwrap_or(def)
        };
        diff[def] += tn.bytes() as i64;
        diff[last + 1] -= tn.bytes() as i64;
    }
    let mut live = 0i64;
    let mut peak = 0i64;
    for d in &diff {
        live += d;
        peak = peak.max(live);
    }
    peak.max(0) as usize
}

/// Artifact-level memory proof: the resident weights an artifact will pin
/// on `device` must fit that card's DRAM. Run by `Engine::prepare_on`
/// before any upload.
pub fn lint_artifact(art: &Artifact, card: &CardSpec, device: usize) -> Report {
    let mut r = Report::new();
    for spec in &art.inputs {
        if spec.shape.iter().any(|&d| d == 0) {
            r.push(Diagnostic::new(
                RuleId::ShapeMismatch,
                Span::Model { model: art.name.clone() },
                format!("input '{}' declares a zero-sized dimension {:?}", spec.name, spec.shape),
            ));
        }
    }
    for spec in &art.outputs {
        if spec.shape.iter().any(|&d| d == 0) {
            r.push(Diagnostic::new(
                RuleId::ShapeMismatch,
                Span::Model { model: art.name.clone() },
                format!("an output declares a zero-sized dimension {:?}", spec.shape),
            ));
        }
    }
    let resident: usize = art
        .inputs
        .iter()
        .filter(|s| s.kind != InputKind::Input)
        .map(|s| s.elements() * s.dtype.bytes())
        .sum();
    if resident > card.lpddr_bytes {
        r.push(
            Diagnostic::new(
                RuleId::PartitionDramOverflow,
                Span::Model { model: art.name.clone() },
                format!(
                    "resident weights {} exceed card {device}'s {} LPDDR",
                    fmt_bytes(resident),
                    fmt_bytes(card.lpddr_bytes)
                ),
            )
            .suggest("shard the artifact or target a larger-memory card"),
        );
    }
    r
}

fn fmt_bytes(b: usize) -> String {
    const GB: f64 = (1u64 << 30) as f64;
    const MB: f64 = (1u64 << 20) as f64;
    let b = b as f64;
    if b >= GB {
        format!("{:.2} GiB", b / GB)
    } else {
        format!("{:.1} MiB", b / MB)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::ModelId;
    use crate::graph::{DType, Shape};
    use crate::runtime::artifact::{ArtDType, InputSpec, OutputSpec};
    use std::path::PathBuf;

    #[test]
    fn builtin_models_fit_the_default_node() {
        let cfg = Config::default();
        for id in ModelId::ALL {
            let r = lint_memory(&id.build(), &cfg);
            assert!(r.is_empty(), "{}: \n{}", id.name(), r.render());
        }
    }

    #[test]
    fn dlrm_on_a_tiny_card_is_a_partition_failure() {
        let mut cfg = Config::default();
        cfg.node.card.lpddr_bytes = 1 << 30; // 1 GiB: tables cannot shard in
        let r = lint_memory(&ModelId::RecsysComplex.build(), &cfg);
        assert!(r.has_errors(), "{}", r.render());
        assert!(!r.by_rule(RuleId::PartitionFailed).is_empty(), "{}", r.render());
    }

    #[test]
    fn vendor_mix_override_card_overflow_names_the_card() {
        // base card passes the partitioner's own check; the tiny override
        // slot only the per-card lint sees
        let mut cfg = Config::default();
        cfg.node.card_overrides.push((2, CardSpec { lpddr_bytes: 8 << 20, ..CardSpec::default() }));
        let r = lint_memory(&ModelId::ResNeXt101.build(), &cfg);
        let hits = r.by_rule(RuleId::PartitionDramOverflow);
        assert_eq!(hits.len(), 1, "{}", r.render());
        assert!(matches!(hits[0].span, Span::Partition { card: Some(2), .. }), "{:?}", hits[0].span);
        assert!(hits[0].message.contains("card 2"));
    }

    #[test]
    fn giant_activation_warns_sram_spill() {
        let mut g = Graph::new("spill");
        let x = g.add_tensor("x", Shape::new(&[1, 64 << 20]), DType::F32, TensorKind::Input);
        let y = g.add_tensor("y", Shape::new(&[1, 64 << 20]), DType::F32, TensorKind::Output);
        g.add_node("big_relu", crate::graph::ops::OpKind::Relu, vec![x], vec![y]);
        let r = lint_memory(&g, &Config::default());
        let hits = r.by_rule(RuleId::ActivationSramSpill);
        assert_eq!(hits.len(), 1, "{}", r.render());
        assert!(!r.has_errors()); // a spill is a perf warning, not an error
    }

    #[test]
    fn peak_is_liveness_not_sum() {
        // chain a -> b -> c of equal 1 MiB activations: peak is 2 MiB
        // (producer + consumer), not 3
        let mut g = Graph::new("chain");
        let elems = (1 << 20) / 4;
        let a = g.add_tensor("a", Shape::new(&[elems]), DType::F32, TensorKind::Input);
        let b = g.add_tensor("b", Shape::new(&[elems]), DType::F32, TensorKind::Activation);
        let c = g.add_tensor("c", Shape::new(&[elems]), DType::F32, TensorKind::Output);
        g.add_node("r1", crate::graph::ops::OpKind::Relu, vec![a], vec![b]);
        g.add_node("r2", crate::graph::ops::OpKind::Relu, vec![b], vec![c]);
        let order = g.topo_order().unwrap();
        let peak = peak_activation_bytes(&g, &order, &[0, 1]);
        // c escapes (Output) so it is live from its def to the end; a is
        // dead after r1: peak = b + c at the r2 step plus a at the r1 step
        assert_eq!(peak, 2 << 20, "peak {peak}");
    }

    #[test]
    fn oversized_artifact_rejected() {
        let art = Artifact {
            name: "huge".into(),
            file: PathBuf::from("huge.bin"),
            model: "huge".into(),
            role: "full".into(),
            batch: 1,
            seq: None,
            shard: None,
            inputs: vec![
                InputSpec {
                    name: "w".into(),
                    shape: vec![5 << 30, 1],
                    dtype: ArtDType::F32,
                    kind: InputKind::Weight,
                },
                InputSpec {
                    name: "x".into(),
                    shape: vec![1, 8],
                    dtype: ArtDType::F32,
                    kind: InputKind::Input,
                },
            ],
            outputs: vec![OutputSpec { shape: vec![1, 8], dtype: ArtDType::F32 }],
        };
        let r = lint_artifact(&art, &CardSpec::default(), 0);
        assert!(r.has_errors());
        assert!(!r.by_rule(RuleId::PartitionDramOverflow).is_empty());
        // request inputs do not count against resident DRAM
        let small = Artifact { inputs: vec![art.inputs[1].clone()], ..art };
        assert!(lint_artifact(&small, &CardSpec::default(), 0).is_empty());
    }
}
