//! Deployment-feasibility rules (lint layer 4).
//!
//! An infeasible serving config used to surface only as a mysterious 100%
//! shed rate deep inside a DES run. Everything checked here is knowable
//! *statically*: the modeled latency floor of a family's model (so an SLA
//! budget below it can never be met — §VII's operational lesson), the NIC
//! line rate against the wire bytes a target QPS implies (§VI-C sizes
//! those bytes; §III-A the 50 Gbps NIC), and structural config mistakes —
//! zero-replica families that still carry traffic, queue bounds of zero,
//! batch-growth windows that can never open, clusters that are all
//! failure headroom.

use super::{Diagnostic, Report, RuleId, Span};
use crate::config::Config;
use crate::graph::ops::OpKind;
use crate::graph::TensorKind;
use crate::serving::fleet::{Family, FamilyMix, FleetConfig};
use crate::util::error::Result;
use crate::workloads::AVG_LOOKUP_FRACTION;
use std::collections::HashSet;

/// Rules over [`Config`] alone — run by `Config::from_json` as a loading
/// gate (bypass: `--no-lint` / [`Config::from_json_with`]).
pub fn lint_config(cfg: &Config) -> Report {
    let mut r = Report::new();
    if cfg.serving.max_queue == 0 {
        r.push(
            Diagnostic::new(
                RuleId::QueueBoundZero,
                Span::Config { path: "serving.max_queue".into() },
                "a queue bound of zero sheds every request before it is served",
            )
            .suggest("set serving.max_queue >= 1"),
        );
    }
    if let Some(cl) = &cfg.cluster {
        if !cl.nodes.is_empty() && cl.headroom >= cl.nodes.len() {
            r.push(
                Diagnostic::new(
                    RuleId::HeadroomExceedsNodes,
                    Span::Config { path: "cluster.headroom".into() },
                    format!(
                        "failure headroom {} leaves no load-carrying node in a {}-node tier",
                        cl.headroom,
                        cl.nodes.len()
                    ),
                )
                .suggest("keep headroom below the node count"),
            );
        }
    }
    r
}

/// A planned deployment to vet: the fleet knobs, the family traffic mix,
/// and (optionally) the offered load the NIC must carry.
pub struct DeploySpec<'a> {
    pub fleet: &'a FleetConfig,
    pub mix: FamilyMix,
    /// Target request rate; `None` skips the NIC-bandwidth rule.
    pub offered_qps: Option<f64>,
}

/// Vet a deployment before simulating it. `Err` only when a rule needs the
/// analytic simulator and it fails (e.g. the model cannot compile);
/// findings land in the returned [`Report`].
pub fn lint_deployment(cfg: &Config, d: &DeploySpec<'_>) -> Result<Report> {
    let mut r = lint_config(cfg);
    let fleet = d.fleet;
    let active: Vec<Family> =
        Family::ALL.iter().copied().filter(|&f| d.mix.share(f) > 0.0).collect();

    if fleet.replicas == 0 {
        for &f in &active {
            r.push(
                Diagnostic::new(
                    RuleId::ZeroReplicaFamily,
                    Span::Config { path: "fleet.replicas".into() },
                    format!(
                        "family '{}' carries {:.0}% of traffic but has zero replicas",
                        f.name(),
                        d.mix.share(f) * 100.0
                    ),
                )
                .suggest("set fleet.replicas >= 1 or drop the family from the mix"),
            );
        }
    }
    if fleet.max_queue == 0 {
        r.push(
            Diagnostic::new(
                RuleId::QueueBoundZero,
                Span::Config { path: "fleet.max_queue".into() },
                "a per-card queue bound of zero sheds every request",
            )
            .suggest("set fleet.max_queue >= 1"),
        );
    }
    if let Some(db) = &fleet.dynamic_batch {
        if db.depth_hi >= fleet.max_queue && fleet.max_queue > 0 {
            r.push(
                Diagnostic::new(
                    RuleId::BatchWindowNeverOpens,
                    Span::Config { path: "fleet.dynamic_batch.depth_hi".into() },
                    format!(
                        "growth trigger depth_hi ({}) is never reached: the queue bound sheds \
                         at {} first, so dynamic batching degenerates to static",
                        db.depth_hi, fleet.max_queue
                    ),
                )
                .suggest("set depth_hi well below max_queue"),
            );
        }
    }

    // SLA budget vs the modeled single-request floor: queueing and batching
    // only ever add latency on top of it, so a budget below the floor sheds
    // 100% of admitted traffic regardless of routing policy.
    if let Some(budget) = fleet.sla_budget_s {
        for &f in &active {
            let floor = family_floor_s(f, cfg, fleet)?;
            if budget < floor {
                r.push(
                    Diagnostic::new(
                        RuleId::SlaBelowModeledFloor,
                        Span::Config { path: "fleet.sla_budget_s".into() },
                        format!(
                            "budget {:.3} ms is below family '{}''s modeled request floor \
                             {:.3} ms — every request would be shed",
                            budget * 1e3,
                            f.name(),
                            floor * 1e3
                        ),
                    )
                    .suggest("raise the SLA budget above the modeled floor or shrink the model"),
                );
            }
        }
    }

    // NIC line rate vs the wire bytes the offered QPS implies (§VI-C
    // transfer volumes; the tier's ingress ceiling is the NIC).
    if let Some(qps) = d.offered_qps {
        if qps > 0.0 {
            let bits_per_req: f64 = Family::ALL
                .iter()
                .map(|&f| d.mix.share(f) * 8.0 * family_wire_bytes(f, cfg, fleet))
                .sum();
            let required = qps * bits_per_req;
            let (available, path) = match &cfg.cluster {
                Some(cl) => (cl.total_nic_bw_bits(), "cluster"),
                None => (cfg.node.nic.bw_bits, "node.nic.bw_bits"),
            };
            if required > available {
                r.push(
                    Diagnostic::new(
                        RuleId::NicBandwidthInsufficient,
                        Span::Config { path: path.into() },
                        format!(
                            "{qps:.0} req/s of this mix needs {:.2} Gbit/s on the wire but the \
                             tier's NICs provide {:.2} Gbit/s",
                            required / 1e9,
                            available / 1e9
                        ),
                    )
                    .suggest("add nodes / faster NICs, or lower the offered QPS"),
                );
            }
        }
    }
    Ok(r)
}

/// Modeled single-request latency of a family's Table I model under this
/// config — the floor no routing policy can beat.
fn family_floor_s(f: Family, cfg: &Config, fleet: &FleetConfig) -> Result<f64> {
    let rep = match f {
        Family::Recsys => {
            crate::sim::simulate_model_batch(f.model_id(), fleet.recsys_batch.max(1), cfg, 1)?
        }
        _ => crate::sim::simulate_model(f.model_id(), cfg, 1)?,
    };
    Ok(rep.latency_s)
}

/// Per-request wire bytes of one family: the larger of the request's input
/// payload and its output payload, from the graph's Input/Output tensors.
/// With `transfers.partial_tensors` the SLS index tensors count only their
/// used prefix (§VI-C), matching the sim backend's PCIe model.
fn family_wire_bytes(f: Family, cfg: &Config, fleet: &FleetConfig) -> f64 {
    let id = f.model_id();
    let batch = if f == Family::Recsys { fleet.recsys_batch.max(1) } else { id.typical_batch() };
    let g = id.build_batch(batch);
    // index operands of SLS ops (input position 1) are the partial-tensor
    // candidates
    let idx_tensors: HashSet<usize> = g
        .nodes
        .iter()
        .filter(|n| {
            matches!(n.kind, OpKind::SparseLengthsSum { .. } | OpKind::SparseLengthsSumSingle)
        })
        .filter_map(|n| n.inputs.get(1).copied())
        .collect();
    let mut ingress = 0.0f64;
    let mut egress = 0.0f64;
    for t in &g.tensors {
        match t.kind {
            TensorKind::Input => {
                let mut b = t.bytes() as f64;
                if cfg.transfers.partial_tensors && idx_tensors.contains(&t.id) {
                    b *= AVG_LOOKUP_FRACTION;
                }
                ingress += b;
            }
            TensorKind::Output => egress += t.bytes() as f64,
            _ => {}
        }
    }
    ingress.max(egress)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{ClusterSpec, NodeSpec};
    use crate::serving::fleet::DynamicBatch;

    fn deploy<'a>(fleet: &'a FleetConfig, qps: Option<f64>) -> DeploySpec<'a> {
        DeploySpec { fleet, mix: FamilyMix::default(), offered_qps: qps }
    }

    #[test]
    fn default_deployment_lints_clean() {
        let cfg = Config::default();
        let fleet = FleetConfig::default();
        let r = lint_deployment(&cfg, &deploy(&fleet, None)).unwrap();
        assert!(r.is_empty(), "{}", r.render());
        assert!(lint_config(&cfg).is_empty());
    }

    #[test]
    fn zero_replicas_with_traffic_is_an_error() {
        let cfg = Config::default();
        let fleet = FleetConfig { replicas: 0, ..FleetConfig::default() };
        let r = lint_deployment(&cfg, &deploy(&fleet, None)).unwrap();
        // all three families of the default 70/20/10 mix are hit
        assert_eq!(r.by_rule(RuleId::ZeroReplicaFamily).len(), 3, "{}", r.render());
        // a family with no traffic share is not
        let d = DeploySpec {
            fleet: &fleet,
            mix: FamilyMix::new(1.0, 0.0, 0.0).unwrap(),
            offered_qps: None,
        };
        let r = lint_deployment(&cfg, &d).unwrap();
        assert_eq!(r.by_rule(RuleId::ZeroReplicaFamily).len(), 1);
        assert!(r.render().contains("recsys"), "{}", r.render());
    }

    #[test]
    fn queue_bound_zero_both_layers() {
        let mut cfg = Config::default();
        cfg.serving.max_queue = 0;
        assert_eq!(lint_config(&cfg).by_rule(RuleId::QueueBoundZero).len(), 1);
        let fleet = FleetConfig { max_queue: 0, ..FleetConfig::default() };
        let r = lint_deployment(&cfg, &deploy(&fleet, None)).unwrap();
        assert_eq!(r.by_rule(RuleId::QueueBoundZero).len(), 2, "{}", r.render());
    }

    #[test]
    fn batch_window_that_never_opens_warns() {
        let cfg = Config::default();
        let mut fleet = FleetConfig {
            dynamic_batch: Some(DynamicBatch { depth_hi: 5000, ..DynamicBatch::default() }),
            ..FleetConfig::default()
        };
        let r = lint_deployment(&cfg, &deploy(&fleet, None)).unwrap();
        let hits = r.by_rule(RuleId::BatchWindowNeverOpens);
        assert_eq!(hits.len(), 1, "{}", r.render());
        assert!(!r.has_errors(), "window lint must be a warning");
        // a sane trigger is clean
        fleet.dynamic_batch = Some(DynamicBatch::default());
        assert!(lint_deployment(&cfg, &deploy(&fleet, None)).unwrap().is_empty());
    }

    #[test]
    fn sla_below_modeled_floor_rejected_before_any_des_run() {
        let cfg = Config::default();
        // 1 µs: no model serves in that
        let mut fleet = FleetConfig { sla_budget_s: Some(1e-6), ..FleetConfig::default() };
        let d = DeploySpec {
            fleet: &fleet,
            mix: FamilyMix::new(1.0, 0.0, 0.0).unwrap(),
            offered_qps: None,
        };
        let r = lint_deployment(&cfg, &d).unwrap();
        let hits = r.by_rule(RuleId::SlaBelowModeledFloor);
        assert_eq!(hits.len(), 1, "{}", r.render());
        assert!(hits[0].message.contains("recsys"), "{}", hits[0].message);
        // a generous budget is clean
        fleet.sla_budget_s = Some(10.0);
        let d = DeploySpec {
            fleet: &fleet,
            mix: FamilyMix::new(1.0, 0.0, 0.0).unwrap(),
            offered_qps: None,
        };
        assert!(lint_deployment(&cfg, &d).unwrap().is_empty());
    }

    #[test]
    fn nic_bandwidth_rule_scales_with_offered_qps() {
        let cfg = Config::default();
        let fleet = FleetConfig::default();
        let r = lint_deployment(&cfg, &deploy(&fleet, Some(1e9))).unwrap();
        assert_eq!(r.by_rule(RuleId::NicBandwidthInsufficient).len(), 1, "{}", r.render());
        assert!(lint_deployment(&cfg, &deploy(&fleet, Some(1.0))).unwrap().is_empty());
        // a cluster aggregates its members' NICs
        let ccfg = Config {
            cluster: Some(ClusterSpec::uniform(3, NodeSpec::default(), 1)),
            ..Config::default()
        };
        let solo_limit = {
            let mut q = 1.0;
            while lint_deployment(&cfg, &deploy(&fleet, Some(q))).unwrap().is_empty() {
                q *= 2.0;
            }
            q
        };
        // the 3-node tier admits the single-node breaking load
        assert!(
            lint_deployment(&ccfg, &deploy(&fleet, Some(solo_limit / 2.0 * 3.0 * 0.9)))
                .unwrap()
                .is_empty()
        );
    }

    #[test]
    fn all_headroom_cluster_is_an_error() {
        // constructed programmatically: Config::validate would refuse this
        // JSON, but a hand-built ClusterSpec must still be caught
        let cfg = Config {
            cluster: Some(ClusterSpec { nodes: vec![NodeSpec::default(); 2], headroom: 2 }),
            ..Config::default()
        };
        let r = lint_config(&cfg);
        assert_eq!(r.by_rule(RuleId::HeadroomExceedsNodes).len(), 1, "{}", r.render());
    }

    #[test]
    fn wire_bytes_honor_partial_tensors() {
        let cfg = Config::default();
        let fleet = FleetConfig::default();
        let full = {
            let mut c = cfg.clone();
            c.transfers.partial_tensors = false;
            family_wire_bytes(Family::Recsys, &c, &fleet)
        };
        let partial = family_wire_bytes(Family::Recsys, &cfg, &fleet);
        assert!(partial < full, "partial {partial} full {full}");
        // CV has no SLS tensors: the switch is a no-op
        let cv = family_wire_bytes(Family::Cv, &cfg, &fleet);
        assert!(cv > 0.0);
    }
}
