//! Static analysis (`fbia lint`): the compile-time gate the paper's Glow
//! toolchain provides (§V, §VI-B), reproduced for this crate's graphs and
//! deployment configs.
//!
//! Four layers, mirroring the tentpole split:
//!
//! 1. a diagnostics framework ([`Diagnostic`] / [`Report`]) — rules are
//!    *collected*, not fail-fast, and render as text or JSON;
//! 2. per-op shape & dtype inference over [`Graph`] ([`shape`]);
//! 3. a static memory-fit proof per [`crate::compiler::partition::Plan`]
//!    partition ([`memory`]) — "model M cannot fit node spec N" becomes a
//!    lint error naming the failing partition, before any `prepare()`;
//! 4. deployment-feasibility rules over `FleetConfig`/`ClusterSpec`
//!    ([`deploy`]) — SLA below the modeled floor, NIC too slow for the
//!    byte demand, batching windows that can never open.
//!
//! `Engine::prepare` and `Config::from_json` run the analyzer and refuse
//! on `Error`-severity diagnostics; `--no-lint` is the escape hatch. The
//! rule catalog lives in `rust/docs/lints.md`.

pub mod deploy;
pub mod memory;
pub mod quantization;
pub mod shape;

pub use deploy::{lint_config, lint_deployment, DeploySpec};
pub use memory::{lint_artifact, lint_memory};
pub use quantization::lint_quantization;
pub use shape::lint_graph;

use crate::config::Config;
use crate::graph::models::ModelId;
use crate::graph::{Graph, NodeId, TensorId};
use crate::util::error::{bail, Result};
use crate::util::json::Json;
use std::fmt;

/// How bad a finding is. `Error` findings fail `fbia lint` and are refused
/// by the `Engine::prepare` / config-loading gates; `Warn` findings are
/// reported but never block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Severity {
    Warn,
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Every lint rule the analyzer knows. One entry per rule in
/// `rust/docs/lints.md`; `fbia lint --json` reports rules by
/// [`RuleId::name`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RuleId {
    /// Graph breaks a structural invariant: dangling tensor id, multiple
    /// producers, write to a constant, or a cycle.
    StructuralInvalid,
    /// An op has the wrong number of inputs or outputs.
    ArityMismatch,
    /// A declared output tensor disagrees with the shape inferred from the
    /// op's inputs and attributes.
    ShapeMismatch,
    /// A tensor's dtype is illegal for its op (e.g. fp16 weights on a
    /// quantized FC, non-int32 SLS indices).
    DtypeMismatch,
    /// An activation is produced but never consumed.
    UnconsumedIntermediate,
    /// A node has no path to any `Output` tensor.
    UnreachableNode,
    /// `compiler::partition` cannot place the model on the node spec at all.
    PartitionFailed,
    /// Weights + peak live activations on one card exceed its LPDDR.
    PartitionDramOverflow,
    /// One op's activation working set exceeds on-chip SRAM (it will
    /// stream through LPDDR; §VI-B).
    ActivationSramSpill,
    /// SLA budget below the modeled minimum request cost (§VII).
    SlaBelowModeledFloor,
    /// NIC bandwidth below the wire-byte demand at the offered QPS (§VI-C).
    NicBandwidthInsufficient,
    /// `dynamic_batch.depth_hi` at or above the queue bound: the growth
    /// window can never open.
    BatchWindowNeverOpens,
    /// Cluster failure headroom at or above the node count.
    HeadroomExceedsNodes,
    /// A family carries traffic in the mix but has zero replicas.
    ZeroReplicaFamily,
    /// A queue bound of zero sheds every request.
    QueueBoundZero,
    /// A weight's estimated int8 quantization error exceeds the error
    /// budget: it serves at f32 under `--precision int8`, forfeiting the
    /// int8 engine's throughput on that layer (§V-A).
    QuantizationAccuracyBudget,
}

impl RuleId {
    pub const ALL: [RuleId; 16] = [
        RuleId::StructuralInvalid,
        RuleId::ArityMismatch,
        RuleId::ShapeMismatch,
        RuleId::DtypeMismatch,
        RuleId::UnconsumedIntermediate,
        RuleId::UnreachableNode,
        RuleId::PartitionFailed,
        RuleId::PartitionDramOverflow,
        RuleId::ActivationSramSpill,
        RuleId::SlaBelowModeledFloor,
        RuleId::NicBandwidthInsufficient,
        RuleId::BatchWindowNeverOpens,
        RuleId::HeadroomExceedsNodes,
        RuleId::ZeroReplicaFamily,
        RuleId::QueueBoundZero,
        RuleId::QuantizationAccuracyBudget,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RuleId::StructuralInvalid => "structural-invalid",
            RuleId::ArityMismatch => "arity-mismatch",
            RuleId::ShapeMismatch => "shape-mismatch",
            RuleId::DtypeMismatch => "dtype-mismatch",
            RuleId::UnconsumedIntermediate => "unconsumed-intermediate",
            RuleId::UnreachableNode => "unreachable-node",
            RuleId::PartitionFailed => "partition-failed",
            RuleId::PartitionDramOverflow => "partition-dram-overflow",
            RuleId::ActivationSramSpill => "activation-sram-spill",
            RuleId::SlaBelowModeledFloor => "sla-below-floor",
            RuleId::NicBandwidthInsufficient => "nic-bandwidth-insufficient",
            RuleId::BatchWindowNeverOpens => "batch-window-never-opens",
            RuleId::HeadroomExceedsNodes => "headroom-exceeds-nodes",
            RuleId::ZeroReplicaFamily => "zero-replica-family",
            RuleId::QueueBoundZero => "queue-bound-zero",
            RuleId::QuantizationAccuracyBudget => "quantization-accuracy-budget",
        }
    }

    /// The rule's fixed severity.
    pub fn severity(&self) -> Severity {
        match self {
            RuleId::UnconsumedIntermediate
            | RuleId::UnreachableNode
            | RuleId::ActivationSramSpill
            | RuleId::BatchWindowNeverOpens
            | RuleId::QuantizationAccuracyBudget => Severity::Warn,
            _ => Severity::Error,
        }
    }
}

/// Where a diagnostic points: a graph node, a tensor, a plan partition, a
/// whole model, or a config field.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Span {
    Node { graph: String, node: NodeId, name: String },
    Tensor { graph: String, tensor: TensorId, name: String },
    Partition { model: String, partition: usize, card: Option<usize> },
    Model { model: String },
    Config { path: String },
}

impl Span {
    pub fn label(&self) -> String {
        match self {
            Span::Node { graph, node, name } => format!("{graph}/node {node} '{name}'"),
            Span::Tensor { graph, tensor, name } => format!("{graph}/tensor {tensor} '{name}'"),
            Span::Partition { model, partition, card } => match card {
                Some(c) => format!("{model}/partition {partition} (card {c})"),
                None => format!("{model}/partition {partition} (host)"),
            },
            Span::Model { model } => model.clone(),
            Span::Config { path } => format!("config.{path}"),
        }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// One finding: rule, severity, where, what, and (optionally) how to fix it.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: RuleId,
    pub severity: Severity,
    pub span: Span,
    pub message: String,
    pub suggestion: Option<String>,
}

impl Diagnostic {
    pub fn new(rule: RuleId, span: Span, message: impl Into<String>) -> Diagnostic {
        Diagnostic { rule, severity: rule.severity(), span, message: message.into(), suggestion: None }
    }

    /// Attach a fix suggestion (chainable).
    pub fn suggest(mut self, s: impl Into<String>) -> Diagnostic {
        self.suggestion = Some(s.into());
        self
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}] {}: {}", self.severity, self.rule.name(), self.span, self.message)
    }
}

/// A collected set of diagnostics. Rules append; nothing here fails fast —
/// [`Report::check`] converts `Error` findings into a [`Result`] at the
/// gate boundaries (`Engine::prepare`, config loading, `fbia lint`).
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Report {
        Report::default()
    }

    pub fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }

    /// Append another report's findings.
    pub fn merge(&mut self, other: Report) {
        self.diagnostics.extend(other.diagnostics);
    }

    pub fn is_empty(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warn).count()
    }

    pub fn has_errors(&self) -> bool {
        self.errors() > 0
    }

    /// All findings for one rule (test + reporting helper).
    pub fn by_rule(&self, rule: RuleId) -> Vec<&Diagnostic> {
        self.diagnostics.iter().filter(|d| d.rule == rule).collect()
    }

    /// Text rendering: one line per finding plus its suggestion.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
            if let Some(s) = &d.suggestion {
                out.push_str("  help: ");
                out.push_str(s);
                out.push('\n');
            }
        }
        out
    }

    /// Machine-readable rendering (`fbia lint --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("errors", Json::num(self.errors() as f64)),
            ("warnings", Json::num(self.warnings() as f64)),
            (
                "items",
                Json::arr(
                    self.diagnostics
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("rule", Json::str(d.rule.name())),
                                ("severity", Json::str(d.severity.name())),
                                ("span", Json::str(&d.span.label())),
                                ("message", Json::str(&d.message)),
                                (
                                    "suggestion",
                                    match &d.suggestion {
                                        Some(s) => Json::str(s),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The gate: `Err` iff any `Error`-severity finding was collected.
    pub fn check(&self, what: &str) -> Result<()> {
        if self.has_errors() {
            bail!(
                "{what}: {} lint error(s) (pass --no-lint to bypass)\n{}",
                self.errors(),
                self.render().trim_end()
            );
        }
        Ok(())
    }
}

/// Full static analysis of one builtin model under a node config: shape /
/// dtype inference plus the memory-fit proof.
pub fn lint_model(id: ModelId, cfg: &Config) -> Report {
    lint_built_graph(&id.build(), cfg)
}

/// Same as [`lint_model`] but over an already-built graph (custom batch
/// sizes, tests).
pub fn lint_built_graph(g: &Graph, cfg: &Config) -> Report {
    let mut r = shape::lint_graph(g);
    r.merge(memory::lint_memory(g, cfg));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_names_are_unique_kebab_case() {
        let mut seen = std::collections::HashSet::new();
        for rule in RuleId::ALL {
            let n = rule.name();
            assert!(seen.insert(n), "duplicate rule name {n}");
            assert!(n.chars().all(|c| c.is_ascii_lowercase() || c == '-'), "bad rule name {n}");
        }
    }

    #[test]
    fn report_counts_and_gate() {
        let mut r = Report::new();
        assert!(r.check("ok").is_ok());
        r.push(Diagnostic::new(
            RuleId::UnreachableNode,
            Span::Model { model: "m".into() },
            "dead code",
        ));
        assert_eq!((r.errors(), r.warnings()), (0, 1));
        assert!(r.check("warn-only").is_ok(), "warnings must not trip the gate");
        r.push(
            Diagnostic::new(
                RuleId::ShapeMismatch,
                Span::Config { path: "x".into() },
                "bad shape",
            )
            .suggest("fix it"),
        );
        assert!(r.has_errors());
        let err = r.check("gated").unwrap_err().to_string();
        assert!(err.contains("shape-mismatch"), "render missing rule: {err}");
        assert!(err.contains("help: fix it"), "render missing suggestion: {err}");
        let json = r.to_json().to_string();
        assert!(json.contains("\"rule\""), "json missing rule field: {json}");
    }
}
