//! Config system: typed configs for the platform, compiler, simulator and
//! serving layers, loadable from JSON files and overridable from the CLI.
//!
//! `fbia --config node.json simulate --model xlmr` style; every example and
//! bench constructs these programmatically too.

use crate::platform::{CardSpec, ClusterSpec, HostSpec, NicSpec, NodeSpec, PcieSpec};
use crate::serving::cluster::NodePolicy;
use crate::serving::fleet::{Placement, RoutePolicy};
use crate::serving::policy::{card_policy_by_name, node_policy_by_name, placement_by_name};
use crate::util::json::Json;
use crate::util::error::{bail, Context, Result};
use std::path::Path;

/// Compiler knobs (§IV-C, §VI-B) — each maps to one documented optimization
/// so the ablation benches can switch them individually.
#[derive(Debug, Clone)]
pub struct CompilerConfig {
    /// int8 quantization with fp16 fallback (§V-B). Off = all fp16.
    pub quantize_int8: bool,
    /// op-splitting parallelization across Accel Cores (§VI-B).
    pub parallelize: bool,
    /// explicit list-scheduling placement hints (§VI-B). Off = vendor default.
    pub placement_hints: bool,
    /// fraction of Accel Cores given to SLS partitions (§VI-B: 1 in 3).
    pub sls_core_fraction: f64,
    /// use profiled average lookup counts for SLS load balancing (§VI-B).
    pub sls_length_aware: bool,
    /// number of cards carrying SLS shards in the recsys scheme (Fig. 6);
    /// default = all six (every card hosts a shard + a dense replica).
    pub sls_cards: usize,
    /// graph optimizations: CSE, conversion elimination, fusion (§IV-C).
    pub graph_optimize: bool,
}

impl Default for CompilerConfig {
    fn default() -> Self {
        CompilerConfig {
            quantize_int8: true,
            parallelize: true,
            placement_hints: true,
            sls_core_fraction: 1.0 / 3.0,
            sls_length_aware: true,
            sls_cards: 6,
            graph_optimize: true,
        }
    }
}

/// System-level transfer optimizations (§VI-C), individually switchable.
#[derive(Debug, Clone)]
pub struct TransferConfig {
    /// transfer only the used prefix of statically-sized index tensors.
    pub partial_tensors: bool,
    /// combine many small transfers into one DMA.
    pub command_batching: bool,
    /// card↔card peer-to-peer instead of bouncing through the host.
    pub peer_to_peer: bool,
    /// dense features shipped fp16 (§VI-A).
    pub fp16_dense_inputs: bool,
    /// broadcast on card after a single host-side concat (§VI-A) rather
    /// than per-table broadcasts.
    pub fused_broadcast: bool,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            partial_tensors: true,
            command_batching: true,
            peer_to_peer: true,
            fp16_dense_inputs: true,
            fused_broadcast: true,
        }
    }
}

/// Serving-layer knobs (§IV-C runtime, §VI-B batching).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub max_batch: usize,
    /// max time to hold a request while forming a batch, seconds.
    pub batch_timeout_s: f64,
    /// NLP sequence buckets (§VI-A padding boundaries).
    pub seq_buckets: Vec<usize>,
    /// length-aware NLP batching: only batch same-bucket sentences (§VII).
    pub length_aware_batching: bool,
    pub worker_threads: usize,
    /// queue depth before backpressure.
    pub max_queue: usize,
    /// default within-node card-routing policy (JSON: a name from
    /// [`crate::serving::policy::CARD_POLICY_NAMES`]).
    pub card_policy: RoutePolicy,
    /// default cross-node routing policy for the cluster tier (JSON: a
    /// name from [`crate::serving::policy::NODE_POLICY_NAMES`]).
    pub node_policy: NodePolicy,
    /// default replica placement (JSON: a name from
    /// [`crate::serving::policy::PLACEMENT_NAMES`]).
    pub placement: Placement,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 32,
            batch_timeout_s: 2e-3,
            seq_buckets: vec![32, 64, 128],
            length_aware_batching: true,
            worker_threads: 6,
            max_queue: 1024,
            card_policy: RoutePolicy::LatencyAware,
            node_policy: NodePolicy::WeightedCapacity,
            placement: Placement::SlsAffine,
        }
    }
}

/// Everything together.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub node: NodeSpec,
    pub compiler: CompilerConfig,
    pub transfers: TransferConfig,
    pub serving: ServingConfig,
    /// Optional datacenter tier: N nodes behind a node-level router.
    /// `None` keeps single-node semantics (`fbia cluster` then builds a
    /// uniform tier from `node` and its own `--nodes` flag).
    pub cluster: Option<ClusterSpec>,
}

impl Config {
    /// Load from a JSON file; missing keys keep defaults (partial configs).
    pub fn from_file(path: &Path) -> Result<Config> {
        Config::from_file_with(path, true)
    }

    /// [`Config::from_file`] with the static-analysis gate switchable
    /// (`lint: false` is the CLI's `--no-lint` escape hatch).
    pub fn from_file_with(path: &Path, lint: bool) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Config::from_json_with(&json, lint)
    }

    pub fn from_json(j: &Json) -> Result<Config> {
        Config::from_json_with(j, true)
    }

    pub fn from_json_with(j: &Json, lint: bool) -> Result<Config> {
        let mut c = Config::default();
        if let Some(n) = j.get("node") {
            apply_node(&mut c.node, n)?;
        }
        if let Some(x) = j.get("compiler") {
            apply_compiler(&mut c.compiler, x);
        }
        if let Some(x) = j.get("transfers") {
            apply_transfers(&mut c.transfers, x);
        }
        if let Some(x) = j.get("serving") {
            apply_serving(&mut c.serving, x)?;
        }
        if let Some(x) = j.get("cluster") {
            c.cluster = Some(parse_cluster(x, &c.node)?);
        }
        c.validate()?;
        if lint {
            crate::analysis::lint_config(&c).check("config")?;
        }
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        validate_node("node", &self.node)?;
        if let Some(cl) = &self.cluster {
            if cl.nodes.is_empty() {
                bail!("cluster.nodes must not be empty (give cluster.count or cluster.nodes)");
            }
            for (i, n) in cl.nodes.iter().enumerate() {
                validate_node(&format!("cluster.nodes[{i}]"), n)?;
            }
            // at least one node must carry load: a tier that is all
            // headroom has no capacity to plan around
            if cl.headroom >= cl.nodes.len() {
                bail!(
                    "cluster.headroom ({}) must be smaller than the cluster node count ({})",
                    cl.headroom,
                    cl.nodes.len()
                );
            }
        }
        if self.compiler.sls_cards > self.node.cards {
            bail!(
                "compiler.sls_cards ({}) exceeds node.cards ({})",
                self.compiler.sls_cards,
                self.node.cards
            );
        }
        if !(0.0..=1.0).contains(&self.compiler.sls_core_fraction) {
            bail!("sls_core_fraction must be in [0,1]");
        }
        if self.serving.max_batch == 0 || self.serving.worker_threads == 0 {
            bail!("serving.max_batch and worker_threads must be > 0");
        }
        let mut b = self.serving.seq_buckets.clone();
        b.sort_unstable();
        if b != self.serving.seq_buckets || b.is_empty() {
            bail!("serving.seq_buckets must be non-empty and ascending");
        }
        Ok(())
    }
}

/// Validate one node description; `path` names it in error messages
/// ("node", or "cluster.nodes[i]" for tier members).
fn validate_node(path: &str, n: &NodeSpec) -> Result<()> {
    if n.cards == 0 {
        bail!("{path}.cards must be > 0");
    }
    if let Some((id, _)) = n.card_overrides.iter().find(|(id, _)| *id >= n.cards) {
        bail!("{path}.card_overrides names card {id} but the node has {} cards", n.cards);
    }
    // first match wins in NodeSpec::card_spec, so a duplicate slot
    // would silently drop the later entry — reject it instead
    for (i, (id, _)) in n.card_overrides.iter().enumerate() {
        if n.card_overrides[..i].iter().any(|(j, _)| j == id) {
            bail!("{path}.card_overrides lists card {id} more than once");
        }
    }
    // a zero-bandwidth NIC makes every modeled ingress take forever — the
    // cluster tier serializes request bytes on this link
    if !(n.nic.bw_bits > 0.0) {
        bail!("{path}.nic.bw_bits must be positive (got {})", n.nic.bw_bits);
    }
    Ok(())
}

fn f(j: &Json, key: &str, cur: f64) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(cur)
}

fn u(j: &Json, key: &str, cur: usize) -> usize {
    j.get(key).and_then(Json::as_usize).unwrap_or(cur)
}

fn b(j: &Json, key: &str, cur: bool) -> bool {
    j.get(key).and_then(Json::as_bool).unwrap_or(cur)
}

/// One card description on top of a base spec; fields not present keep the
/// base values (shared by `node.card` and each `node.card_overrides` entry).
fn card_from_json(c: &Json, base: &CardSpec) -> CardSpec {
    CardSpec {
        accel_cores: u(c, "accel_cores", base.accel_cores),
        peak_tops_int8: f(c, "peak_tops_int8", base.peak_tops_int8),
        peak_tflops_fp16: f(c, "peak_tflops_fp16", base.peak_tflops_fp16),
        lpddr_bytes: u(c, "lpddr_bytes", base.lpddr_bytes),
        lpddr_bw: f(c, "lpddr_bw", base.lpddr_bw),
        sram_per_core: u(c, "sram_per_core", base.sram_per_core),
        shared_cache: u(c, "shared_cache", base.shared_cache),
        sram_bw: f(c, "sram_bw", base.sram_bw),
        power_w: f(c, "power_w", base.power_w),
        pcie_lanes: u(c, "pcie_lanes", base.pcie_lanes),
    }
}

fn apply_node(n: &mut NodeSpec, j: &Json) -> Result<()> {
    n.cards = u(j, "cards", n.cards);
    if let Some(c) = j.get("card") {
        n.card = card_from_json(c, &CardSpec::default());
    }
    // vendor-mix node: per-slot overrides on top of the (possibly custom)
    // base card; each entry names its slot with "card"
    if let Some(arr) = j.get("card_overrides").and_then(Json::as_arr) {
        for o in arr {
            let id = o
                .get("card")
                .and_then(Json::as_usize)
                .context("node.card_overrides entries need a \"card\" slot index")?;
            let spec = card_from_json(o, &n.card);
            n.card_overrides.push((id, spec));
        }
    }
    if let Some(h) = j.get("host") {
        let d = HostSpec::default();
        n.host = HostSpec {
            cores: u(h, "cores", d.cores),
            mem_bytes: u(h, "mem_bytes", d.mem_bytes),
            mem_bw: f(h, "mem_bw", d.mem_bw),
            gflops: f(h, "gflops", d.gflops),
        };
    }
    if let Some(p) = j.get("pcie") {
        let d = PcieSpec::default();
        n.pcie = PcieSpec {
            lane_bw: f(p, "lane_bw", d.lane_bw),
            host_lanes: u(p, "host_lanes", d.host_lanes),
            switch_power_w: f(p, "switch_power_w", d.switch_power_w),
            transfer_overhead_s: f(p, "transfer_overhead_s", d.transfer_overhead_s),
        };
    }
    if let Some(nic) = j.get("nic") {
        n.nic = NicSpec { bw_bits: f(nic, "bw_bits", NicSpec::default().bw_bits) };
    }
    Ok(())
}

/// Cluster tier: either `count` copies of the base node or an explicit
/// `nodes` list. Each list entry is a full node description parsed on top
/// of the (possibly customized) base `node`, so a heterogeneous tier only
/// states its differences — e.g. `{"cards": 4, "nic": {"bw_bits": 25e9}}`.
fn parse_cluster(j: &Json, base: &NodeSpec) -> Result<ClusterSpec> {
    let nodes = if let Some(arr) = j.get("nodes").and_then(Json::as_arr) {
        let mut nodes = Vec::with_capacity(arr.len());
        for (i, entry) in arr.iter().enumerate() {
            let mut spec = base.clone();
            apply_node(&mut spec, entry).with_context(|| format!("cluster.nodes[{i}]"))?;
            nodes.push(spec);
        }
        nodes
    } else {
        let count = u(j, "count", 0);
        if count == 0 {
            bail!("cluster.count must be > 0 (or give an explicit cluster.nodes list)");
        }
        vec![base.clone(); count]
    };
    // default: one node of failure headroom — but a single-node tier has
    // none to give, and the user should not be rejected over a key they
    // never wrote (explicit "headroom": 1 on one node still errors)
    let headroom = u(j, "headroom", usize::from(nodes.len() > 1));
    Ok(ClusterSpec { nodes, headroom })
}

fn apply_compiler(c: &mut CompilerConfig, j: &Json) {
    c.quantize_int8 = b(j, "quantize_int8", c.quantize_int8);
    c.parallelize = b(j, "parallelize", c.parallelize);
    c.placement_hints = b(j, "placement_hints", c.placement_hints);
    c.sls_core_fraction = f(j, "sls_core_fraction", c.sls_core_fraction);
    c.sls_length_aware = b(j, "sls_length_aware", c.sls_length_aware);
    c.sls_cards = u(j, "sls_cards", c.sls_cards);
    c.graph_optimize = b(j, "graph_optimize", c.graph_optimize);
}

fn apply_transfers(t: &mut TransferConfig, j: &Json) {
    t.partial_tensors = b(j, "partial_tensors", t.partial_tensors);
    t.command_batching = b(j, "command_batching", t.command_batching);
    t.peer_to_peer = b(j, "peer_to_peer", t.peer_to_peer);
    t.fp16_dense_inputs = b(j, "fp16_dense_inputs", t.fp16_dense_inputs);
    t.fused_broadcast = b(j, "fused_broadcast", t.fused_broadcast);
}

fn apply_serving(s: &mut ServingConfig, j: &Json) -> Result<()> {
    s.max_batch = u(j, "max_batch", s.max_batch);
    s.batch_timeout_s = f(j, "batch_timeout_s", s.batch_timeout_s);
    s.length_aware_batching = b(j, "length_aware_batching", s.length_aware_batching);
    s.worker_threads = u(j, "worker_threads", s.worker_threads);
    s.max_queue = u(j, "max_queue", s.max_queue);
    if let Some(arr) = j.get("seq_buckets").and_then(Json::as_arr) {
        s.seq_buckets = arr
            .iter()
            .map(|v| v.as_usize().context("seq_buckets entries must be usize"))
            .collect::<Result<_>>()?;
    }
    // routing/placement policies resolve through the shared registry so a
    // typo'd config name fails listing the valid set, same as the CLI
    if let Some(v) = j.get("card_policy") {
        let name = v.as_str().context("serving.card_policy must be a string")?;
        s.card_policy = card_policy_by_name(name).context("serving.card_policy")?;
    }
    if let Some(v) = j.get("node_policy") {
        let name = v.as_str().context("serving.node_policy must be a string")?;
        s.node_policy = node_policy_by_name(name).context("serving.node_policy")?;
    }
    if let Some(v) = j.get("placement") {
        let name = v.as_str().context("serving.placement must be a string")?;
        s.placement = placement_by_name(name).context("serving.placement")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn partial_json_overrides() {
        let j = Json::parse(
            r#"{"node": {"cards": 4, "card": {"peak_tops_int8": 30}},
                "compiler": {"sls_cards": 2, "quantize_int8": false},
                "serving": {"seq_buckets": [16, 32]}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.node.cards, 4);
        assert_eq!(c.node.card.peak_tops_int8, 30.0);
        assert!(!c.compiler.quantize_int8);
        assert_eq!(c.compiler.sls_cards, 2);
        assert_eq!(c.serving.seq_buckets, vec![16, 32]);
        // untouched fields keep defaults
        assert_eq!(c.node.card.accel_cores, 12);
        assert!(c.transfers.peer_to_peer);
    }

    #[test]
    fn card_overrides_parse_on_top_of_base_card() {
        let j = Json::parse(
            r#"{"node": {"cards": 4, "card": {"peak_tops_int8": 30},
                "card_overrides": [{"card": 3, "peak_tops_int8": 12, "power_w": 7}]}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.node.card_spec(0).peak_tops_int8, 30.0);
        assert_eq!(c.node.card_spec(3).peak_tops_int8, 12.0);
        assert_eq!(c.node.card_spec(3).power_w, 7.0);
        // unnamed fields of the override inherit the custom base card
        assert_eq!(c.node.card_spec(3).accel_cores, c.node.card.accel_cores);
        // an override outside the node is rejected
        let j = Json::parse(
            r#"{"node": {"cards": 2, "card_overrides": [{"card": 5, "power_w": 7}]}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err());
        // and so is an entry without a slot index
        let j =
            Json::parse(r#"{"node": {"card_overrides": [{"power_w": 7}]}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        // duplicate slots would silently drop the later entry: rejected
        let j = Json::parse(
            r#"{"node": {"card_overrides": [{"card": 1, "power_w": 7},
                                            {"card": 1, "peak_tops_int8": 12}]}}"#,
        )
        .unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn cluster_spec_parses_uniform_and_heterogeneous_tiers() {
        // count replicates the (customized) base node
        let j = Json::parse(
            r#"{"node": {"cards": 4}, "cluster": {"count": 3, "headroom": 1}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        let cl = c.cluster.as_ref().unwrap();
        assert_eq!(cl.nodes.len(), 3);
        assert_eq!(cl.headroom, 1);
        assert!(cl.nodes.iter().all(|n| n.cards == 4));
        // explicit nodes state only their differences from the base node
        let j = Json::parse(
            r#"{"node": {"cards": 6},
                "cluster": {"headroom": 1, "nodes": [
                    {},
                    {"cards": 2, "nic": {"bw_bits": 25e9}}]}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        let cl = c.cluster.as_ref().unwrap();
        assert_eq!(cl.nodes.len(), 2);
        assert_eq!(cl.nodes[0].cards, 6);
        assert_eq!(cl.nodes[1].cards, 2);
        assert_eq!(cl.nodes[1].nic.bw_bits, 25e9);
        assert_eq!(cl.nodes[0].nic.bw_bits, 50e9);
        // no cluster key: cluster stays None
        assert!(Config::from_json(&Json::parse("{}").unwrap()).unwrap().cluster.is_none());
    }

    #[test]
    fn cluster_spec_errors_name_the_offending_field() {
        let err_of = |s: &str| Config::from_json(&Json::parse(s).unwrap()).unwrap_err().to_string();
        // bad node counts
        let e = err_of(r#"{"cluster": {"count": 0}}"#);
        assert!(e.contains("cluster.count"), "{e}");
        let e = err_of(r#"{"cluster": {"nodes": []}}"#);
        assert!(e.contains("cluster.nodes"), "{e}");
        let e = err_of(r#"{"cluster": {"nodes": [{"cards": 0}]}}"#);
        assert!(e.contains("cluster.nodes[0].cards"), "{e}");
        // zero NIC bandwidth, on a tier member and on the base node
        let e = err_of(r#"{"cluster": {"nodes": [{}, {"nic": {"bw_bits": 0}}]}}"#);
        assert!(e.contains("cluster.nodes[1].nic.bw_bits"), "{e}");
        let e = err_of(r#"{"node": {"nic": {"bw_bits": -1}}}"#);
        assert!(e.contains("node.nic.bw_bits"), "{e}");
        // headroom >= node count
        let e = err_of(r#"{"cluster": {"count": 2, "headroom": 2}}"#);
        assert!(e.contains("cluster.headroom"), "{e}");
        assert!(e.contains('2'), "{e}");
        let e = err_of(r#"{"cluster": {"count": 1, "headroom": 1}}"#);
        assert!(e.contains("cluster.headroom"), "{e}");
        // ...but a single-node tier without an explicit headroom is fine
        // (the default headroom only applies when there is a node to spare)
        let c = Config::from_json(&Json::parse(r#"{"cluster": {"count": 1}}"#).unwrap()).unwrap();
        assert_eq!(c.cluster.as_ref().unwrap().headroom, 0);
        // per-member card overrides are validated with the member's path
        let e = err_of(
            r#"{"cluster": {"nodes": [{"cards": 2, "card_overrides": [{"card": 5}]}]}}"#,
        );
        assert!(e.contains("cluster.nodes[0].card_overrides"), "{e}");
    }

    #[test]
    fn serving_policies_parse_through_the_registry() {
        let j = Json::parse(
            r#"{"serving": {"card_policy": "rr", "node_policy": "jsq",
                            "placement": "spread"}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.serving.card_policy, RoutePolicy::RoundRobin);
        assert_eq!(c.serving.node_policy, NodePolicy::JoinShortestQueue);
        assert_eq!(c.serving.placement, Placement::Spread);
        // untouched policies keep their defaults
        assert_eq!(c.serving.card_policy.name(), "round-robin");
        let d = Config::default();
        assert_eq!(d.serving.card_policy, RoutePolicy::LatencyAware);
        assert_eq!(d.serving.node_policy, NodePolicy::WeightedCapacity);
        assert_eq!(d.serving.placement, Placement::SlsAffine);
        // unknown names error with the config path and the valid set
        let e = Config::from_json(
            &Json::parse(r#"{"serving": {"card_policy": "bogus"}}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("serving.card_policy") && e.contains("latency-aware"), "{e}");
        // non-string values are rejected, not coerced
        let e = Config::from_json(
            &Json::parse(r#"{"serving": {"placement": 3}}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("must be a string"), "{e}");
    }

    #[test]
    fn invalid_configs_rejected() {
        let j = Json::parse(r#"{"node": {"cards": 2}, "compiler": {"sls_cards": 5}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        let j = Json::parse(r#"{"serving": {"seq_buckets": [64, 32]}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        let j = Json::parse(r#"{"node": {"cards": 0}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }
}
