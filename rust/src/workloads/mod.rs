//! Workload generators (§II): seeded, distribution-faithful request streams
//! for all four model classes. The recsys generator uses Zipf-distributed
//! table popularity and variable lookup counts — the properties behind the
//! paper's partial-tensor and SLS-load-balancing optimizations.

use crate::numerics::HostTensor;
use crate::util::rng::Rng;

/// Fraction of `max_lookups` a request actually uses on average — the
/// Poisson mean of [`RecsysGen`] and the partial-tensor traffic assumption
/// of the sim backend's PCIe model; keeping it in one place keeps the
/// modeled upload bytes in sync with the generated request distribution.
pub const AVG_LOOKUP_FRACTION: f64 = 0.4;

/// One recommendation request: dense features + per-table sparse lookups,
/// already padded to `max_lookups` (the static-shape contract, §VI-C).
#[derive(Debug, Clone)]
pub struct RecsysRequest {
    pub dense: HostTensor,
    /// per table: indices [batch, max_lookups] i32
    pub indices: Vec<HostTensor>,
    /// per table: lengths [batch] i32
    pub lengths: Vec<HostTensor>,
}

/// Recsys request generator.
pub struct RecsysGen {
    pub batch: usize,
    pub num_tables: usize,
    pub rows_per_table: usize,
    pub dense_in: usize,
    pub max_lookups: usize,
    /// mean lookup count per bag.
    pub mean_lookups: f64,
    pub zipf_s: f64,
    rng: Rng,
}

impl RecsysGen {
    /// Build a generator matching a manifest's DLRM config (the shape every
    /// server/bench/test needs — one place instead of four config lookups
    /// at each call site).
    pub fn from_manifest(
        seed: u64,
        batch: usize,
        m: &crate::runtime::artifact::Manifest,
    ) -> crate::util::error::Result<RecsysGen> {
        Ok(RecsysGen::new(
            seed,
            batch,
            m.config_usize("dlrm", "num_tables")?,
            m.config_usize("dlrm", "rows_per_table")?,
            m.config_usize("dlrm", "dense_in")?,
            m.config_usize("dlrm", "max_lookups")?,
        ))
    }

    pub fn new(seed: u64, batch: usize, num_tables: usize, rows_per_table: usize,
               dense_in: usize, max_lookups: usize) -> Self {
        RecsysGen {
            batch,
            num_tables,
            rows_per_table,
            dense_in,
            max_lookups,
            mean_lookups: max_lookups as f64 * AVG_LOOKUP_FRACTION,
            zipf_s: 1.2,
            rng: Rng::new(seed),
        }
    }

    pub fn next(&mut self) -> RecsysRequest {
        let mut dense = vec![0f32; self.batch * self.dense_in];
        self.rng.fill_normal_f32(&mut dense, 1.0);
        let mut indices = Vec::with_capacity(self.num_tables);
        let mut lengths = Vec::with_capacity(self.num_tables);
        for _ in 0..self.num_tables {
            let mut idx = vec![0i32; self.batch * self.max_lookups];
            let mut len = vec![0i32; self.batch];
            for b in 0..self.batch {
                let l = (self.rng.poisson(self.mean_lookups) as usize).min(self.max_lookups);
                len[b] = l as i32;
                for j in 0..l {
                    // Zipf-skewed row popularity (§II-A: hot entries dominate)
                    idx[b * self.max_lookups + j] =
                        self.rng.zipf(self.rows_per_table as u64, self.zipf_s) as i32;
                }
            }
            indices.push(HostTensor::i32(idx, &[self.batch, self.max_lookups]));
            lengths.push(HostTensor::i32(len, &[self.batch]));
        }
        RecsysRequest {
            dense: HostTensor::f32(dense, &[self.batch, self.dense_in]),
            indices,
            lengths,
        }
    }
}

/// One NLP sentence (token ids, true length before padding).
#[derive(Debug, Clone)]
pub struct NlpRequest {
    pub tokens: Vec<i32>,
    pub arrival_s: f64,
}

/// NLP sentence generator with the paper's skew: lengths mostly 20–70
/// tokens (§II-C), long tail to `max_len`.
pub struct NlpGen {
    pub vocab: usize,
    pub max_len: usize,
    rng: Rng,
    clock: f64,
    pub rate: f64,
}

impl NlpGen {
    pub fn new(seed: u64, vocab: usize, max_len: usize, rate: f64) -> Self {
        NlpGen { vocab, max_len, rng: Rng::new(seed), clock: 0.0, rate }
    }

    pub fn sample_len(&mut self) -> usize {
        // log-normal-ish: exp(N(3.6, 0.5)) ~ median 36, bulk 20-70
        let l = (3.6 + 0.5 * self.rng.normal()).exp();
        (l.round() as usize).clamp(1, self.max_len)
    }

    pub fn next(&mut self) -> NlpRequest {
        let n = self.sample_len();
        let tokens = (0..n).map(|_| self.rng.below(self.vocab as u64) as i32).collect();
        self.clock += self.rng.exponential(self.rate);
        NlpRequest { tokens, arrival_s: self.clock }
    }
}

/// One CV image request.
#[derive(Debug, Clone)]
pub struct CvRequest {
    pub image: HostTensor,
}

pub struct CvGen {
    pub image: usize,
    rng: Rng,
}

impl CvGen {
    pub fn new(seed: u64, image: usize) -> Self {
        CvGen { image, rng: Rng::new(seed) }
    }

    pub fn next(&mut self, batch: usize) -> CvRequest {
        let n = batch * self.image * self.image * 3;
        let mut v = vec![0f32; n];
        // pixel-ish values in [0, 1)
        for x in v.iter_mut() {
            *x = self.rng.f32();
        }
        CvRequest { image: HostTensor::f32(v, &[batch, self.image, self.image, 3]) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recsys_lengths_within_bounds() {
        let mut g = RecsysGen::new(1, 8, 4, 1000, 16, 32);
        for _ in 0..5 {
            let r = g.next();
            assert_eq!(r.indices.len(), 4);
            for (idx, len) in r.indices.iter().zip(&r.lengths) {
                for (b, &l) in len.as_i32().unwrap().iter().enumerate() {
                    assert!(l >= 0 && l as usize <= 32);
                    for j in 0..l as usize {
                        let v = idx.as_i32().unwrap()[b * 32 + j];
                        assert!(v >= 0 && (v as usize) < 1000);
                    }
                }
            }
        }
    }

    #[test]
    fn recsys_deterministic() {
        let mut a = RecsysGen::new(7, 4, 2, 100, 8, 8);
        let mut b = RecsysGen::new(7, 4, 2, 100, 8, 8);
        assert_eq!(a.next().dense, b.next().dense);
    }

    #[test]
    fn nlp_lengths_mostly_20_70() {
        let mut g = NlpGen::new(3, 1000, 512, 100.0);
        let lens: Vec<usize> = (0..2000).map(|_| g.sample_len()).collect();
        let in_range = lens.iter().filter(|&&l| (15..=90).contains(&l)).count();
        assert!(in_range as f64 / 2000.0 > 0.6, "{in_range}");
        assert!(lens.iter().all(|&l| l >= 1 && l <= 512));
    }

    #[test]
    fn nlp_arrivals_monotone() {
        let mut g = NlpGen::new(5, 100, 128, 50.0);
        let mut last = 0.0;
        for _ in 0..100 {
            let r = g.next();
            assert!(r.arrival_s > last);
            last = r.arrival_s;
        }
    }

    #[test]
    fn cv_pixels_in_unit_range() {
        let mut g = CvGen::new(9, 16);
        let r = g.next(2);
        assert_eq!(r.image.shape(), &[2, 16, 16, 3]);
        assert!(r.image.as_f32().unwrap().iter().all(|&v| (0.0..1.0).contains(&v)));
    }
}
