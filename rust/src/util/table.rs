//! Paper-style ASCII table printer: every bench target prints the same rows
//! the paper reports, via this helper, so "regenerating Table II" is a
//! single readable block in bench output.

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        let sep = {
            let mut line = String::from("+");
            for w in &widths {
                line.push_str(&"-".repeat(w + 2));
                line.push('+');
            }
            line
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_string());
    }
}

/// Format helpers shared by bench targets.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn ms(x_seconds: f64) -> String {
    format!("{:.2} ms", x_seconds * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["op", "share"]);
        t.row_strs(&["FC", "30.9%"]);
        t.row_strs(&["SparseLengthsSum", "27.0%"]);
        let s = t.to_string();
        assert!(s.contains("| FC               | 30.9% |"), "{s}");
        assert!(s.lines().count() >= 6);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn helpers() {
        assert_eq!(pct(0.305), "30.5%");
        assert_eq!(ms(0.0123), "12.30 ms");
    }
}
