//! Bench harness (criterion is unavailable offline).
//!
//! Two responsibilities:
//! 1. timing: warmup + repeated measurement with mean/std/min reporting;
//! 2. paper-style reporting: every bench target regenerates the rows/series
//!    of one paper table or figure (DESIGN.md §5) via [`crate::util::table`].

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f`, auto-scaling iteration count to hit ~`target_s` of total
/// measurement after `warmup` calls.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, 3, 0.5, &mut f)
}

/// Fully parameterized variant.
pub fn bench_with<F: FnMut()>(name: &str, warmup: usize, target_s: f64, f: &mut F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once).ceil() as usize).clamp(3, 10_000);

    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.add(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        std_s: s.std(),
        min_s: s.min(),
    }
}

/// Pretty-print a timing result in bench output style.
pub fn report(r: &BenchResult) {
    println!(
        "  {:<44} {:>12} {:>12} {:>10}  ({} iters)",
        r.name,
        fmt_time(r.mean_s),
        fmt_time(r.min_s),
        format!("±{:.1}%", 100.0 * r.std_s / r.mean_s.max(1e-12)),
        r.iters
    );
}

/// Human-friendly seconds formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Section header used by all bench binaries for a consistent look.
pub fn section(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// The shared `BENCH_*.json` schema: every emitter (`fig7` bench,
/// `fbia fleet --json`, `fbia cluster --json`, `fbia des --json`) writes
/// the same top-level fields so PR-over-PR trend tooling can diff the
/// files without per-bench parsing. Detail payloads (policy sweeps,
/// per-card tables, capacity plans) nest under emitter-specific `extra`
/// keys; the headline numbers and acceptance flags always live at the top
/// level.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Bench identity ("fig7_latency_qps", "fleet_smoke", ...).
    pub name: String,
    /// Backend that produced the numbers ("ref" | "sim" | "pjrt").
    pub backend: String,
    /// Clock the numbers are on ("wall" | "modeled").
    pub clock: String,
    /// Requests offered / completed / shed (conservation:
    /// completed + shed == offered).
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// Headline throughput and tail latency.
    pub qps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Named acceptance checks ("la_beats_rr", "all_within_budget", ...);
    /// the CI gates read these.
    pub acceptance: Vec<(String, bool)>,
    /// Emitter-specific detail, merged into the object as-is.
    pub extra: Vec<(String, Json)>,
}

impl BenchReport {
    /// A report skeleton; fill the metric fields then call
    /// [`BenchReport::to_json`] / [`BenchReport::write`].
    pub fn new(name: &str, backend: &str, clock: &str) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            backend: backend.to_string(),
            clock: clock.to_string(),
            offered: 0,
            completed: 0,
            shed: 0,
            qps: 0.0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            acceptance: Vec::new(),
            extra: Vec::new(),
        }
    }

    /// Record one named acceptance flag (chainable).
    pub fn accept(mut self, check: &str, holds: bool) -> BenchReport {
        self.acceptance.push((check.to_string(), holds));
        self
    }

    /// Attach one emitter-specific detail field (chainable).
    pub fn with(mut self, key: &str, value: Json) -> BenchReport {
        self.extra.push((key.to_string(), value));
        self
    }

    /// Render the shared schema. `shed_rate` and the acceptance map are
    /// derived here so every emitter agrees on their definitions.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("bench".to_string(), Json::str(&self.name)),
            ("backend".to_string(), Json::str(&self.backend)),
            ("clock".to_string(), Json::str(&self.clock)),
            ("offered".to_string(), Json::num(self.offered as f64)),
            ("completed".to_string(), Json::num(self.completed as f64)),
            ("shed".to_string(), Json::num(self.shed as f64)),
            (
                "shed_rate".to_string(),
                Json::num(self.shed as f64 / (self.offered as f64).max(1.0)),
            ),
            ("qps".to_string(), Json::num(self.qps)),
            ("p50_ms".to_string(), Json::num(self.p50_ms)),
            ("p99_ms".to_string(), Json::num(self.p99_ms)),
            (
                "acceptance".to_string(),
                Json::Obj(
                    self.acceptance
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Bool(*v)))
                        .collect(),
                ),
            ),
        ];
        for (k, v) in &self.extra {
            fields.push((k.clone(), v.clone()));
        }
        Json::Obj(fields.into_iter().collect())
    }

    /// Write the report to `path` (the `--json` flag's sink).
    pub fn write(&self, path: &str) -> crate::util::error::Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| crate::util::error::err!("writing {path}: {e}"))?;
        println!("wrote {path}");
        Ok(())
    }
}

/// Regression gating: diff a fresh `BENCH_*.json` against a committed
/// baseline with per-metric direction-aware tolerances (`fbia bench-diff`,
/// the blocking CI step).
///
/// Semantics: every metric that a [`Tolerances`] rule names **and** the
/// baseline contains is checked; baselines may therefore be partial (pin
/// only what is known-stable) and grow as maintainers refresh them from
/// green CI artifacts. Improvements always pass — only movement in the
/// regression direction counts against the tolerance. Acceptance flags are
/// one-way: a flag that is `true` in the baseline must still be `true` in
/// the fresh report.
pub mod compare {
    use crate::util::error::{err, Result};
    use crate::util::json::Json;

    /// Which direction of movement is a regression.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Direction {
        /// Throughput-like: smaller is a regression.
        HigherIsBetter,
        /// Latency/shed-like: larger is a regression.
        LowerIsBetter,
        /// Identity-like (workload size): any difference is a regression.
        Exact,
    }

    /// One metric's gate: regression direction plus tolerance. A fresh
    /// value regressing by more than `abs_tol + rel_tol * |baseline|`
    /// fails the gate.
    #[derive(Debug, Clone)]
    pub struct MetricRule {
        pub metric: String,
        pub direction: Direction,
        pub rel_tol: f64,
        pub abs_tol: f64,
    }

    /// The rule set applied by a diff. [`Tolerances::default`] covers the
    /// shared `BENCH_*.json` schema:
    ///
    /// | metric      | direction | rel    | abs   |
    /// |-------------|-----------|--------|-------|
    /// | `offered`   | exact     | —      | —     |
    /// | `completed` | higher    | 2%     | 2     |
    /// | `shed`      | lower     | 2%     | 2     |
    /// | `shed_rate` | lower     | 5%     | 0.005 |
    /// | `qps`       | higher    | 5%     | 0     |
    /// | `p50_ms`    | lower     | 5%     | 0.05  |
    /// | `p99_ms`    | lower     | 5%     | 0.05  |
    ///
    /// The small absolute slacks keep near-zero baselines (a handful of
    /// shed requests, sub-ms latencies) from failing on one-count wiggle.
    #[derive(Debug, Clone)]
    pub struct Tolerances {
        pub rules: Vec<MetricRule>,
    }

    impl Default for Tolerances {
        fn default() -> Tolerances {
            let rule = |metric: &str, direction: Direction, rel_tol: f64, abs_tol: f64| {
                MetricRule { metric: metric.to_string(), direction, rel_tol, abs_tol }
            };
            Tolerances {
                rules: vec![
                    rule("offered", Direction::Exact, 0.0, 0.0),
                    rule("completed", Direction::HigherIsBetter, 0.02, 2.0),
                    rule("shed", Direction::LowerIsBetter, 0.02, 2.0),
                    rule("shed_rate", Direction::LowerIsBetter, 0.05, 0.005),
                    rule("qps", Direction::HigherIsBetter, 0.05, 0.0),
                    rule("p50_ms", Direction::LowerIsBetter, 0.05, 0.05),
                    rule("p99_ms", Direction::LowerIsBetter, 0.05, 0.05),
                ],
            }
        }
    }

    impl Tolerances {
        /// Override one metric's relative tolerance (CLI `--tol`). Errors
        /// on a metric no rule covers, so typos don't silently un-gate.
        pub fn set_rel(&mut self, metric: &str, rel_tol: f64) -> Result<()> {
            match self.rules.iter_mut().find(|r| r.metric == metric) {
                Some(r) => {
                    r.rel_tol = rel_tol;
                    Ok(())
                }
                None => Err(err!(
                    "no tolerance rule for metric '{metric}' (known: {})",
                    self.rules.iter().map(|r| r.metric.as_str()).collect::<Vec<_>>().join(", ")
                )),
            }
        }
    }

    /// One checked metric's outcome.
    #[derive(Debug, Clone)]
    pub struct MetricDiff {
        pub metric: String,
        pub base: f64,
        pub fresh: f64,
        /// Signed relative change, `(fresh - base) / |base|`.
        pub delta_rel: f64,
        pub within: bool,
    }

    /// The full verdict for one bench file pair.
    #[derive(Debug, Clone)]
    pub struct DiffReport {
        pub bench: String,
        pub metrics: Vec<MetricDiff>,
        /// Acceptance flags true in the baseline but not in the fresh run.
        pub flag_regressions: Vec<String>,
        /// Metrics the baseline pins but the fresh report lacks.
        pub missing: Vec<String>,
    }

    impl DiffReport {
        pub fn pass(&self) -> bool {
            self.missing.is_empty()
                && self.flag_regressions.is_empty()
                && self.metrics.iter().all(|m| m.within)
        }

        /// Human-readable failure lines (empty when passing).
        pub fn failures(&self) -> Vec<String> {
            let mut out = Vec::new();
            for m in &self.metrics {
                if !m.within {
                    out.push(format!(
                        "{}: {} regressed {:.6} -> {:.6} ({:+.1}%)",
                        self.bench,
                        m.metric,
                        m.base,
                        m.fresh,
                        100.0 * m.delta_rel
                    ));
                }
            }
            for f in &self.flag_regressions {
                out.push(format!("{}: acceptance flag '{f}' no longer holds", self.bench));
            }
            for m in &self.missing {
                out.push(format!("{}: metric '{m}' pinned by baseline but absent", self.bench));
            }
            out
        }

        pub fn to_json(&self) -> Json {
            Json::obj(vec![
                ("bench", Json::str(&self.bench)),
                ("pass", Json::Bool(self.pass())),
                (
                    "metrics",
                    Json::arr(
                        self.metrics
                            .iter()
                            .map(|m| {
                                Json::obj(vec![
                                    ("metric", Json::str(&m.metric)),
                                    ("base", Json::num(m.base)),
                                    ("fresh", Json::num(m.fresh)),
                                    ("delta_rel", Json::num(m.delta_rel)),
                                    ("within", Json::Bool(m.within)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "flag_regressions",
                    Json::arr(self.flag_regressions.iter().map(|f| Json::str(f)).collect()),
                ),
                ("missing", Json::arr(self.missing.iter().map(|m| Json::str(m)).collect())),
            ])
        }
    }

    /// Diff `fresh` against `baseline` (both parsed `BENCH_*.json`
    /// objects) under `tol`. Errors only on malformed inputs (missing
    /// `bench` field, mismatched bench identities) — regressions are
    /// reported in the returned [`DiffReport`], not as errors.
    pub fn compare(baseline: &Json, fresh: &Json, tol: &Tolerances) -> Result<DiffReport> {
        let bench = baseline
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("baseline has no 'bench' field"))?
            .to_string();
        let fresh_bench = fresh
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("fresh report has no 'bench' field"))?;
        if fresh_bench != bench {
            return Err(err!(
                "bench identity mismatch: baseline is '{bench}', fresh is '{fresh_bench}'"
            ));
        }
        let mut metrics = Vec::new();
        let mut missing = Vec::new();
        for rule in &tol.rules {
            let Some(base) = baseline.get(&rule.metric).and_then(Json::as_f64) else {
                continue; // baseline doesn't pin this metric
            };
            let Some(fresh_v) = fresh.get(&rule.metric).and_then(Json::as_f64) else {
                missing.push(rule.metric.clone());
                continue;
            };
            let worse = match rule.direction {
                Direction::HigherIsBetter => base - fresh_v,
                Direction::LowerIsBetter => fresh_v - base,
                Direction::Exact => (fresh_v - base).abs(),
            };
            let within = worse <= rule.abs_tol + rule.rel_tol * base.abs();
            metrics.push(MetricDiff {
                metric: rule.metric.clone(),
                base,
                fresh: fresh_v,
                delta_rel: (fresh_v - base) / base.abs().max(1e-12),
                within,
            });
        }
        let mut flag_regressions = Vec::new();
        if let Some(flags) = baseline.get("acceptance").and_then(Json::as_obj) {
            for (name, holds) in flags {
                if holds.as_bool() != Some(true) {
                    continue;
                }
                let still = fresh
                    .path(&format!("acceptance.{name}"))
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                if !still {
                    flag_regressions.push(name.clone());
                }
            }
        }
        Ok(DiffReport { bench, metrics, flag_regressions, missing })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench_with("noop-ish", 1, 0.02, &mut || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-9);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    fn sample_report() -> Json {
        let mut r = BenchReport::new("unit_diff", "sim", "modeled").accept("conserves", true);
        r.offered = 1000;
        r.completed = 980;
        r.shed = 20;
        r.qps = 5000.0;
        r.p50_ms = 4.0;
        r.p99_ms = 9.0;
        r.to_json()
    }

    fn with_metric(mut j: Json, key: &str, v: f64) -> Json {
        if let Json::Obj(m) = &mut j {
            m.insert(key.to_string(), Json::num(v));
        }
        j
    }

    #[test]
    fn diff_passes_on_identical_and_improved_reports() {
        let base = sample_report();
        let tol = compare::Tolerances::default();
        let same = compare::compare(&base, &base, &tol).unwrap();
        assert!(same.pass(), "identical report must pass: {:?}", same.failures());
        // Improvements in every direction-aware metric also pass.
        let better = with_metric(
            with_metric(with_metric(base.clone(), "qps", 9000.0), "p99_ms", 2.0),
            "shed",
            0.0,
        );
        let d = compare::compare(&base, &better, &tol).unwrap();
        assert!(d.pass(), "improvements must pass: {:?}", d.failures());
    }

    #[test]
    fn diff_fails_on_ten_percent_qps_regression() {
        let base = sample_report();
        let fresh = with_metric(base.clone(), "qps", 5000.0 * 0.89);
        let d = compare::compare(&base, &fresh, &compare::Tolerances::default()).unwrap();
        assert!(!d.pass());
        let qps = d.metrics.iter().find(|m| m.metric == "qps").unwrap();
        assert!(!qps.within);
        assert!(d.failures().iter().any(|f| f.contains("qps")));
        // A 3% dip stays inside the default 5% gate.
        let mild = with_metric(base.clone(), "qps", 5000.0 * 0.97);
        assert!(compare::compare(&base, &mild, &compare::Tolerances::default()).unwrap().pass());
    }

    #[test]
    fn diff_fails_on_acceptance_flag_and_exact_mismatch() {
        let base = sample_report();
        // Acceptance flag true -> false is a regression.
        let mut b = BenchReport::new("unit_diff", "sim", "modeled").accept("conserves", false);
        b.offered = 1000;
        b.completed = 980;
        b.shed = 20;
        b.qps = 5000.0;
        b.p50_ms = 4.0;
        b.p99_ms = 9.0;
        let broken = b.to_json();
        let d = compare::compare(&base, &broken, &compare::Tolerances::default()).unwrap();
        assert_eq!(d.flag_regressions, vec!["conserves".to_string()]);
        assert!(!d.pass());
        // `offered` is gated exactly: a different workload size fails.
        let resized = with_metric(base.clone(), "offered", 999.0);
        assert!(!compare::compare(&base, &resized, &compare::Tolerances::default()).unwrap().pass());
        // Different bench identity is a hard error, not a diff result.
        let other = BenchReport::new("other_bench", "sim", "modeled").to_json();
        assert!(compare::compare(&base, &other, &compare::Tolerances::default()).is_err());
    }

    #[test]
    fn diff_checks_only_baseline_pinned_metrics() {
        // A partial baseline (no latency numbers) must not fail a fresh
        // report over metrics it never pinned.
        let base = Json::obj(vec![
            ("bench", Json::str("unit_diff")),
            ("offered", Json::num(1000.0)),
            ("acceptance", Json::obj(vec![("conserves", Json::Bool(true))])),
        ]);
        let fresh = sample_report();
        let d = compare::compare(&base, &fresh, &compare::Tolerances::default()).unwrap();
        assert!(d.pass(), "{:?}", d.failures());
        assert_eq!(d.metrics.len(), 1, "only 'offered' is pinned");
        // But a pinned metric missing from the fresh report fails.
        let base2 = with_metric(base, "qps", 5000.0);
        let mut thin = fresh.clone();
        if let Json::Obj(m) = &mut thin {
            m.remove("qps");
        }
        let d2 = compare::compare(&base2, &thin, &compare::Tolerances::default()).unwrap();
        assert_eq!(d2.missing, vec!["qps".to_string()]);
        assert!(!d2.pass());
    }

    #[test]
    fn tolerance_override_rejects_unknown_metric() {
        let mut tol = compare::Tolerances::default();
        tol.set_rel("qps", 0.20).unwrap();
        assert!(tol.set_rel("no_such_metric", 0.1).is_err());
        // The widened gate now admits a 15% dip.
        let base = sample_report();
        let fresh = with_metric(base.clone(), "qps", 5000.0 * 0.85);
        assert!(compare::compare(&base, &fresh, &tol).unwrap().pass());
    }
}
