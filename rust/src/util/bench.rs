//! Bench harness (criterion is unavailable offline).
//!
//! Two responsibilities:
//! 1. timing: warmup + repeated measurement with mean/std/min reporting;
//! 2. paper-style reporting: every bench target regenerates the rows/series
//!    of one paper table or figure (DESIGN.md §5) via [`crate::util::table`].

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f`, auto-scaling iteration count to hit ~`target_s` of total
/// measurement after `warmup` calls.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, 3, 0.5, &mut f)
}

/// Fully parameterized variant.
pub fn bench_with<F: FnMut()>(name: &str, warmup: usize, target_s: f64, f: &mut F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once).ceil() as usize).clamp(3, 10_000);

    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.add(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        std_s: s.std(),
        min_s: s.min(),
    }
}

/// Pretty-print a timing result in bench output style.
pub fn report(r: &BenchResult) {
    println!(
        "  {:<44} {:>12} {:>12} {:>10}  ({} iters)",
        r.name,
        fmt_time(r.mean_s),
        fmt_time(r.min_s),
        format!("±{:.1}%", 100.0 * r.std_s / r.mean_s.max(1e-12)),
        r.iters
    );
}

/// Human-friendly seconds formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Section header used by all bench binaries for a consistent look.
pub fn section(title: &str) {
    println!();
    println!("=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench_with("noop-ish", 1, 0.02, &mut || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-9);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
