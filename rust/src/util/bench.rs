//! Bench harness (criterion is unavailable offline).
//!
//! Two responsibilities:
//! 1. timing: warmup + repeated measurement with mean/std/min reporting;
//! 2. paper-style reporting: every bench target regenerates the rows/series
//!    of one paper table or figure (DESIGN.md §5) via [`crate::util::table`].

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f`, auto-scaling iteration count to hit ~`target_s` of total
/// measurement after `warmup` calls.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, 3, 0.5, &mut f)
}

/// Fully parameterized variant.
pub fn bench_with<F: FnMut()>(name: &str, warmup: usize, target_s: f64, f: &mut F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once).ceil() as usize).clamp(3, 10_000);

    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.add(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        std_s: s.std(),
        min_s: s.min(),
    }
}

/// Pretty-print a timing result in bench output style.
pub fn report(r: &BenchResult) {
    println!(
        "  {:<44} {:>12} {:>12} {:>10}  ({} iters)",
        r.name,
        fmt_time(r.mean_s),
        fmt_time(r.min_s),
        format!("±{:.1}%", 100.0 * r.std_s / r.mean_s.max(1e-12)),
        r.iters
    );
}

/// Human-friendly seconds formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Section header used by all bench binaries for a consistent look.
pub fn section(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// The shared `BENCH_*.json` schema: every emitter (`fig7` bench,
/// `fbia fleet --json`, `fbia cluster --json`, `fbia des --json`) writes
/// the same top-level fields so PR-over-PR trend tooling can diff the
/// files without per-bench parsing. Detail payloads (policy sweeps,
/// per-card tables, capacity plans) nest under emitter-specific `extra`
/// keys; the headline numbers and acceptance flags always live at the top
/// level.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Bench identity ("fig7_latency_qps", "fleet_smoke", ...).
    pub name: String,
    /// Backend that produced the numbers ("ref" | "sim" | "pjrt").
    pub backend: String,
    /// Clock the numbers are on ("wall" | "modeled").
    pub clock: String,
    /// Requests offered / completed / shed (conservation:
    /// completed + shed == offered).
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// Headline throughput and tail latency.
    pub qps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Named acceptance checks ("la_beats_rr", "all_within_budget", ...);
    /// the CI gates read these.
    pub acceptance: Vec<(String, bool)>,
    /// Emitter-specific detail, merged into the object as-is.
    pub extra: Vec<(String, Json)>,
}

impl BenchReport {
    /// A report skeleton; fill the metric fields then call
    /// [`BenchReport::to_json`] / [`BenchReport::write`].
    pub fn new(name: &str, backend: &str, clock: &str) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            backend: backend.to_string(),
            clock: clock.to_string(),
            offered: 0,
            completed: 0,
            shed: 0,
            qps: 0.0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            acceptance: Vec::new(),
            extra: Vec::new(),
        }
    }

    /// Record one named acceptance flag (chainable).
    pub fn accept(mut self, check: &str, holds: bool) -> BenchReport {
        self.acceptance.push((check.to_string(), holds));
        self
    }

    /// Attach one emitter-specific detail field (chainable).
    pub fn with(mut self, key: &str, value: Json) -> BenchReport {
        self.extra.push((key.to_string(), value));
        self
    }

    /// Render the shared schema. `shed_rate` and the acceptance map are
    /// derived here so every emitter agrees on their definitions.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("bench".to_string(), Json::str(&self.name)),
            ("backend".to_string(), Json::str(&self.backend)),
            ("clock".to_string(), Json::str(&self.clock)),
            ("offered".to_string(), Json::num(self.offered as f64)),
            ("completed".to_string(), Json::num(self.completed as f64)),
            ("shed".to_string(), Json::num(self.shed as f64)),
            (
                "shed_rate".to_string(),
                Json::num(self.shed as f64 / (self.offered as f64).max(1.0)),
            ),
            ("qps".to_string(), Json::num(self.qps)),
            ("p50_ms".to_string(), Json::num(self.p50_ms)),
            ("p99_ms".to_string(), Json::num(self.p99_ms)),
            (
                "acceptance".to_string(),
                Json::Obj(
                    self.acceptance
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Bool(*v)))
                        .collect(),
                ),
            ),
        ];
        for (k, v) in &self.extra {
            fields.push((k.clone(), v.clone()));
        }
        Json::Obj(fields.into_iter().collect())
    }

    /// Write the report to `path` (the `--json` flag's sink).
    pub fn write(&self, path: &str) -> crate::util::error::Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| crate::util::error::err!("writing {path}: {e}"))?;
        println!("wrote {path}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench_with("noop-ish", 1, 0.02, &mut || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-9);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
