//! Deterministic PRNG + the distributions the workload generators need.
//!
//! xoshiro256** — fast, high-quality, and reproducible across platforms;
//! the weight generator and the workload generators must agree with nothing
//! but a seed (the artifact weights are re-derived in tests and in the
//! numerics validator).

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 seed gives a well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-table / per-card generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's method without bias correction is fine for workload gen,
        // but keep the rejection loop for exactness (weights must be stable).
        if n == 0 {
            return 0;
        }
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (cached second value discarded for
    /// reproducibility simplicity).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Poisson via Knuth (fine for the small arrival rates we simulate).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            // normal approximation for large lambda
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential inter-arrival time with the given rate (events/sec).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Sample from a Zipf distribution over {0, .., n-1} with exponent `s`.
    /// Used for embedding-lookup popularity (recsys traffic is heavily
    /// skewed — the basis of the paper's partial-tensor win).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // inverse-CDF on the harmonic partial sums would need a table; use
        // rejection sampling (Devroye) which is table-free and exact.
        debug_assert!(n >= 1);
        let b = 2f64.powf(1.0 - s);
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = (u.powf(-1.0 / (s - 1.0))).floor();
            let t = (1.0 + 1.0 / x).powf(s - 1.0);
            if x <= n as f64 && v * x * (t - 1.0) / (b - 1.0) <= t / b {
                return (x as u64).saturating_sub(1);
            }
        }
    }

    /// Fill a slice with N(0, std) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Shuffle (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let n = 1000u64;
        let mut counts = vec![0u64; n as usize];
        for _ in 0..20_000 {
            let v = r.zipf(n, 1.2);
            assert!(v < n);
            counts[v as usize] += 1;
        }
        // head must dominate the tail
        let head: u64 = counts[..10].iter().sum();
        let tail: u64 = counts[500..].iter().sum();
        assert!(head > tail, "head={head} tail={tail}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::new(17);
        for &lam in &[0.5, 4.0, 50.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.1, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
