//! Minimal JSON parser + serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar except surrogate-pair `\u` escapes beyond
//! the BMP being combined (each escape maps independently). Numbers parse as
//! `f64` with an `as_i64` accessor for integral values, which is sufficient
//! for the artifact manifest and config files this crate reads.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted-key lookup helper.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ----- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.path("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"nested":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn integral_accessors() {
        let v = Json::parse("42").unwrap();
        assert_eq!(v.as_i64(), Some(42));
        assert_eq!(v.as_usize(), Some(42));
        assert_eq!(Json::parse("42.5").unwrap().as_i64(), None);
    }
}
