//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. The `fbia` binary and all bench harnesses share it.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand + options + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — first element is NOT argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, with_subcommand: bool) -> Args {
        let mut args = Args::default();
        let mut items = it.into_iter().peekable();
        if with_subcommand {
            if let Some(first) = items.peek() {
                if !first.starts_with('-') {
                    args.subcommand = items.next();
                }
            }
        }
        while let Some(a) = items.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if matches!(items.peek(), Some(n) if !n.starts_with("--")) {
                    args.opts.insert(rest.to_string(), items.next().unwrap());
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse process arguments (skips argv[0]).
    pub fn from_env(with_subcommand: bool) -> Args {
        Args::parse_from(std::env::args().skip(1), with_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse_from(sv(&["serve", "--model", "dlrm", "--qps=100", "-x"]), true);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("dlrm"));
        assert_eq!(a.get_usize("qps", 0), 100);
        assert_eq!(a.positional, vec!["-x".to_string()]);
    }

    #[test]
    fn flags_without_values() {
        let a = Args::parse_from(sv(&["--verbose", "--n", "3", "--quiet"]), false);
        assert!(a.flag("verbose"));
        assert!(a.flag("quiet"));
        assert!(!a.flag("n"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(sv(&[]), true);
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("r", 1.5), 1.5);
    }

    #[test]
    fn double_dash_value_not_consumed() {
        let a = Args::parse_from(sv(&["--a", "--b", "v"]), false);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
