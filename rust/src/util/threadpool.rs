//! Fixed-size worker thread pool (tokio is unavailable offline; the serving
//! runtime's needs — a request loop with bounded concurrency and join-able
//! task batches — are covered by this + std::sync primitives).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing FIFO jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let inf = Arc::clone(&in_flight);
            let exec = Arc::clone(&executed);
            workers.push(
                thread::Builder::new()
                    .name(format!("fbia-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                exec.fetch_add(1, Ordering::Relaxed);
                                let (lock, cv) = &*inf;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                cv.notify_all();
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, in_flight, executed }
    }

    /// Submit a job. Panics if the pool has been shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.in_flight;
        *lock.lock().unwrap() += 1;
        self.tx.as_ref().expect("pool shut down").send(Box::new(f)).expect("send job");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.in_flight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Total jobs executed since creation.
    pub fn executed(&self) -> usize {
        self.executed.load(Ordering::Relaxed)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run a batch of closures, blocking until all complete (scoped-join
    /// convenience used by the data-parallel serving path).
    pub fn scope_run<F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'static,
    {
        for j in jobs {
            self.execute(j);
        }
        self.wait_idle();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.executed(), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn scope_run_joins() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        let jobs: Vec<_> = (0..10)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.scope_run(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not deadlock
    }
}
