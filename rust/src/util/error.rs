//! In-crate error type (anyhow is unavailable offline; DESIGN.md §2).
//!
//! A single message-carrying [`Error`] with context chaining, the matching
//! [`Result`] alias, a [`Context`] extension trait for `Result`/`Option`,
//! and the [`err!`](crate::err)/[`bail!`](crate::bail) macros. Context wraps
//! as `"context: cause"`, so `{e}` and `{e:#}` both print the full chain.

use std::fmt;

/// A boxed error message with its context chain flattened into the string.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (`"ctx: current"`).
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like anyhow::Error, this type deliberately does NOT implement
// std::error::Error: that keeps the blanket `?` conversion below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or missing value) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string: `err!("bad {x}")`.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// Early-return an `Err` from a format string: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

// Re-export the crate-root macros so `use crate::util::error::{bail, err}`
// (or `fbia::util::error::{bail, err}` from tests/benches) works uniformly.
pub use crate::{bail, err};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 7)
    }

    #[test]
    fn macro_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "inner 7");
        assert_eq!(format!("{e:#}"), "inner 7");
        assert_eq!(format!("{e:?}"), "inner 7");
    }

    #[test]
    fn context_chains() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
        let e = fails().with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "layer 2: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(io().is_err());
        fn parse() -> Result<i32> {
            Ok("not-a-number".parse::<i32>()?)
        }
        assert!(parse().unwrap_err().to_string().contains("invalid digit"));
    }
}
