//! Statistics: streaming summaries, latency histograms, percentiles.
//!
//! The serving stack records per-request latencies into an HDR-style
//! log-bucketed histogram so p50/p95/p99 are O(1) memory regardless of run
//! length (the paper reports per-model latency/QPS points — Fig. 7).

/// Streaming mean/variance/min/max (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Log-bucketed histogram for positive values (latencies in seconds or
/// microseconds). ~1.5% relative resolution, fixed 1024 buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    lo: f64,
    ratio: f64, // log-spacing factor
    count: u64,
    sum: f64,
    overflow: u64,
    underflow: u64,
}

impl Histogram {
    /// `lo`/`hi` bound the tracked range; values outside are clamped into
    /// under/overflow buckets (still counted for percentile purposes).
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(lo > 0.0 && hi > lo);
        let n = 1024usize;
        let ratio = (hi / lo).powf(1.0 / n as f64);
        Histogram {
            buckets: vec![0; n],
            lo,
            ratio,
            count: 0,
            sum: 0.0,
            overflow: 0,
            underflow: 0,
        }
    }

    /// Default latency histogram: 1 µs .. 100 s.
    pub fn latency() -> Self {
        Histogram::new(1e-6, 100.0)
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = ((x / self.lo).ln() / self.ratio.ln()) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile in [0, 100]; returns the bucket lower edge.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target {
            return self.lo;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.lo * self.ratio.powi(i as i32);
            }
        }
        self.lo * self.ratio.powi(self.buckets.len() as i32)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Merge another histogram's counts into this one. Both must cover the
    /// same range: bucket `i` means a different latency in a differently
    /// parameterized histogram, so merging would silently corrupt
    /// percentiles (the threaded servers merge per-worker histograms
    /// through here).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        assert!(
            self.lo == other.lo && self.ratio == other.ratio,
            "histogram range mismatch: lo {} vs {}, ratio {} vs {}",
            self.lo,
            other.lo,
            self.ratio,
            other.ratio
        );
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.overflow += other.overflow;
        self.underflow += other.underflow;
    }
}

/// Streaming quantile sketch: a deterministic CKMS-style compressed
/// summary with a provable nearest-rank error bound.
///
/// The summary is a sorted list of `(value, weight)` items; every item's
/// value is one of the inserted samples and its weight counts the samples
/// it absorbed (all `<=` its value, all `>` the previous item's value —
/// ranges stay contiguous and disjoint). Compression merges adjacent items
/// while the combined weight stays under `eps * n / 2`, so a quantile
/// query returns an actual sample whose rank overshoots the exact
/// nearest-rank target by fewer than `eps * n / 2` positions. Memory is
/// `O(1/eps)` items regardless of stream length.
///
/// Everything is deterministic in insert order (no randomization), and two
/// sketches with the same `eps` merge deterministically — the properties
/// the windowed telemetry layer needs for bit-reproducible reports.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    eps: f64,
    /// Sorted `(value, absorbed sample count)` summary.
    items: Vec<(f64, u64)>,
    /// Recent inserts, merged into `items` every [`QuantileSketch::BUF`].
    buf: Vec<f64>,
    n: u64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch::new(QuantileSketch::DEFAULT_EPS)
    }
}

impl QuantileSketch {
    /// Default rank-error fraction: p99 of a long stream lands within
    /// ±0.25% of the exact rank.
    pub const DEFAULT_EPS: f64 = 0.005;
    /// Insert buffer length between compactions.
    const BUF: usize = 64;

    /// `eps` is the rank-error fraction (see the type docs); must be in
    /// `(0, 0.5)`.
    pub fn new(eps: f64) -> QuantileSketch {
        assert!(eps > 0.0 && eps < 0.5, "eps {eps} outside (0, 0.5)");
        QuantileSketch {
            eps,
            items: Vec::new(),
            buf: Vec::new(),
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.buf.push(x);
        if self.buf.len() >= Self::BUF {
            self.flush();
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Exact stream minimum (tracked outside the summary).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact stream maximum.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Summary + buffer entries currently held — the memory footprint, in
    /// samples. Bounded by `O(1/eps)` however long the stream runs.
    pub fn footprint(&self) -> usize {
        self.items.len() + self.buf.len()
    }

    /// Merge `other`'s samples into `self` (deterministic in operand
    /// order). The stricter (smaller) `eps` of the two wins.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.n == 0 {
            return;
        }
        self.eps = self.eps.min(other.eps);
        self.flush();
        let mut theirs = other.clone();
        theirs.flush();
        let merged = merge_weighted(&self.items, &theirs.items);
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.items = merged;
        self.compress();
    }

    /// Approximate quantile, `q` in `[0, 1]`, matching [`exact_quantile`]'s
    /// nearest-rank `ceil(q*n)` convention. Returns an inserted sample
    /// whose rank is within `eps*n/2` above the exact target; `q <= 0` and
    /// `q >= 1` return the exact min/max.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut sorted_buf = self.buf.clone();
        sorted_buf.sort_by(f64::total_cmp);
        let mut cum = 0u64;
        let (mut i, mut j) = (0usize, 0usize);
        let mut last = self.min;
        while i < self.items.len() || j < sorted_buf.len() {
            let take_item = match (self.items.get(i), sorted_buf.get(j)) {
                (Some(&(v, _)), Some(&b)) => v <= b,
                (Some(_), None) => true,
                _ => false,
            };
            let (v, w) = if take_item {
                let it = self.items[i];
                i += 1;
                it
            } else {
                let b = sorted_buf[j];
                j += 1;
                (b, 1)
            };
            cum += w;
            last = v;
            if cum >= target {
                return v;
            }
        }
        last
    }

    /// Approximate count of samples `<= v` (rank error below `eps*n/2`).
    pub fn rank_le(&self, v: f64) -> u64 {
        let mut cum = 0u64;
        for &(x, w) in &self.items {
            if x <= v {
                cum += w;
            } else {
                break;
            }
        }
        cum + self.buf.iter().filter(|&&b| b <= v).count() as u64
    }

    /// Approximate fraction of samples `<= v`; 0 for an empty sketch.
    pub fn fraction_le(&self, v: f64) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.rank_le(v) as f64 / self.n as f64
        }
    }

    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        self.buf.sort_by(f64::total_cmp);
        let fresh: Vec<(f64, u64)> = self.buf.drain(..).map(|x| (x, 1)).collect();
        self.items = merge_weighted(&self.items, &fresh);
        self.compress();
    }

    fn compress(&mut self) {
        let wcap = ((self.eps * self.n as f64 / 2.0) as u64).max(1);
        let mut out: Vec<(f64, u64)> = Vec::with_capacity(self.items.len());
        for &(v, w) in &self.items {
            match out.last_mut() {
                // absorbing a neighbor keeps the upper value, so every
                // item still bounds its range from above
                Some(last) if last.1 + w <= wcap => {
                    last.0 = v;
                    last.1 += w;
                }
                _ => out.push((v, w)),
            }
        }
        self.items = out;
    }
}

/// Merge two sorted weighted lists into one (stable: `a` wins ties).
fn merge_weighted(a: &[(f64, u64)], b: &[(f64, u64)]) -> Vec<(f64, u64)> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() || j < b.len() {
        let take_a = match (a.get(i), b.get(j)) {
            (Some(&(av, _)), Some(&(bv, _))) => av <= bv,
            (Some(_), None) => true,
            _ => false,
        };
        if take_a {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out
}

/// Exact small-sample quantile (nearest-rank, matching
/// [`Histogram::percentile`]'s `ceil(q*n)` convention): `q` in `[0, 1]`,
/// sorts a copy of the samples. The log-bucketed [`Histogram`] has ~1.5%
/// relative resolution — too coarse for sub-millisecond stage latencies —
/// so per-stage p99s go through here instead.
pub fn exact_quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let rank = (q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Cosine similarity — the paper's embedding-quality metric (§V-A).
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return if na == nb { 1.0 } else { 0.0 };
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Normalized cross-entropy delta — the paper's recsys offline metric
/// (§V-A): NE of predictions `p` vs labels, normalized by the entropy of the
/// base rate. Returns (ne_a - ne_b) / ne_b as a percentage when comparing
/// two prediction sets.
pub fn normalized_entropy(preds: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    assert!(!preds.is_empty());
    let n = preds.len() as f64;
    let base = labels.iter().map(|&y| y as f64).sum::<f64>() / n;
    let base = base.clamp(1e-6, 1.0 - 1e-6);
    let mut ce = 0.0;
    for (&p, &y) in preds.iter().zip(labels) {
        let p = (p as f64).clamp(1e-6, 1.0 - 1e-6);
        let y = y as f64;
        ce -= y * p.ln() + (1.0 - y) * (1.0 - p).ln();
    }
    let base_ce = -(base * base.ln() + (1.0 - base) * (1.0 - base).ln()) * n;
    ce / base_ce
}

/// Relative NE degradation in percent: 100 * (ne_test - ne_ref) / ne_ref.
pub fn ne_degradation_pct(ref_preds: &[f32], test_preds: &[f32], labels: &[f32]) -> f64 {
    let a = normalized_entropy(ref_preds, labels);
    let b = normalized_entropy(test_preds, labels);
    100.0 * (b - a) / a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut all = Summary::new();
        let mut a = Summary::new();
        let mut b = Summary::new();
        for (i, &x) in xs.iter().enumerate() {
            all.add(x);
            if i % 2 == 0 {
                a.add(x);
            } else {
                b.add(x);
            }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles_monotone_and_accurate() {
        let mut h = Histogram::latency();
        for i in 1..=10_000 {
            h.add(i as f64 * 1e-5); // 10µs .. 100ms uniform
        }
        let p50 = h.p50();
        let p95 = h.p95();
        let p99 = h.p99();
        assert!(p50 < p95 && p95 < p99);
        assert!((p50 - 0.05).abs() / 0.05 < 0.05, "{p50}");
        assert!((p99 - 0.099).abs() / 0.099 < 0.05, "{p99}");
    }

    #[test]
    fn histogram_merge_equals_combined() {
        let mut all = Histogram::latency();
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        for i in 1..=1000 {
            let x = i as f64 * 1e-4;
            all.add(x);
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.p50(), all.p50());
        assert_eq!(a.p99(), all.p99());
    }

    #[test]
    #[should_panic(expected = "histogram range mismatch")]
    fn histogram_merge_rejects_range_mismatch() {
        let mut a = Histogram::latency();
        let b = Histogram::new(1e-3, 10.0); // same 1024 buckets, different range
        a.merge(&b);
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut h = Histogram::new(1e-3, 1.0);
        h.add(1e-9);
        h.add(50.0);
        assert_eq!(h.count(), 2);
        assert!(h.percentile(10.0) >= 1e-3);
    }

    #[test]
    fn exact_quantile_matches_sorted_slice_ground_truth() {
        // odd/even sizes, unsorted input, duplicate values
        let xs = vec![5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(exact_quantile(&xs, 0.0), 1.0);
        assert_eq!(exact_quantile(&xs, 0.2), 1.0); // ceil(0.2*5)=1 -> 1st
        assert_eq!(exact_quantile(&xs, 0.5), 3.0); // ceil(0.5*5)=3 -> 3rd
        assert_eq!(exact_quantile(&xs, 0.99), 5.0);
        assert_eq!(exact_quantile(&xs, 1.0), 5.0);
        let xs = vec![2.0, 2.0, 1.0, 1.0];
        assert_eq!(exact_quantile(&xs, 0.5), 1.0); // ceil(0.5*4)=2 -> 2nd
        assert_eq!(exact_quantile(&xs, 0.75), 2.0);
        assert_eq!(exact_quantile(&[], 0.5), 0.0);
        assert_eq!(exact_quantile(&[7.5], 0.99), 7.5);
        // agrees with the nearest-rank formula on a bigger sample
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        assert_eq!(exact_quantile(&xs, 0.99), 990.0);
        assert_eq!(exact_quantile(&xs, 0.501), 501.0);
    }

    /// SplitMix64 — deterministic pseudo-random stream for sketch tests.
    fn splitmix(seed: &mut u64) -> f64 {
        *seed = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = *seed;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Ground truth: the sketch answer must sit between the exact
    /// quantiles at `q` and `q + eps/2` (plus one rank of slack for the
    /// ceil convention) — the bound promised by the type docs.
    fn assert_sketch_within_eps(xs: &[f64], sk: &QuantileSketch, eps: f64) {
        let n = xs.len() as f64;
        for q in [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let got = sk.quantile(q);
            let lo = exact_quantile(xs, q);
            let hi = exact_quantile(xs, (q + eps / 2.0 + 1.5 / n).min(1.0));
            assert!(
                got >= lo && got <= hi,
                "q={q}: sketch {got} outside exact [{lo}, {hi}]"
            );
        }
    }

    #[test]
    fn sketch_matches_exact_quantile_within_eps() {
        let eps = 0.01;
        // uniform, heavy-tailed, and duplicate-rich streams
        let mut seed = 7u64;
        let streams: Vec<Vec<f64>> = vec![
            (0..50_000).map(|_| splitmix(&mut seed)).collect(),
            (0..50_000).map(|_| splitmix(&mut seed).powi(8) * 1e3).collect(),
            (0..50_000).map(|_| (splitmix(&mut seed) * 10.0).floor()).collect(),
        ];
        for xs in &streams {
            let mut sk = QuantileSketch::new(eps);
            for &x in xs {
                sk.add(x);
            }
            assert_eq!(sk.count(), xs.len() as u64);
            assert_sketch_within_eps(xs, &sk, eps);
            // exact extremes survive compression
            let mut sorted = xs.clone();
            sorted.sort_by(f64::total_cmp);
            assert_eq!(sk.min(), sorted[0]);
            assert_eq!(sk.max(), *sorted.last().unwrap());
        }
    }

    #[test]
    fn sketch_is_exact_below_the_buffer_and_for_small_streams() {
        // fewer samples than one compaction's weight cap => every item
        // keeps weight 1 and queries reproduce exact_quantile bit-for-bit
        let xs: Vec<f64> = (1..=200).map(|i| (i * 37 % 211) as f64).collect();
        let mut sk = QuantileSketch::new(0.005);
        for &x in &xs {
            sk.add(x);
        }
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(sk.quantile(q), exact_quantile(&xs, q), "q={q}");
        }
    }

    #[test]
    fn sketch_footprint_is_bounded() {
        let eps = 0.01;
        let mut sk = QuantileSketch::new(eps);
        let mut seed = 3u64;
        for _ in 0..200_000 {
            sk.add(splitmix(&mut seed));
        }
        // compress guarantees adjacent items can't both fit under the
        // weight cap, so the summary holds < 4/eps items (+ buffer)
        let cap = (4.0 / eps) as usize + 64;
        assert!(sk.footprint() <= cap, "{} > {cap}", sk.footprint());
    }

    #[test]
    fn sketch_merge_matches_single_stream_bound() {
        let eps = 0.01;
        let mut seed = 11u64;
        let xs: Vec<f64> = (0..60_000).map(|_| splitmix(&mut seed) * 50.0).collect();
        let (mut a, mut b) = (QuantileSketch::new(eps), QuantileSketch::new(eps));
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
        }
        a.merge(&b);
        assert_eq!(a.count(), xs.len() as u64);
        assert_sketch_within_eps(&xs, &a, 2.0 * eps); // merge may double rank error
        // deterministic: the same merge again gives the identical sketch
        let (mut a2, mut b2) = (QuantileSketch::new(eps), QuantileSketch::new(eps));
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 { a2.add(x) } else { b2.add(x) }
        }
        a2.merge(&b2);
        assert_eq!(a, a2);
    }

    #[test]
    fn sketch_rank_le_counts_samples() {
        let mut sk = QuantileSketch::new(0.02);
        for i in 1..=10_000 {
            sk.add(i as f64);
        }
        let got = sk.rank_le(2_500.0) as f64;
        assert!((got - 2_500.0).abs() <= 0.02 * 10_000.0 / 2.0, "{got}");
        assert_eq!(sk.rank_le(0.0), 0);
        assert_eq!(sk.rank_le(1e9), 10_000);
        assert!((sk.fraction_le(5_000.0) - 0.5).abs() < 0.011);
        assert_eq!(QuantileSketch::default().fraction_le(1.0), 0.0);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine_similarity(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine_similarity(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine_similarity(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ne_perfect_predictions_beat_base_rate() {
        let labels = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let good = vec![0.9, 0.1, 0.9, 0.1, 0.9, 0.1];
        let base = vec![0.5; 6];
        assert!(normalized_entropy(&good, &labels) < normalized_entropy(&base, &labels));
    }

    #[test]
    fn ne_degradation_zero_for_identical() {
        let labels = vec![1.0, 0.0, 1.0];
        let p = vec![0.8, 0.3, 0.6];
        assert!(ne_degradation_pct(&p, &p, &labels).abs() < 1e-12);
    }
}
