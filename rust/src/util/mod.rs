//! Substrate utilities built in-repo because the build environment is fully
//! offline (DESIGN.md §2): errors, JSON, RNG + distributions, statistics, a
//! CLI argument parser, a thread pool, a property-testing mini-framework, a
//! bench harness, and a paper-style table printer.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
