//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and, on failure, performs a bounded greedy shrink via the generator's
//! `shrink` hook before panicking with the minimal counterexample found.
//!
//! Coordinator invariants (partitioner, batcher, pipeline) are verified with
//! this — see `compiler::partition` and `serving::batcher` tests.

use crate::util::rng::Rng;
use std::fmt::Debug;

/// A generator of random values with an optional shrinker.
pub trait Gen {
    type Value: Clone + Debug;

    fn generate(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate smaller values; default = no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs; panic with the (shrunk)
/// counterexample and reproduction seed on failure.
pub fn check<G, F>(name: &str, cases: usize, gen: &G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    let seed = std::env::var("FBIA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF00D_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // greedy shrink: repeatedly take the first failing candidate
            let mut cur = v.clone();
            let mut cur_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&cur) {
                    budget -= 1;
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  \
                 counterexample: {cur:?}\n  reason: {cur_msg}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi]; shrinks toward lo.
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeIn {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo as i64, self.hi as i64) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Vector of T with length in [min_len, max_len]; shrinks by halving and by
/// element-wise shrinking of a single position.
pub struct VecOf<G> {
    pub item: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let n = rng.range(self.min_len as i64, self.max_len as i64) as usize;
        (0..n).map(|_| self.item.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // drop the back half
            let keep = self.min_len.max(v.len() / 2);
            out.push(v[..keep].to_vec());
            // drop one element
            let mut one = v.clone();
            one.pop();
            out.push(one);
        }
        // shrink the first shrinkable element
        for (i, item) in v.iter().enumerate().take(4) {
            for cand in self.item.shrink(item) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
                break;
            }
        }
        out
    }
}

/// Pair generator.
pub struct PairOf<A, B> {
    pub a: A,
    pub b: B,
}

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.a.generate(rng), self.b.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .a
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.b.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Map a generator through a function (no shrinking across the map).
pub struct MapGen<G, F> {
    pub inner: G,
    pub f: F,
}

impl<G: Gen, T: Clone + Debug, F: Fn(G::Value) -> T> Gen for MapGen<G, F> {
    type Value = T;

    fn generate(&self, rng: &mut Rng) -> T {
        (self.f)(self.inner.generate(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("usize in range", 200, &UsizeIn { lo: 3, hi: 10 }, |&v| {
            if (3..=10).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_counterexample() {
        check("always fails", 10, &UsizeIn { lo: 0, hi: 100 }, |_| Err("nope".into()));
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // property: v < 50. minimal counterexample should be <= 75 after
        // greedy shrink (exact value depends on path; must not stay at 100).
        let result = std::panic::catch_unwind(|| {
            check("lt50", 100, &UsizeIn { lo: 0, hi: 100 }, |&v| {
                if v < 50 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 50"))
                }
            });
        });
        let err = *result.unwrap_err().downcast::<String>().unwrap();
        // the shrinker probes lo and midpoints; it must report some failing
        // value, and that value must fail the property
        assert!(err.contains(">= 50"), "{err}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecOf { item: UsizeIn { lo: 0, hi: 5 }, min_len: 2, max_len: 7 };
        check("vec bounds", 100, &g, |v| {
            if (2..=7).contains(&v.len()) && v.iter().all(|&x| x <= 5) {
                Ok(())
            } else {
                Err(format!("{v:?}"))
            }
        });
    }
}
