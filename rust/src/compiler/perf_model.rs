//! Per-op performance model (roofline) for the accelerator card.
//!
//! This is the "performance model learned by profiling" that drives the
//! paper's list-scheduling placement (§VI-B) and the simulator's op timing.
//! Each op gets a compute time (peak engine throughput × core share ×
//! efficiency) and a memory time (bytes / bandwidth, SRAM vs LPDDR); the op
//! takes max(compute, memory) + a fixed launch overhead.

use crate::graph::ops::{self, Engine, OpKind};
use crate::graph::{Graph, Node};
use crate::platform::CardSpec;

/// Cost components for one op on one card.
#[derive(Debug, Clone, Copy)]
pub struct OpCost {
    pub flops: f64,
    pub bytes: f64,
    /// seconds of compute on ONE Accel Core.
    pub compute_1core_s: f64,
    /// seconds of memory traffic (shared LPDDR; does not scale with cores).
    pub memory_s: f64,
    /// whether the weights can live in SRAM (affects memory_s already).
    pub weights_onchip: bool,
}

/// Fixed per-op launch overhead on the card, seconds. Small ops are overhead
/// dominated — the reason §VI-A keeps tiny ops on the host CPU.
pub const OP_OVERHEAD_S: f64 = 2.5e-6;

/// Shared-DRAM occupancy factor for co-resident SLS + dense partitions
/// (§VI-B: the recsys scheme keeps both on every card). The two partitions
/// stream the same LPDDR controller — embedding lookups issue random row
/// hits while the dense side streams activations — so each side sees the
/// memory system stretched by the other's demand. 1.5 models an even
/// interleave where the co-resident claims half the effective bandwidth;
/// an isolated partition (a card hosting only one of the two) runs at 1.0.
pub const SLS_DENSE_DRAM_OCCUPANCY: f64 = 1.5;

/// Engine efficiency: fraction of peak the kernels achieve. Matrix ops reach
/// a large fraction on well-shaped GEMMs; vector ops are bandwidth-limited
/// anyway. The avgpool before its optimization (§VI-B) ran at a tiny
/// fraction of peak — modeled explicitly in `efficiency`.
fn efficiency(kind: &OpKind) -> f64 {
    match kind {
        OpKind::Fc | OpKind::QuantizedFc | OpKind::MatMul => 0.70,
        OpKind::BatchMatMul => 0.60,
        OpKind::Conv { .. } | OpKind::ConvAddFused { .. } => 0.65,
        OpKind::Conv3D { .. } => 0.55,
        OpKind::SparseLengthsSum { .. } | OpKind::SparseLengthsSumSingle => 0.50,
        // the un-optimized average pool: §VI-B reports 44% of RegNetY
        // runtime before the fix, 6% after — the bad kernel ran orders of
        // magnitude below peak on large/full-image pooling windows.
        OpKind::AvgPool { optimized: false, .. } | OpKind::AdaptiveAvgPool { optimized: false } => {
            0.002
        }
        OpKind::AvgPool { optimized: true, .. } | OpKind::AdaptiveAvgPool { optimized: true } => {
            0.40
        }
        _ => 0.40,
    }
}

/// Compute the cost of `node` on `card`, assuming weights for this op are
/// resident on-chip when they fit (`sram_resident_bytes` tracks what the
/// compiler placed there).
pub fn op_cost(g: &Graph, node: &Node, card: &CardSpec, weights_onchip: bool) -> OpCost {
    op_cost_shared_dram(g, node, card, weights_onchip, 1.0)
}

/// [`op_cost`] with a shared-DRAM occupancy factor (>= 1): the DRAM-bound
/// terms — SLS random row hits and streaming traffic whose weights did not
/// fit on-chip — stretch by `dram_occupancy` when another partition is
/// co-resident on the card's memory system. SRAM-resident traffic and pure
/// compute are unaffected; pass 1.0 for an isolated partition.
pub fn op_cost_shared_dram(
    g: &Graph,
    node: &Node,
    card: &CardSpec,
    weights_onchip: bool,
    dram_occupancy: f64,
) -> OpCost {
    let dram_occupancy = dram_occupancy.max(1.0);
    let flops = ops::node_flops(g, node);
    let bytes = ops::node_bytes(g, node);
    let engine = node.kind.engine();

    // Activations are fused into their producer by the vendor compiler
    // (§IV-D "whether or not to fuse or chain multiple ops"): they cost an
    // op-launch only. Table II accordingly has no ReLU/Sigmoid rows.
    if matches!(node.kind, OpKind::Relu | OpKind::Sigmoid) {
        return OpCost { flops, bytes: 0.0, compute_1core_s: 0.0, memory_s: 0.0, weights_onchip };
    }

    let peak_card = match engine {
        Engine::Matrix => card.peak_ops(node.kind.is_int8()),
        // vector cores: model as fp16 peak / 4 (pointwise SIMD, not MXU)
        Engine::Vector => card.peak_ops(false) / 4.0,
        Engine::Host => 0.0, // host ops are costed by the host model
    };
    let per_core = peak_card / card.accel_cores as f64;
    let mut compute_1core_s = if per_core > 0.0 { flops / (per_core * efficiency(&node.kind)) } else { 0.0 };

    // SLS is dominated by DRAM *random access*, not streaming bandwidth:
    // each lookup pays an LPDDR row hit (~70 ns effective after bank-level
    // overlap). This is what makes the paper's FC/SLS split roughly even
    // (Table II) and motivates the near-memory-processing discussion (§VIII).
    if let OpKind::SparseLengthsSum { avg_lookups } = node.kind {
        let pooled_rows = g.tensor(node.outputs[0]).shape.dim(0) as f64;
        compute_1core_s += pooled_rows * avg_lookups * 70e-9 * dram_occupancy;
    }

    let memory_s = if weights_onchip {
        bytes / card.sram_bw
    } else {
        bytes * dram_occupancy / card.lpddr_bw
    };

    OpCost { flops, bytes, compute_1core_s, memory_s, weights_onchip }
}

impl OpCost {
    /// Execution time with `cores` Accel Cores assigned. Compute scales with
    /// cores; memory bandwidth is shared so it does not.
    pub fn time_s(&self, cores: usize) -> f64 {
        let c = (self.compute_1core_s / cores.max(1) as f64).max(self.memory_s);
        c + OP_OVERHEAD_S
    }

    /// Cores beyond which the op is memory-bound (no further speedup) —
    /// used by the parallelization heuristic to stop splitting.
    pub fn saturation_cores(&self) -> usize {
        if self.memory_s <= 0.0 {
            return usize::MAX;
        }
        (self.compute_1core_s / self.memory_s).ceil().max(1.0) as usize
    }
}

/// Host-side op cost (for net portions kept on CPU, §VI-A).
pub fn host_op_cost(g: &Graph, node: &Node, host: &crate::platform::HostSpec) -> f64 {
    let flops = ops::node_flops(g, node);
    let bytes = ops::node_bytes(g, node);
    // hosts are good at small/branchy ops: lower overhead, lower peak
    let compute = flops / (host.gflops * 1e9 * 0.5);
    let memory = bytes / host.mem_bw;
    compute.max(memory) + 0.5e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Graph, Shape, TensorKind};

    fn fc_graph(m: usize, k: usize, n: usize, quant: bool) -> (Graph, usize) {
        let mut g = Graph::new("t");
        let dt = if quant { DType::I8 } else { DType::F16 };
        let x = g.add_tensor("x", Shape::new(&[m, k]), DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", Shape::new(&[n, k]), dt, TensorKind::Weight);
        let b = g.add_tensor("b", Shape::new(&[n]), DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", Shape::new(&[m, n]), DType::F32, TensorKind::Activation);
        let kind = if quant { OpKind::QuantizedFc } else { OpKind::Fc };
        let id = g.add_node("fc", kind, vec![x, w, b], vec![y]);
        (g, id)
    }

    #[test]
    fn int8_faster_than_fp16_for_compute_bound() {
        let card = CardSpec::default();
        let (g8, n8) = fc_graph(512, 4096, 4096, true);
        let (g16, n16) = fc_graph(512, 4096, 4096, false);
        let c8 = op_cost(&g8, g8.node(n8), &card, true);
        let c16 = op_cost(&g16, g16.node(n16), &card, true);
        let t8 = c8.time_s(card.accel_cores);
        let t16 = c16.time_s(card.accel_cores);
        assert!(t16 / t8 > 2.0, "int8 {t8} fp16 {t16}");
    }

    #[test]
    fn compute_scales_with_cores_until_memory_bound() {
        let card = CardSpec::default();
        let (g, n) = fc_graph(256, 2048, 2048, true);
        let c = op_cost(&g, g.node(n), &card, true);
        let t1 = c.time_s(1);
        let t4 = c.time_s(4);
        assert!(t1 / t4 > 2.0, "t1={t1} t4={t4}");
        // tiny op: more cores don't help once memory-bound
        let (g2, n2) = fc_graph(1, 64, 64, true);
        let c2 = op_cost(&g2, g2.node(n2), &card, false);
        assert!(c2.saturation_cores() <= 2);
    }

    #[test]
    fn sram_residency_cuts_memory_time() {
        let card = CardSpec::default();
        let (g, n) = fc_graph(32, 1024, 1024, true);
        let on = op_cost(&g, g.node(n), &card, true);
        let off = op_cost(&g, g.node(n), &card, false);
        assert!(off.memory_s > 5.0 * on.memory_s);
    }

    #[test]
    fn unoptimized_avgpool_is_slow() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[1, 7, 7, 2048]), DType::F32, TensorKind::Input);
        let y = g.add_tensor("y", Shape::new(&[1, 2048]), DType::F32, TensorKind::Activation);
        let slow = g.add_node("p1", OpKind::AdaptiveAvgPool { optimized: false }, vec![x], vec![y]);
        let y2 = g.add_tensor("y2", Shape::new(&[1, 2048]), DType::F32, TensorKind::Activation);
        let fast = g.add_node("p2", OpKind::AdaptiveAvgPool { optimized: true }, vec![x], vec![y2]);
        let card = CardSpec::default();
        let ts = op_cost(&g, g.node(slow), &card, false).compute_1core_s;
        let tf = op_cost(&g, g.node(fast), &card, false).compute_1core_s;
        assert!(ts / tf > 10.0, "{ts} {tf}");
    }

    #[test]
    fn shared_dram_occupancy_scales_only_dram_bound_terms() {
        let card = CardSpec::default();
        // an SLS op's random row hits stretch with the occupancy factor
        let mut g = Graph::new("t");
        let idx = g.add_tensor("idx", Shape::new(&[64, 20]), DType::I32, TensorKind::Input);
        let tab =
            g.add_tensor("tab", Shape::new(&[10_000, 64]), DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", Shape::new(&[64, 64]), DType::F32, TensorKind::Activation);
        let n = g.add_node(
            "sls",
            OpKind::SparseLengthsSum { avg_lookups: 20.0 },
            vec![idx, tab],
            vec![y],
        );
        let iso = op_cost_shared_dram(&g, g.node(n), &card, false, 1.0);
        let co = op_cost_shared_dram(&g, g.node(n), &card, false, SLS_DENSE_DRAM_OCCUPANCY);
        assert!(
            co.compute_1core_s > iso.compute_1core_s,
            "co-resident SLS {} must exceed isolated {}",
            co.compute_1core_s,
            iso.compute_1core_s
        );
        assert!(co.memory_s > iso.memory_s);
        // SRAM-resident traffic is not contended: same memory time either way
        let (g2, n2) = fc_graph(32, 1024, 1024, true);
        let a = op_cost_shared_dram(&g2, g2.node(n2), &card, true, 1.0);
        let b = op_cost_shared_dram(&g2, g2.node(n2), &card, true, SLS_DENSE_DRAM_OCCUPANCY);
        assert_eq!(a.memory_s, b.memory_s);
        assert_eq!(a.compute_1core_s, b.compute_1core_s);
        // factor 1.0 is the plain op_cost
        let plain = op_cost(&g, g.node(n), &card, false);
        assert_eq!(plain.compute_1core_s, iso.compute_1core_s);
        assert_eq!(plain.memory_s, iso.memory_s);
    }

    #[test]
    fn host_cost_positive() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[100, 4]), DType::F32, TensorKind::Input);
        let y = g.add_tensor("y", Shape::new(&[100, 80]), DType::F32, TensorKind::Activation);
        let n = g.add_node("roi", OpKind::RoiAlign, vec![x], vec![y]);
        let host = crate::platform::HostSpec::default();
        assert!(host_op_cost(&g, g.node(n), &host) > 0.0);
    }
}
