//! Quantization pass (§V-B).
//!
//! Workflow mirrors the paper's: target the compute-heavy ops (FC, Conv);
//! estimate per-layer quantization error; fall back to fp16 where int8 error
//! is too high; always skip the *last* FC (and the first conv), which the
//! paper found necessary to stay within the 0.05% NE budget. Embedding
//! tables go to mixed int8/int4 independently.

use crate::graph::ops::OpKind;
use crate::graph::{DType, Graph, TensorKind};

/// Per-node decision record (surfaced by `fbia compile-report`).
#[derive(Debug, Clone, PartialEq)]
pub enum QuantDecision {
    /// converted to int8
    Int8,
    /// kept fp16 because estimated error exceeded the budget
    FallbackFp16 { est_error: f64 },
    /// on the skip list (first conv / last FC)
    Skipped,
    /// not a quantization target
    NotTarget,
}

#[derive(Debug, Clone)]
pub struct QuantReport {
    pub decisions: Vec<(String, QuantDecision)>,
    pub int8_ops: usize,
    pub fp16_fallbacks: usize,
    pub skipped: usize,
}

/// Error budget per op. The paper's workflow iterates precision until the
/// end-to-end metric passes; at the op level that materializes as a
/// per-layer error ceiling.
pub const DEFAULT_ERROR_BUDGET: f64 = 0.035;

/// Estimated relative error of int8 row-wise quantization for a layer with
/// contraction depth `k`: quantization noise grows ~ sqrt(k) * lsb with
/// random signs. The constant is calibrated against the python kernel tests
/// (test_quant_fc_close_to_fp32).
pub fn estimate_int8_error(k: usize) -> f64 {
    (k as f64).sqrt() / 127.0 * 0.25
}

/// Apply int8 quantization to eligible FC/Conv ops, with fp16 fallback and
/// skip rules. Returns the rewritten graph + report.
pub fn quantize(g: &Graph, error_budget: f64) -> (Graph, QuantReport) {
    let mut out = g.clone();
    let mut decisions = Vec::new();
    let (mut int8_ops, mut fallbacks, mut skipped) = (0, 0, 0);

    // identify the last FC in topological order (skip list, §V-B)
    let order = g.topo_order().expect("valid graph");
    let last_fc = order
        .iter()
        .rev()
        .find(|&&nid| matches!(g.nodes[nid].kind, OpKind::Fc | OpKind::QuantizedFc))
        .copied();
    // first conv = skip list too
    let first_conv = order
        .iter()
        .find(|&&nid| matches!(g.nodes[nid].kind, OpKind::Conv { .. }))
        .copied();

    for &nid in &order {
        let node = &g.nodes[nid];
        let decision = match node.kind {
            OpKind::Fc => {
                if Some(nid) == last_fc {
                    skipped += 1;
                    QuantDecision::Skipped
                } else {
                    let k = g.tensor(node.inputs[1]).shape.dim(1);
                    let err = estimate_int8_error(k);
                    if err > error_budget {
                        fallbacks += 1;
                        QuantDecision::FallbackFp16 { est_error: err }
                    } else {
                        out.nodes[nid].kind = OpKind::QuantizedFc;
                        retype_weight(&mut out, nid, DType::I8);
                        int8_ops += 1;
                        QuantDecision::Int8
                    }
                }
            }
            OpKind::Conv { groups, stride, kh, kw, quantized: false } => {
                if Some(nid) == first_conv {
                    skipped += 1;
                    QuantDecision::Skipped
                } else {
                    let cin = g.tensor(node.inputs[0]).shape.dim(3);
                    let k = (cin / groups) * kh * kw;
                    let err = estimate_int8_error(k);
                    if err > error_budget {
                        fallbacks += 1;
                        QuantDecision::FallbackFp16 { est_error: err }
                    } else {
                        out.nodes[nid].kind =
                            OpKind::Conv { groups, stride, kh, kw, quantized: true };
                        retype_weight(&mut out, nid, DType::I8);
                        int8_ops += 1;
                        QuantDecision::Int8
                    }
                }
            }
            _ => QuantDecision::NotTarget,
        };
        decisions.push((node.name.clone(), decision));
    }

    (out, QuantReport { decisions, int8_ops, fp16_fallbacks: fallbacks, skipped })
}

fn retype_weight(g: &mut Graph, nid: usize, dt: DType) {
    let widx = g.nodes[nid]
        .inputs
        .iter()
        .copied()
        .find(|&t| g.tensors[t].kind == TensorKind::Weight);
    if let Some(w) = widx {
        g.tensors[w].dtype = dt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::{dlrm, DlrmSpec, ModelId};

    #[test]
    fn last_fc_skipped() {
        let mut spec = DlrmSpec::base();
        spec.quantized_fc = false; // start un-quantized
        let g = dlrm(&spec, 32);
        let (q, report) = quantize(&g, DEFAULT_ERROR_BUDGET);
        q.validate().unwrap();
        assert!(report.skipped >= 1, "{report:?}");
        // the last FC (top_fc2) must not be int8
        let last = q
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Fc | OpKind::QuantizedFc))
            .last()
            .unwrap();
        assert_eq!(last.kind, OpKind::Fc);
    }

    #[test]
    fn most_fcs_become_int8() {
        let mut spec = DlrmSpec::base();
        spec.quantized_fc = false;
        let g = dlrm(&spec, 32);
        let (_, report) = quantize(&g, DEFAULT_ERROR_BUDGET);
        assert!(report.int8_ops >= 3, "{report:?}");
    }

    #[test]
    fn tight_budget_forces_fp16_fallback() {
        let mut spec = DlrmSpec::base();
        spec.quantized_fc = false;
        let g = dlrm(&spec, 32);
        let (_, report) = quantize(&g, 1e-6);
        assert_eq!(report.int8_ops, 0);
        assert!(report.fp16_fallbacks >= 3, "{report:?}");
    }

    #[test]
    fn error_estimate_grows_with_depth() {
        assert!(estimate_int8_error(4096) > estimate_int8_error(64));
    }

    #[test]
    fn weight_dtype_rewritten() {
        let mut spec = DlrmSpec::base();
        spec.quantized_fc = false;
        let g = dlrm(&spec, 32);
        let (q, _) = quantize(&g, DEFAULT_ERROR_BUDGET);
        let int8_weights = q
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Weight && t.dtype == DType::I8)
            .count();
        assert!(int8_weights >= 3, "{int8_weights}");
    }

    #[test]
    fn cnn_first_conv_skipped() {
        let g = ModelId::ResNeXt101.build();
        // build() already marks quantized convs; force a fresh pass anyway:
        let (q, report) = quantize(&g, DEFAULT_ERROR_BUDGET);
        q.validate().unwrap();
        // the stem conv in the builder is unquantized; the pass must keep it so
        let stem = q.nodes.iter().find(|n| n.name == "stem").unwrap();
        assert!(matches!(stem.kind, OpKind::Conv { quantized: false, .. }));
        let _ = report;
    }
}
