//! Resource allocation: Accel Cores per co-resident partition (§VI-B).
//!
//! On each card of the recsys deployment an SLS shard and a dense replica
//! run concurrently; the compiler sweeps the (small) space of core splits
//! and picks the one balancing their runtimes — the paper lands on 1-in-3
//! cores for SLS. Because requests pipeline (Fig. 6 right), steady-state
//! throughput is set by max(sls_time, dense_time).

use crate::compiler::parallelize::ParallelPlan;
use crate::compiler::partition::{Partition, PartitionKind, Plan};
use crate::compiler::placement::schedule;
use crate::graph::Graph;
use crate::platform::CardSpec;

/// One point of the allocation sweep.
#[derive(Debug, Clone)]
pub struct AllocPoint {
    pub sls_cores: usize,
    pub dense_cores: usize,
    pub sls_time_s: f64,
    pub dense_time_s: f64,
    /// pipelined steady-state time per batch.
    pub stage_time_s: f64,
}

/// Result of the sweep.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub points: Vec<AllocPoint>,
    pub best: AllocPoint,
}

/// Sweep core allocations for a card hosting `sls` and `dense` partitions.
pub fn sweep_cores(
    g: &Graph,
    sls: &Partition,
    dense: &Partition,
    plan: &ParallelPlan,
    card: &CardSpec,
    use_hints: bool,
) -> Allocation {
    assert_eq!(sls.kind, PartitionKind::Sls);
    let total = card.accel_cores;
    let mut points = Vec::new();
    for sls_cores in 1..total {
        let dense_cores = total - sls_cores;
        let s = schedule(g, &sls.nodes, plan, card, sls_cores, use_hints);
        let d = schedule(g, &dense.nodes, plan, card, dense_cores, use_hints);
        points.push(AllocPoint {
            sls_cores,
            dense_cores,
            sls_time_s: s.makespan_s,
            dense_time_s: d.makespan_s,
            stage_time_s: s.makespan_s.max(d.makespan_s),
        });
    }
    let best = points
        .iter()
        .min_by(|a, b| a.stage_time_s.total_cmp(&b.stage_time_s))
        .cloned()
        .expect("non-empty sweep");
    Allocation { points, best }
}

/// Convenience: run the sweep for the first SLS partition of a plan.
pub fn sweep_plan(
    g: &Graph,
    plan: &Plan,
    ppar: &ParallelPlan,
    card: &CardSpec,
    use_hints: bool,
) -> Option<Allocation> {
    let sls = plan.partitions.iter().find(|p| p.kind == PartitionKind::Sls)?;
    let dense = plan.dense_partition()?;
    Some(sweep_cores(g, sls, dense, ppar, card, use_hints))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::parallelize::parallelize;
    use crate::compiler::partition::partition_recsys;
    use crate::config::CompilerConfig;
    use crate::graph::models::ModelId;
    use crate::platform::NodeSpec;

    #[test]
    fn sweep_finds_interior_balance() {
        let g = ModelId::RecsysComplex.build();
        let node = NodeSpec::default();
        let cfg = CompilerConfig::default();
        let plan = partition_recsys(&g, &cfg, &node).unwrap();
        let ppar = parallelize(&g, &node.card, true);
        let alloc = sweep_plan(&g, &plan, &ppar, &node.card, true).unwrap();
        // the best split gives SLS a minority of cores (paper: 1 in 3)
        let frac = alloc.best.sls_cores as f64 / node.card.accel_cores as f64;
        assert!(frac <= 0.5, "sls fraction {frac}");
        assert!(alloc.best.sls_cores >= 1);
        // sweep covers all splits
        assert_eq!(alloc.points.len(), node.card.accel_cores - 1);
    }

    #[test]
    fn best_is_min_stage_time() {
        let g = ModelId::RecsysBase.build();
        let node = NodeSpec::default();
        let plan = partition_recsys(&g, &CompilerConfig::default(), &node).unwrap();
        let ppar = parallelize(&g, &node.card, true);
        let alloc = sweep_plan(&g, &plan, &ppar, &node.card, true).unwrap();
        for p in &alloc.points {
            assert!(alloc.best.stage_time_s <= p.stage_time_s + 1e-12);
        }
    }

    #[test]
    fn stage_time_is_max_of_parts() {
        let g = ModelId::RecsysBase.build();
        let node = NodeSpec::default();
        let plan = partition_recsys(&g, &CompilerConfig::default(), &node).unwrap();
        let ppar = parallelize(&g, &node.card, true);
        let alloc = sweep_plan(&g, &plan, &ppar, &node.card, true).unwrap();
        for p in &alloc.points {
            assert!((p.stage_time_s - p.sls_time_s.max(p.dense_time_s)).abs() < 1e-15);
        }
    }
}
