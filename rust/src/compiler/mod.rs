//! Glow-like compiler (§IV-C): graph optimization → quantization →
//! partitioning → parallelization → placement, driven by [`compile`].
//!
//! The output [`CompiledModel`] is what the simulator executes and what the
//! `fbia compile-report` CLI prints.

pub mod alloc;
pub mod optimize;
pub mod parallelize;
pub mod partition;
pub mod perf_model;
pub mod placement;
pub mod quantize;

use crate::config::Config;
use crate::graph::Graph;
use crate::util::error::Result;
use parallelize::ParallelPlan;
use partition::{PartitionKind, Plan};
use placement::Schedule;

/// A fully compiled model: optimized graph + multi-card plan + per-partition
/// schedules + the decisions taken along the way.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    pub graph: Graph,
    pub plan: Plan,
    pub parallel: ParallelPlan,
    /// schedule per partition id (host partitions have no card schedule).
    pub schedules: Vec<Option<Schedule>>,
    pub opt_stats: optimize::OptStats,
    pub quant_report: Option<quantize::QuantReport>,
    /// chosen SLS core allocation (recsys only).
    pub sls_cores: Option<usize>,
}

/// Run the full pipeline on `g` under `cfg`.
pub fn compile(g: &Graph, cfg: &Config) -> Result<CompiledModel> {
    // 1. graph optimizations (§IV-C)
    let (g1, opt_stats) = if cfg.compiler.graph_optimize {
        optimize::optimize(g)
    } else {
        (g.clone(), optimize::OptStats::default())
    };

    // 2. quantization (§V-B)
    let (g2, quant_report) = if cfg.compiler.quantize_int8 {
        let (q, r) = quantize::quantize(&g1, quantize::DEFAULT_ERROR_BUDGET);
        (q, Some(r))
    } else {
        (g1, None)
    };

    // 3. multi-card partitioning (§VI-B)
    let plan = partition::partition(&g2, &cfg.compiler, &cfg.node)?;

    // 4. op parallelization (§VI-B)
    let parallel = parallelize::parallelize(&g2, &cfg.node.card, cfg.compiler.parallelize);

    // 5. core allocation for co-resident partitions (recsys; §VI-B)
    let has_sls = plan.partitions.iter().any(|p| p.kind == PartitionKind::Sls);
    let sls_cores = if has_sls {
        let cores = cfg.node.card.accel_cores;
        let from_cfg = ((cores as f64) * cfg.compiler.sls_core_fraction).round() as usize;
        Some(from_cfg.clamp(1, cores - 1))
    } else {
        None
    };

    // 6. placement per partition (§VI-B)
    let cores = cfg.node.card.accel_cores;
    let schedules = plan
        .partitions
        .iter()
        .map(|p| match p.kind {
            PartitionKind::Host => None,
            PartitionKind::Sls => Some(placement::schedule(
                &g2,
                &p.nodes,
                &parallel,
                &cfg.node.card,
                sls_cores.unwrap_or(cores),
                cfg.compiler.placement_hints,
            )),
            PartitionKind::Dense => Some(placement::schedule(
                &g2,
                &p.nodes,
                &parallel,
                &cfg.node.card,
                cores - sls_cores.unwrap_or(0),
                cfg.compiler.placement_hints,
            )),
            PartitionKind::Full => Some(placement::schedule(
                &g2,
                &p.nodes,
                &parallel,
                &cfg.node.card,
                cores,
                cfg.compiler.placement_hints,
            )),
        })
        .collect();

    Ok(CompiledModel {
        graph: g2,
        plan,
        parallel,
        schedules,
        opt_stats,
        quant_report,
        sls_cores,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::graph::models::ModelId;

    #[test]
    fn compile_all_models() {
        let cfg = Config::default();
        for id in ModelId::ALL {
            let g = id.build();
            let c = compile(&g, &cfg).unwrap_or_else(|e| panic!("{}: {e}", g.name));
            assert_eq!(c.schedules.len(), c.plan.partitions.len());
            for (p, s) in c.plan.partitions.iter().zip(&c.schedules) {
                match p.kind {
                    PartitionKind::Host => assert!(s.is_none()),
                    _ => assert!(s.is_some(), "{} partition {}", g.name, p.id),
                }
            }
        }
    }

    #[test]
    fn recsys_gets_sls_core_allocation() {
        let cfg = Config::default();
        let c = compile(&ModelId::RecsysBase.build(), &cfg).unwrap();
        let cores = cfg.node.card.accel_cores;
        // 1-in-3 of 12 cores = 4
        assert_eq!(c.sls_cores, Some((cores as f64 / 3.0).round() as usize));
    }

    #[test]
    fn cv_has_no_sls_allocation() {
        let cfg = Config::default();
        let c = compile(&ModelId::ResNeXt101.build(), &cfg).unwrap();
        assert_eq!(c.sls_cores, None);
    }

    #[test]
    fn quantization_disabled_respected() {
        let mut cfg = Config::default();
        cfg.compiler.quantize_int8 = false;
        let c = compile(&ModelId::XlmR.build(), &cfg).unwrap();
        assert!(c.quant_report.is_none());
    }
}
