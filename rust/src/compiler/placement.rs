//! Placement & scheduling onto Accel Cores (§VI-B, §IV-D).
//!
//! Two modes, matching the paper:
//! * **vendor default** — graph-order scheduling with round-robin core
//!   assignment (what you get with no hints);
//! * **explicit placement hints** — critical-path-priority list scheduling
//!   with earliest-finish-time core selection, informed by the perf model
//!   ("list scheduling informed by a performance model learned by
//!   profiling"). Hints can be *rejected*: SRAM tensor-placement hints that
//!   exceed capacity fall back to LPDDR (§IV-D), which shows up as higher
//!   memory time for those ops.

use crate::compiler::parallelize::ParallelPlan;
use crate::compiler::perf_model::{op_cost_shared_dram, OP_OVERHEAD_S};
use crate::graph::{Graph, NodeId, TensorKind};
use crate::platform::CardSpec;
use std::collections::HashMap;

/// Result of scheduling one partition onto one card.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// (node, subtask) → (core, start_s, end_s)
    pub tasks: Vec<ScheduledTask>,
    /// end-to-end makespan, seconds.
    pub makespan_s: f64,
    /// average core busy fraction over the makespan (§VI-B reports 78%).
    pub core_utilization: f64,
    /// tensor-placement hints rejected for capacity (§IV-D).
    pub hints_rejected: usize,
    /// bytes of weights resident in SRAM.
    pub sram_resident_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct ScheduledTask {
    pub node: NodeId,
    pub subtask: usize,
    pub core: usize,
    pub start_s: f64,
    pub end_s: f64,
}

/// Decide which weights live in SRAM: greedy by (bytes saved per byte of
/// SRAM) until capacity; everything else stays in LPDDR. Returns the set of
/// nodes whose weights are on-chip + rejected hint count.
fn sram_residency(g: &Graph, nodes: &[NodeId], card: &CardSpec) -> (Vec<bool>, usize, usize) {
    let mut order: Vec<(usize, NodeId)> = Vec::new(); // (weight bytes, node)
    for &nid in nodes {
        let bytes: usize = g.nodes[nid]
            .inputs
            .iter()
            .filter(|&&t| g.tensor(t).kind == TensorKind::Weight)
            .map(|&t| g.tensor(t).bytes())
            .sum();
        if bytes > 0 {
            order.push((bytes, nid));
        }
    }
    // hot-first: smaller weights first (most reuse per byte for FCs)
    order.sort_by_key(|&(b, _)| b);
    let cap = card.onchip_bytes();
    let mut used = 0usize;
    let mut onchip = vec![false; g.nodes.len()];
    let mut rejected = 0usize;
    for (bytes, nid) in order {
        if used + bytes <= cap {
            used += bytes;
            onchip[nid] = true;
        } else {
            rejected += 1; // hint didn't fit — vendor rejects it (§IV-D)
        }
    }
    (onchip, rejected, used)
}

/// Schedule `nodes` (a partition) on `cores` cores of `card`.
///
/// `use_hints` selects list scheduling vs vendor-default order.
pub fn schedule(
    g: &Graph,
    nodes: &[NodeId],
    plan: &ParallelPlan,
    card: &CardSpec,
    cores: usize,
    use_hints: bool,
) -> Schedule {
    schedule_shared_dram(g, nodes, plan, card, cores, use_hints, 1.0)
}

/// [`schedule`] for a partition that shares the card's DRAM with a
/// co-resident partition: every op is costed with
/// [`op_cost_shared_dram`]'s occupancy factor, so memory-bound ops stretch
/// while compute-bound ones are untouched (§VI-B SLS/dense co-residency).
pub fn schedule_shared_dram(
    g: &Graph,
    nodes: &[NodeId],
    plan: &ParallelPlan,
    card: &CardSpec,
    cores: usize,
    use_hints: bool,
    dram_occupancy: f64,
) -> Schedule {
    let cores = cores.max(1);
    let in_partition: HashMap<NodeId, ()> = nodes.iter().map(|&n| (n, ())).collect();
    let (onchip, hints_rejected, sram_resident_bytes) = sram_residency(g, nodes, card);

    // dependency edges within the partition
    let producers = g.producers();
    let topo = g.topo_order().expect("valid graph");
    let topo_pos: HashMap<NodeId, usize> = topo.iter().enumerate().map(|(i, &n)| (n, i)).collect();
    let mut order: Vec<NodeId> = nodes.to_vec();
    order.sort_by_key(|n| topo_pos[n]);

    // critical-path priority (hints mode): longest path to a sink using
    // 1-core op times
    let time_1core: HashMap<NodeId, f64> = order
        .iter()
        .map(|&nid| {
            let c = op_cost_shared_dram(g, &g.nodes[nid], card, onchip[nid], dram_occupancy);
            (nid, c.time_s(plan.split_of(nid).max(1)))
        })
        .collect();
    let mut cp: HashMap<NodeId, f64> = HashMap::new();
    for &nid in order.iter().rev() {
        let succ_max = order
            .iter()
            .filter(|&&m| {
                g.nodes[m]
                    .inputs
                    .iter()
                    .any(|&t| producers[t] == Some(nid))
            })
            .map(|&m| cp.get(&m).copied().unwrap_or(0.0))
            .fold(0.0, f64::max);
        cp.insert(nid, time_1core[&nid] + succ_max);
    }

    let mut ready_order = order.clone();
    if use_hints {
        // schedule high-critical-path nodes first within each topo level
        ready_order.sort_by(|a, b| {
            topo_pos[a]
                .cmp(&topo_pos[b])
                .then(cp[b].partial_cmp(&cp[a]).unwrap())
        });
    }

    let mut core_free = vec![0.0f64; cores];
    let mut node_end: HashMap<NodeId, f64> = HashMap::new();
    let mut tasks = Vec::new();
    let mut rr = 0usize; // round-robin cursor for the no-hints mode

    for &nid in &ready_order {
        let node = &g.nodes[nid];
        // dependency ready time (only deps inside this partition)
        let dep_ready = node
            .inputs
            .iter()
            .filter_map(|&t| producers[t])
            .filter(|p| in_partition.contains_key(p))
            .map(|p| node_end.get(&p).copied().unwrap_or(0.0))
            .fold(0.0, f64::max);

        let splits = plan.split_of(nid).max(1).min(cores);
        let cost = op_cost_shared_dram(g, node, card, onchip[nid], dram_occupancy);
        // each subtask: compute/splits (already parallel) but memory shared
        let sub_time = (cost.compute_1core_s / splits as f64).max(cost.memory_s) + OP_OVERHEAD_S;

        let mut end_max = 0.0f64;
        for s in 0..splits {
            let core = if use_hints {
                // earliest-finish-time core
                (0..cores)
                    .min_by(|&a, &b| core_free[a].partial_cmp(&core_free[b]).unwrap())
                    .unwrap()
            } else {
                let c = rr % cores;
                rr += 1;
                c
            };
            let start = core_free[core].max(dep_ready);
            let end = start + sub_time;
            core_free[core] = end;
            end_max = end_max.max(end);
            tasks.push(ScheduledTask { node: nid, subtask: s, core, start_s: start, end_s: end });
        }
        node_end.insert(nid, end_max);
    }

    let makespan = core_free.iter().cloned().fold(0.0, f64::max);
    let busy: f64 = tasks.iter().map(|t| t.end_s - t.start_s).sum();
    let util = if makespan > 0.0 { busy / (makespan * cores as f64) } else { 0.0 };
    Schedule {
        tasks,
        makespan_s: makespan,
        core_utilization: util,
        hints_rejected,
        sram_resident_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::parallelize::parallelize;
    use crate::graph::models::{xlmr, ModelId, XlmrSpec};

    fn full_partition(g: &Graph) -> Vec<NodeId> {
        g.nodes.iter().filter(|n| !n.kind.host_only()).map(|n| n.id).collect()
    }

    #[test]
    fn parallelization_speedup_on_nlp() {
        // §VI-B: "2.6x speedup when parallelizing using this heuristic"
        let g = xlmr(&XlmrSpec::paper(), 1, 32);
        let card = CardSpec::default();
        let nodes = full_partition(&g);
        let seq = ParallelPlan::sequential(&g, &card);
        let par = parallelize(&g, &card, true);
        let s_seq = schedule(&g, &nodes, &seq, &card, card.accel_cores, true);
        let s_par = schedule(&g, &nodes, &par, &card, card.accel_cores, true);
        let speedup = s_seq.makespan_s / s_par.makespan_s;
        assert!(speedup > 1.5, "speedup {speedup}");
    }

    #[test]
    fn hints_no_worse_than_default() {
        let g = ModelId::XlmR.build();
        let card = CardSpec::default();
        let nodes = full_partition(&g);
        let par = parallelize(&g, &card, true);
        let with = schedule(&g, &nodes, &par, &card, card.accel_cores, true);
        let without = schedule(&g, &nodes, &par, &card, card.accel_cores, false);
        assert!(with.makespan_s <= without.makespan_s * 1.001,
                "with {} without {}", with.makespan_s, without.makespan_s);
    }

    #[test]
    fn schedule_respects_dependencies() {
        let g = ModelId::XlmR.build();
        let card = CardSpec::default();
        let nodes = full_partition(&g);
        let par = parallelize(&g, &card, true);
        let s = schedule(&g, &nodes, &par, &card, 4, true);
        let producers = g.producers();
        let mut node_span: HashMap<NodeId, (f64, f64)> = HashMap::new();
        for t in &s.tasks {
            let e = node_span.entry(t.node).or_insert((f64::INFINITY, 0.0));
            e.0 = e.0.min(t.start_s);
            e.1 = e.1.max(t.end_s);
        }
        for t in &s.tasks {
            for &inp in &g.nodes[t.node].inputs {
                if let Some(p) = producers[inp] {
                    if let Some(&(_, p_end)) = node_span.get(&p) {
                        assert!(
                            t.start_s >= p_end - 1e-9,
                            "node {} starts {} before dep {} ends {}",
                            t.node,
                            t.start_s,
                            p,
                            p_end
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn no_core_overlap() {
        let g = ModelId::XlmR.build();
        let card = CardSpec::default();
        let nodes = full_partition(&g);
        let par = parallelize(&g, &card, true);
        let s = schedule(&g, &nodes, &par, &card, 6, true);
        let mut per_core: Vec<Vec<(f64, f64)>> = vec![Vec::new(); 6];
        for t in &s.tasks {
            per_core[t.core].push((t.start_s, t.end_s));
        }
        for spans in per_core.iter_mut() {
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in spans.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-9, "{w:?}");
            }
        }
    }

    #[test]
    fn utilization_reasonable_for_parallel_model() {
        // §VI-B: 78% utilization on the non-SLS recsys partition
        let g = ModelId::RecsysComplex.build();
        let card = CardSpec::default();
        let nodes: Vec<NodeId> = g
            .nodes
            .iter()
            .filter(|n| {
                !n.kind.host_only()
                    && !matches!(
                        n.kind,
                        crate::graph::ops::OpKind::SparseLengthsSum { .. }
                    )
            })
            .map(|n| n.id)
            .collect();
        let par = parallelize(&g, &card, true);
        let s = schedule(&g, &nodes, &par, &card, card.accel_cores, true);
        // small-batch recsys dense partitions are launch/memory bound; the
        // paper's 78% is the vendor counter on a much larger net — here we
        // just require non-degenerate utilization and a valid range.
        assert!(s.core_utilization > 0.05, "util {}", s.core_utilization);
        assert!(s.core_utilization <= 1.0);
    }

    #[test]
    fn sram_hints_rejected_when_over_capacity() {
        let g = ModelId::RegNetY.build(); // ~700 MB of weights >> SRAM
        let card = CardSpec::default();
        let nodes = full_partition(&g);
        let par = parallelize(&g, &card, true);
        let s = schedule(&g, &nodes, &par, &card, card.accel_cores, true);
        assert!(s.hints_rejected > 0);
        assert!(s.sram_resident_bytes <= card.onchip_bytes());
    }
}
