//! Op-splitting parallelization (§VI-B "Parallelization and placement").
//!
//! When a partition doesn't expose enough independent ops to fill the Accel
//! Cores, Glow splits individual ops. The heuristic follows the paper's
//! description — split by op type, dimensions, and predecessors: Matrix ops
//! split along their largest data-parallel dim until they are memory-bound
//! (no point splitting past the roofline) or the core count is reached.
//!
//! We keep splits as a plan (node → split count) consumed by the list
//! scheduler and the simulator, rather than physically rewriting the graph —
//! equivalent for timing, and it keeps the IR small.

use crate::compiler::perf_model::{op_cost, OpCost};
use crate::graph::ops::{Engine, OpKind};
use crate::graph::{Graph, NodeId};
use crate::platform::CardSpec;

/// Split decisions per node.
#[derive(Debug, Clone)]
pub struct ParallelPlan {
    pub splits: Vec<usize>,
    pub costs: Vec<OpCost>,
}

impl ParallelPlan {
    /// No-parallelization baseline (every op on one core).
    pub fn sequential(g: &Graph, card: &CardSpec) -> ParallelPlan {
        let costs = g.nodes.iter().map(|n| op_cost(g, n, card, false)).collect();
        ParallelPlan { splits: vec![1; g.nodes.len()], costs }
    }

    pub fn split_of(&self, n: NodeId) -> usize {
        self.splits[n]
    }
}

/// Maximum split supported by the op's shape (outer data-parallel dim).
fn max_split(g: &Graph, nid: NodeId) -> usize {
    let n = &g.nodes[nid];
    match n.kind {
        OpKind::Fc | OpKind::QuantizedFc | OpKind::MatMul => {
            // split along output features
            g.tensor(n.outputs[0]).shape.0.last().copied().unwrap_or(1)
        }
        OpKind::BatchMatMul => g.tensor(n.inputs[0]).shape.dim(0),
        OpKind::Conv { .. } | OpKind::ConvAddFused { .. } => {
            // split along output channels
            g.tensor(n.outputs[0]).shape.0.last().copied().unwrap_or(1)
        }
        OpKind::Conv3D { .. } => g.tensor(n.outputs[0]).shape.0.last().copied().unwrap_or(1),
        OpKind::SparseLengthsSum { .. } | OpKind::SparseLengthsSumSingle => {
            // split along the batch dimension
            g.tensor(n.outputs[0]).shape.dim(0)
        }
        _ => 1,
    }
}

/// Compute the parallelization plan for one card.
pub fn parallelize(g: &Graph, card: &CardSpec, enabled: bool) -> ParallelPlan {
    let costs: Vec<OpCost> = g.nodes.iter().map(|n| op_cost(g, n, card, false)).collect();
    if !enabled {
        return ParallelPlan { splits: vec![1; g.nodes.len()], costs };
    }
    let splits = g
        .nodes
        .iter()
        .map(|n| {
            if n.kind.engine() != Engine::Matrix
                && !matches!(n.kind, OpKind::SparseLengthsSum { .. })
            {
                return 1;
            }
            let c = &costs[n.id];
            // don't split ops that are already trivial
            if c.compute_1core_s < 4.0 * crate::compiler::perf_model::OP_OVERHEAD_S {
                return 1;
            }
            card.accel_cores
                .min(c.saturation_cores())
                .min(max_split(g, n.id))
                .max(1)
        })
        .collect();
    ParallelPlan { splits, costs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::{ModelId, XlmrSpec};

    #[test]
    fn big_matmuls_split_small_ops_dont() {
        let g = crate::graph::models::xlmr(&XlmrSpec::paper(), 1, 64);
        let card = CardSpec::default();
        let plan = parallelize(&g, &card, true);
        let mut split_some = false;
        for n in &g.nodes {
            match n.kind {
                OpKind::MatMul => {
                    if plan.split_of(n.id) > 1 {
                        split_some = true;
                    }
                }
                OpKind::Add | OpKind::Softmax | OpKind::LayerNorm => {
                    assert_eq!(plan.split_of(n.id), 1, "{}", n.name);
                }
                _ => {}
            }
        }
        assert!(split_some);
    }

    #[test]
    fn disabled_gives_all_ones() {
        let g = ModelId::XlmR.build();
        let plan = parallelize(&g, &CardSpec::default(), false);
        assert!(plan.splits.iter().all(|&s| s == 1));
    }

    #[test]
    fn splits_bounded_by_cores_and_shape() {
        let g = ModelId::RecsysComplex.build();
        let card = CardSpec::default();
        let plan = parallelize(&g, &card, true);
        for n in &g.nodes {
            let s = plan.split_of(n.id);
            assert!(s >= 1 && s <= card.accel_cores, "{}: {s}", n.name);
            assert!(s <= max_split(&g, n.id).max(1), "{}", n.name);
        }
    }

    #[test]
    fn memory_bound_ops_not_oversplit() {
        // SLS is memory-bound: splitting past saturation gains nothing, the
        // heuristic must cap at saturation_cores
        let g = ModelId::RecsysBase.build();
        let card = CardSpec::default();
        let plan = parallelize(&g, &card, true);
        for n in &g.nodes {
            if matches!(n.kind, OpKind::SparseLengthsSum { .. }) {
                let c = &plan.costs[n.id];
                assert!(plan.split_of(n.id) <= c.saturation_cores().max(1));
            }
        }
    }
}
