//! Multi-card model partitioner (§VI-B, Fig. 6).
//!
//! Recommendation models: embedding tables are *model-parallel* across the
//! SLS cards (they don't fit one card's 16 GB), dense compute is
//! *data-parallel* on the remaining cards; pooled embeddings travel card→
//! card over PCIe (P2P after §VI-C). CV/NLP models fit a single card and are
//! replicated data-parallel. Host-only ops (NMS, ROIAlign) stay on the CPU.

use crate::config::CompilerConfig;
use crate::graph::ops::OpKind;
use crate::graph::{Graph, NodeId, TensorKind};
use crate::platform::NodeSpec;
use crate::util::error::{bail, Result};

/// What a partition does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    /// embedding lookups for a shard of tables (model parallel)
    Sls,
    /// dense compute (data-parallel replicas)
    Dense,
    /// whole model on one card (CV/NLP)
    Full,
    /// ops kept on the host CPU (§VI-A)
    Host,
}

/// One partition of the net.
#[derive(Debug, Clone)]
pub struct Partition {
    pub id: usize,
    pub kind: PartitionKind,
    /// card index; None = host CPU.
    pub card: Option<usize>,
    pub nodes: Vec<NodeId>,
    /// bytes of weights resident on this partition's device.
    pub weight_bytes: usize,
    /// profiled lookup load (for SLS balance diagnostics).
    pub lookup_load: f64,
}

/// A cross-partition tensor transfer per request.
#[derive(Debug, Clone)]
pub struct CrossTransfer {
    pub from: usize,
    pub to: usize,
    pub bytes: usize,
    pub tensor: String,
}

/// The partitioning plan for one model.
#[derive(Debug, Clone)]
pub struct Plan {
    pub model: String,
    pub partitions: Vec<Partition>,
    /// how many data-parallel replicas the Dense/Full partition has.
    pub replicas: usize,
    pub transfers: Vec<CrossTransfer>,
}

impl Plan {
    pub fn sls_partitions(&self) -> impl Iterator<Item = &Partition> {
        self.partitions.iter().filter(|p| p.kind == PartitionKind::Sls)
    }

    pub fn dense_partition(&self) -> Option<&Partition> {
        self.partitions
            .iter()
            .find(|p| matches!(p.kind, PartitionKind::Dense | PartitionKind::Full))
    }

    /// Verify plan invariants (also exercised by property tests):
    /// every non-host node in exactly one partition, host ops on host,
    /// per-card weights within LPDDR capacity.
    pub fn check(&self, g: &Graph, node: &NodeSpec) -> Result<()> {
        let mut owner = vec![0usize; g.nodes.len()];
        for p in &self.partitions {
            for &n in &p.nodes {
                owner[n] += 1;
                if g.nodes[n].kind.host_only() != (p.card.is_none()) {
                    bail!("node {} placement violates host rule", g.nodes[n].name);
                }
            }
        }
        for (nid, &c) in owner.iter().enumerate() {
            if c != 1 {
                bail!("node {} assigned {} times", g.nodes[nid].name, c);
            }
        }
        for p in &self.partitions {
            if p.card.is_some() && p.weight_bytes > node.card.lpddr_bytes {
                bail!(
                    "partition {} weights {} exceed card LPDDR {}",
                    p.id,
                    p.weight_bytes,
                    node.card.lpddr_bytes
                );
            }
        }
        Ok(())
    }
}

/// Weight bytes attached to a node (its Weight-kind inputs).
fn node_weight_bytes(g: &Graph, nid: NodeId) -> usize {
    g.nodes[nid]
        .inputs
        .iter()
        .filter(|&&t| g.tensor(t).kind == TensorKind::Weight)
        .map(|&t| g.tensor(t).bytes())
        .sum()
}

/// Partition a model across the node.
pub fn partition(g: &Graph, cfg: &CompilerConfig, node: &NodeSpec) -> Result<Plan> {
    let has_sls = g
        .nodes
        .iter()
        .any(|n| matches!(n.kind, OpKind::SparseLengthsSum { .. } | OpKind::SparseLengthsSumSingle));
    let total_weights = g.weight_bytes();
    if has_sls && total_weights > node.card.lpddr_bytes {
        partition_recsys(g, cfg, node)
    } else {
        partition_single_card(g, node)
    }
}

/// Fig. 6 scheme: SLS model-parallel + dense data-parallel.
///
/// Per the paper, every card carries an SLS shard *and* a dense replica; a
/// subset of each card's Accel Cores serves SLS, the rest dense (the 1-in-3
/// split of §VI-B, swept by [`crate::compiler::alloc`]). `cfg.sls_cards`
/// restricts the shard spread for ablations (default: all cards).
pub fn partition_recsys(g: &Graph, cfg: &CompilerConfig, node: &NodeSpec) -> Result<Plan> {
    let sls_cards = cfg.sls_cards.min(node.cards).max(1);
    let dense_cards = node.cards;

    // collect SLS nodes with their weight + load
    struct SlsItem {
        nid: NodeId,
        bytes: usize,
        load: f64,
    }
    let mut items: Vec<SlsItem> = Vec::new();
    let mut dense_nodes: Vec<NodeId> = Vec::new();
    let mut host_nodes: Vec<NodeId> = Vec::new();
    for n in &g.nodes {
        match n.kind {
            OpKind::SparseLengthsSum { avg_lookups } => {
                let bytes = node_weight_bytes(g, n.id);
                let batch = g.tensor(n.outputs[0]).shape.dim(0) as f64;
                let dim = g.tensor(n.outputs[0]).shape.dim(1) as f64;
                items.push(SlsItem { nid: n.id, bytes, load: avg_lookups * batch * dim });
            }
            OpKind::SparseLengthsSumSingle => {
                let bytes = node_weight_bytes(g, n.id);
                let batch = g.tensor(n.outputs[0]).shape.dim(0) as f64;
                let dim = g.tensor(n.outputs[0]).shape.dim(1) as f64;
                items.push(SlsItem { nid: n.id, bytes, load: batch * dim });
            }
            _ if n.kind.host_only() => host_nodes.push(n.id),
            _ => dense_nodes.push(n.id),
        }
    }
    if items.is_empty() {
        bail!("partition_recsys called on a graph without SLS ops");
    }

    // Length-aware (§VI-B "Optimizing Sparse Lookups"): greedy balance on
    // the profiled lookup load — sort descending and place each table on
    // the least-loaded card with capacity. Naive baseline: contiguous
    // table ranges balanced by byte size only, blind to lookup counts —
    // "naive load balancing without the information".
    let mut card_bytes = vec![0usize; sls_cards];
    let mut card_load = vec![0f64; sls_cards];
    let mut card_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); sls_cards];
    if cfg.sls_length_aware {
        // total_cmp, not partial_cmp().unwrap(): a NaN load (degenerate
        // profile, e.g. avg_lookups = 0.0/0.0) must not panic the compiler
        items.sort_by(|a, b| b.load.total_cmp(&a.load));
        for it in &items {
            let mut best: Option<usize> = None;
            for c in 0..sls_cards {
                if card_bytes[c] + it.bytes > node.card.lpddr_bytes {
                    continue;
                }
                if best.is_none() || card_load[c] < card_load[best.unwrap()] {
                    best = Some(c);
                }
            }
            let Some(c) = best else {
                bail!(
                    "embedding tables do not fit: {} cards x {} B",
                    sls_cards,
                    node.card.lpddr_bytes
                )
            };
            card_bytes[c] += it.bytes;
            card_load[c] += it.load;
            card_nodes[c].push(it.nid);
        }
    } else {
        // contiguous split in model order, target = equal bytes per card
        let total_bytes: usize = items.iter().map(|i| i.bytes).sum();
        let target = total_bytes.div_ceil(sls_cards);
        let mut c = 0usize;
        for it in &items {
            if card_bytes[c] + it.bytes > target && c + 1 < sls_cards && !card_nodes[c].is_empty()
            {
                c += 1;
            }
            if card_bytes[c] + it.bytes > node.card.lpddr_bytes {
                bail!(
                    "embedding tables do not fit: {} cards x {} B",
                    sls_cards,
                    node.card.lpddr_bytes
                );
            }
            card_bytes[c] += it.bytes;
            card_load[c] += it.load;
            card_nodes[c].push(it.nid);
        }
    }

    let mut partitions = Vec::new();
    for c in 0..sls_cards {
        partitions.push(Partition {
            id: partitions.len(),
            kind: PartitionKind::Sls,
            card: Some(c),
            nodes: std::mem::take(&mut card_nodes[c]),
            weight_bytes: card_bytes[c],
            lookup_load: card_load[c],
        });
    }

    // dense partition: replicated on every card (data parallel); weights
    // must fit alongside the card's SLS shard
    let dense_weights: usize = dense_nodes.iter().map(|&n| node_weight_bytes(g, n)).sum();
    let dense_id = partitions.len();
    partitions.push(Partition {
        id: dense_id,
        kind: PartitionKind::Dense,
        card: Some(0), // canonical card; replicas on all cards
        nodes: dense_nodes,
        weight_bytes: dense_weights,
        lookup_load: 0.0,
    });
    if !host_nodes.is_empty() {
        partitions.push(Partition {
            id: dense_id + 1,
            kind: PartitionKind::Host,
            card: None,
            nodes: host_nodes,
            weight_bytes: 0,
            lookup_load: 0.0,
        });
    }

    // per-request transfers: each SLS card ships its pooled outputs to the
    // dense card (P2P candidates, §VI-C)
    let mut transfers = Vec::new();
    for p in &partitions {
        if p.kind != PartitionKind::Sls {
            continue;
        }
        let bytes: usize = p
            .nodes
            .iter()
            .flat_map(|&n| g.nodes[n].outputs.iter())
            .map(|&t| g.tensor(t).bytes())
            .sum();
        transfers.push(CrossTransfer {
            from: p.id,
            to: dense_id,
            bytes,
            tensor: format!("pooled_embeddings_card{}", p.card.unwrap()),
        });
    }

    let plan = Plan {
        model: g.name.clone(),
        partitions,
        replicas: dense_cards.max(1),
        transfers,
    };
    plan.check(g, node)?;
    Ok(plan)
}

/// CV/NLP: whole model on one card, replicated data-parallel (§VI-B).
pub fn partition_single_card(g: &Graph, node: &NodeSpec) -> Result<Plan> {
    let mut device_nodes = Vec::new();
    let mut host_nodes = Vec::new();
    for n in &g.nodes {
        if n.kind.host_only() {
            host_nodes.push(n.id);
        } else {
            device_nodes.push(n.id);
        }
    }
    let weight_bytes = g.weight_bytes();
    if weight_bytes > node.card.lpddr_bytes {
        bail!("model {} does not fit one card and has no SLS split", g.name);
    }
    let mut partitions = vec![Partition {
        id: 0,
        kind: PartitionKind::Full,
        card: Some(0),
        nodes: device_nodes,
        weight_bytes,
        lookup_load: 0.0,
    }];
    let mut transfers = Vec::new();
    if !host_nodes.is_empty() {
        // host<->card boundary tensors
        let host_set: std::collections::HashSet<_> = host_nodes.iter().copied().collect();
        let mut bytes = 0usize;
        for n in &g.nodes {
            if !host_set.contains(&n.id) {
                continue;
            }
            for &t in &n.inputs {
                if g.tensor(t).kind == TensorKind::Activation {
                    bytes += g.tensor(t).bytes();
                }
            }
        }
        partitions.push(Partition {
            id: 1,
            kind: PartitionKind::Host,
            card: None,
            nodes: host_nodes,
            weight_bytes: 0,
            lookup_load: 0.0,
        });
        transfers.push(CrossTransfer { from: 0, to: 1, bytes, tensor: "host_boundary".into() });
    }
    let plan = Plan { model: g.name.clone(), partitions, replicas: node.cards, transfers };
    plan.check(g, node)?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompilerConfig;
    use crate::graph::models::{dlrm, DlrmSpec, ModelId};
    use crate::util::prop::{check, Gen, UsizeIn};
    use crate::util::rng::Rng;

    fn default_node() -> NodeSpec {
        NodeSpec::default()
    }

    #[test]
    fn recsys_uses_fig6_scheme() {
        let g = ModelId::RecsysBase.build();
        let cfg = CompilerConfig::default();
        let plan = partition(&g, &cfg, &default_node()).unwrap();
        assert_eq!(plan.sls_partitions().count(), cfg.sls_cards);
        assert!(plan.dense_partition().is_some());
        assert_eq!(plan.replicas, 6); // dense replicated on every card
        assert!(!plan.transfers.is_empty());
        plan.check(&g, &default_node()).unwrap();
    }

    #[test]
    fn cv_model_single_card_replicated() {
        let g = ModelId::ResNeXt101.build();
        let plan = partition(&g, &CompilerConfig::default(), &default_node()).unwrap();
        assert_eq!(plan.partitions.len(), 1);
        assert_eq!(plan.partitions[0].kind, PartitionKind::Full);
        assert_eq!(plan.replicas, 6);
    }

    #[test]
    fn detection_model_gets_host_partition() {
        let g = ModelId::FbNetV3.build();
        let plan = partition(&g, &CompilerConfig::default(), &default_node()).unwrap();
        assert!(plan.partitions.iter().any(|p| p.kind == PartitionKind::Host));
    }

    #[test]
    fn length_aware_balances_load_better() {
        // tables with wildly uneven lookup loads but equal sizes
        let mut spec = DlrmSpec::base();
        spec.rows_per_table = 2_000_000;
        spec.num_tables = 16;
        let mut g = dlrm(&spec, 32);
        // perturb avg_lookups: tables 0..4 hot, rest cold
        for n in g.nodes.iter_mut() {
            if let OpKind::SparseLengthsSum { ref mut avg_lookups } = n.kind {
                let idx: usize = n.name.trim_start_matches("sls").parse().unwrap();
                *avg_lookups = if idx < 4 { 80.0 } else { 2.0 };
            }
        }
        let node = default_node();
        let aware = CompilerConfig { sls_length_aware: true, ..CompilerConfig::default() };
        let naive = CompilerConfig { sls_length_aware: false, ..CompilerConfig::default() };

        let imbalance = |plan: &Plan| {
            let loads: Vec<f64> = plan.sls_partitions().map(|p| p.lookup_load).collect();
            let max = loads.iter().cloned().fold(0.0, f64::max);
            let mean = loads.iter().sum::<f64>() / loads.len() as f64;
            max / mean
        };
        let pa = partition_recsys(&g, &aware, &node).unwrap();
        let pn = partition_recsys(&g, &naive, &node).unwrap();
        assert!(
            imbalance(&pa) <= imbalance(&pn) + 1e-9,
            "aware {} naive {}",
            imbalance(&pa),
            imbalance(&pn)
        );
    }

    #[test]
    fn oversized_model_without_sls_rejected() {
        let mut g = Graph::new("huge_dense");
        let x = g.add_tensor(
            "x",
            crate::graph::Shape::new(&[1, 1024]),
            crate::graph::DType::F32,
            TensorKind::Input,
        );
        let w = g.add_tensor(
            "w",
            crate::graph::Shape::new(&[20_000_000_000 / 1024, 1024]),
            crate::graph::DType::F16,
            TensorKind::Weight,
        );
        let b = g.add_tensor(
            "b",
            crate::graph::Shape::new(&[20_000_000_000 / 1024]),
            crate::graph::DType::F32,
            TensorKind::Weight,
        );
        let y = g.add_tensor(
            "y",
            crate::graph::Shape::new(&[1, 20_000_000_000 / 1024]),
            crate::graph::DType::F32,
            TensorKind::Output,
        );
        g.add_node("fc", OpKind::Fc, vec![x, w, b], vec![y]);
        assert!(partition(&g, &CompilerConfig::default(), &default_node()).is_err());
    }

    /// Property: for random table counts/sizes that fit, the plan always
    /// assigns every node exactly once and respects capacity.
    #[test]
    fn prop_partition_invariants() {
        struct SpecGen;
        impl Gen for SpecGen {
            type Value = (usize, usize, usize);
            fn generate(&self, rng: &mut Rng) -> Self::Value {
                let tables = rng.range(2, 48) as usize;
                let rows = rng.range(100_000, 30_000_000) as usize;
                let sls_cards = rng.range(1, 5) as usize;
                (tables, rows, sls_cards)
            }
            fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
                let mut out = Vec::new();
                if v.0 > 2 {
                    out.push((v.0 / 2, v.1, v.2));
                }
                if v.1 > 100_000 {
                    out.push((v.0, v.1 / 2, v.2));
                }
                out
            }
        }
        check("partition invariants", 25, &SpecGen, |&(tables, rows, sls_cards)| {
            let mut spec = DlrmSpec::base();
            spec.num_tables = tables;
            spec.rows_per_table = rows;
            let g = dlrm(&spec, 32);
            let cfg = CompilerConfig { sls_cards, ..CompilerConfig::default() };
            let node = NodeSpec::default();
            match partition_recsys(&g, &cfg, &node) {
                Ok(plan) => plan.check(&g, &node).map_err(|e| e.to_string()),
                // capacity rejections are allowed; wrong plans are not
                Err(e) if e.to_string().contains("do not fit") => Ok(()),
                Err(e) => Err(format!("unexpected error: {e}")),
            }
        });
    }

    /// Regression: a degenerate lookup profile (zero or NaN `avg_lookups`
    /// from an empty profiling window) must not panic the length-aware
    /// sort — `total_cmp` gives NaN a total order where
    /// `partial_cmp().unwrap()` aborted.
    #[test]
    fn degenerate_lookup_loads_do_not_panic_the_sort() {
        let mut spec = DlrmSpec::base();
        spec.num_tables = 8;
        spec.rows_per_table = 1_000_000;
        let mut g = dlrm(&spec, 32);
        for (i, n) in g.nodes.iter_mut().enumerate() {
            if let OpKind::SparseLengthsSum { ref mut avg_lookups } = n.kind {
                *avg_lookups = if i % 2 == 0 { f64::NAN } else { 0.0 };
            }
        }
        let cfg = CompilerConfig::default();
        let plan = partition_recsys(&g, &cfg, &default_node()).unwrap();
        plan.check(&g, &default_node()).unwrap();
        // every SLS node still placed exactly once despite the junk loads
        let placed: usize = plan.sls_partitions().map(|p| p.nodes.len()).sum();
        assert_eq!(placed, 8);
    }

    /// Property: total SLS weight bytes are preserved by partitioning.
    #[test]
    fn prop_no_weight_lost() {
        let g = ModelId::RecsysBase.build();
        let node = default_node();
        check("weights preserved", 8, &UsizeIn { lo: 1, hi: 5 }, |&cards| {
            let cfg = CompilerConfig { sls_cards: cards, ..CompilerConfig::default() };
            let plan = match partition_recsys(&g, &cfg, &node) {
                Ok(p) => p,
                Err(_) => return Ok(()),
            };
            let sls_bytes: usize = plan.sls_partitions().map(|p| p.weight_bytes).sum();
            let table_bytes: usize = g
                .tensors
                .iter()
                .filter(|t| t.kind == TensorKind::Weight && t.name.starts_with("table"))
                .map(|t| t.bytes())
                .sum();
            if sls_bytes == table_bytes {
                Ok(())
            } else {
                Err(format!("{sls_bytes} != {table_bytes}"))
            }
        });
    }
}
