//! Graph-level optimizations (§IV-C): common-subexpression elimination,
//! conversion elimination, dead-code elimination, and the fusions the paper
//! calls out (Conv+Add → Fused Conv_Add; Dequantize+Swish+Quantize;
//! SLS + LayerNorm is recognized but kept as a fusion *marker* since the
//! vendor level owns it).

use crate::graph::ops::OpKind;
use crate::graph::{Graph, NodeId, TensorKind};
use std::collections::HashMap;

/// Statistics from one optimize() run — surfaced in `fbia compile-report`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OptStats {
    pub cse_removed: usize,
    pub conversions_removed: usize,
    pub dead_removed: usize,
    pub conv_add_fused: usize,
    pub quant_chains_fused: usize,
}

/// Run all graph optimizations; returns the rewritten graph and stats.
pub fn optimize(g: &Graph) -> (Graph, OptStats) {
    let mut stats = OptStats::default();
    let g = cse(g, &mut stats);
    let g = eliminate_conversions(&g, &mut stats);
    let g = fuse_conv_add(&g, &mut stats);
    let g = fuse_quant_chains(&g, &mut stats);
    let g = dce(&g, &mut stats);
    (g, stats)
}

/// Common-subexpression elimination: nodes with identical kind+inputs merge.
fn cse(g: &Graph, stats: &mut OptStats) -> Graph {
    let mut seen: HashMap<String, NodeId> = HashMap::new();
    // tensor substitution map: duplicate node outputs -> canonical outputs
    let mut subst: HashMap<usize, usize> = HashMap::new();
    let order = g.topo_order().expect("valid graph");
    let mut keep: Vec<bool> = vec![true; g.nodes.len()];
    for &nid in &order {
        let n = &g.nodes[nid];
        // rewrite inputs through current substitution before keying
        let inputs: Vec<usize> =
            n.inputs.iter().map(|i| *subst.get(i).unwrap_or(i)).collect();
        let key = format!("{:?}|{:?}", n.kind, inputs);
        if let Some(&canon) = seen.get(&key) {
            // redirect this node's outputs to the canonical node's outputs
            for (dup, orig) in n.outputs.iter().zip(&g.nodes[canon].outputs) {
                // never eliminate graph outputs (they must stay produced)
                if g.tensor(*dup).kind == TensorKind::Output {
                    continue;
                }
                subst.insert(*dup, *orig);
            }
            // only drop the node if all its outputs were redirected
            if n.outputs.iter().all(|o| subst.contains_key(o)) {
                keep[nid] = false;
                stats.cse_removed += 1;
            }
        } else {
            seen.insert(key, nid);
        }
    }
    rebuild(g, &keep, &subst)
}

/// Remove ConvertTo chains that cancel (f16->f32->f16) and conversions whose
/// input already has the output dtype.
fn eliminate_conversions(g: &Graph, stats: &mut OptStats) -> Graph {
    let producers = g.producers();
    let mut keep = vec![true; g.nodes.len()];
    let mut subst: HashMap<usize, usize> = HashMap::new();
    for n in &g.nodes {
        if n.kind != OpKind::ConvertTo {
            continue;
        }
        let src = n.inputs[0];
        let dst = n.outputs[0];
        if g.tensor(dst).kind == TensorKind::Output {
            continue;
        }
        // identity conversion
        if g.tensor(src).dtype == g.tensor(dst).dtype {
            keep[n.id] = false;
            subst.insert(dst, src);
            stats.conversions_removed += 1;
            continue;
        }
        // cancelling chain: producer of src is also a ConvertTo from dst's dtype
        if let Some(p) = producers[src] {
            let pn = &g.nodes[p];
            if pn.kind == OpKind::ConvertTo
                && g.tensor(pn.inputs[0]).dtype == g.tensor(dst).dtype
            {
                keep[n.id] = false;
                subst.insert(dst, pn.inputs[0]);
                stats.conversions_removed += 1;
            }
        }
    }
    rebuild(g, &keep, &subst)
}

/// Fuse Conv directly followed by a single-consumer Add into ConvAddFused
/// (Table II "Fused Conv_Add"; the §II-D fusion requirement).
fn fuse_conv_add(g: &Graph, stats: &mut OptStats) -> Graph {
    let consumers = g.consumers();
    let mut out = g.clone();
    let mut keep = vec![true; g.nodes.len()];
    let mut subst: HashMap<usize, usize> = HashMap::new();
    for n in &g.nodes {
        let (groups, stride, kh, kw, quantized) = match n.kind {
            OpKind::Conv { groups, stride, kh, kw, quantized } => (groups, stride, kh, kw, quantized),
            _ => continue,
        };
        let conv_out = n.outputs[0];
        if g.tensor(conv_out).kind == TensorKind::Output {
            continue;
        }
        let cons = &consumers[conv_out];
        if cons.len() != 1 {
            continue;
        }
        let add = &g.nodes[cons[0]];
        if add.kind != OpKind::Add || !keep[add.id] {
            continue;
        }
        // fold: conv inherits the add's other input and output
        let other: Vec<usize> = add.inputs.iter().copied().filter(|&t| t != conv_out).collect();
        let fused = &mut out.nodes[n.id];
        fused.kind = OpKind::ConvAddFused { groups, stride, kh, kw, quantized };
        fused.inputs.extend(other);
        fused.outputs = add.outputs.clone();
        keep[add.id] = false;
        subst.insert(conv_out, add.outputs[0]);
        stats.conv_add_fused += 1;
    }
    rebuild(&out, &keep, &HashMap::new())
}

/// Fuse Dequantize → {Swish|Gelu|Relu|Sigmoid} → Quantize chains into the
/// middle op (the card executes the activation in the quantized domain).
fn fuse_quant_chains(g: &Graph, stats: &mut OptStats) -> Graph {
    let producers = g.producers();
    let consumers = g.consumers();
    let mut keep = vec![true; g.nodes.len()];
    let mut out = g.clone();
    for n in &g.nodes {
        if !matches!(n.kind, OpKind::Swish | OpKind::Gelu | OpKind::Relu | OpKind::Sigmoid) {
            continue;
        }
        let Some(pid) = producers[n.inputs[0]] else { continue };
        if g.nodes[pid].kind != OpKind::Dequantize || !keep[pid] {
            continue;
        }
        let act_out = n.outputs[0];
        let cons = &consumers[act_out];
        if cons.len() != 1 || g.nodes[cons[0]].kind != OpKind::Quantize || !keep[cons[0]] {
            continue;
        }
        let qid = cons[0];
        if g.tensor(g.nodes[qid].outputs[0]).kind == TensorKind::Output
            && g.tensor(act_out).kind == TensorKind::Output
        {
            continue;
        }
        // the activation now consumes the quantized input and produces the
        // quantized output directly
        let deq_in = g.nodes[pid].inputs[0];
        let q_out = g.nodes[qid].outputs[0];
        let act = &mut out.nodes[n.id];
        act.inputs = vec![deq_in];
        act.outputs = vec![q_out];
        keep[pid] = false;
        keep[qid] = false;
        stats.quant_chains_fused += 1;
    }
    rebuild(&out, &keep, &HashMap::new())
}

/// Dead-code elimination: drop nodes whose outputs nothing consumes and that
/// produce no graph Output.
fn dce(g: &Graph, stats: &mut OptStats) -> Graph {
    let consumers = g.consumers();
    let mut keep = vec![true; g.nodes.len()];
    // iterate to fixpoint (chains of dead nodes)
    loop {
        let mut changed = false;
        for n in &g.nodes {
            if !keep[n.id] {
                continue;
            }
            let live = n.outputs.iter().any(|&o| {
                g.tensor(o).kind == TensorKind::Output
                    || consumers[o].iter().any(|&c| keep[c])
            });
            if !live {
                keep[n.id] = false;
                stats.dead_removed += 1;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    rebuild(g, &keep, &HashMap::new())
}

/// Rebuild a graph keeping only flagged nodes, applying a tensor
/// substitution to inputs, and dropping now-unreferenced tensors.
fn rebuild(g: &Graph, keep: &[bool], subst: &HashMap<usize, usize>) -> Graph {
    let mut out = Graph::new(&g.name);
    // resolve substitution chains
    let resolve = |mut t: usize| {
        let mut hops = 0;
        while let Some(&n) = subst.get(&t) {
            t = n;
            hops += 1;
            if hops > g.tensors.len() {
                break;
            }
        }
        t
    };
    // find referenced tensors
    let mut used: Vec<bool> = vec![false; g.tensors.len()];
    for n in &g.nodes {
        if !keep[n.id] {
            continue;
        }
        for &i in &n.inputs {
            used[resolve(i)] = true;
        }
        for &o in &n.outputs {
            used[o] = true;
        }
    }
    let mut remap: Vec<Option<usize>> = vec![None; g.tensors.len()];
    for t in &g.tensors {
        if used[t.id] || t.kind == TensorKind::Output {
            let nid = out.add_tensor(&t.name, t.shape.clone(), t.dtype, t.kind);
            remap[t.id] = Some(nid);
        }
    }
    for n in &g.nodes {
        if !keep[n.id] {
            continue;
        }
        let ins: Vec<usize> =
            n.inputs.iter().map(|&i| remap[resolve(i)].expect("used input")).collect();
        let outs: Vec<usize> =
            n.outputs.iter().map(|&o| remap[o].expect("used output")).collect();
        out.add_node(&n.name, n.kind, ins, outs);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Shape};

    fn act(g: &mut Graph, name: &str, dims: &[usize]) -> usize {
        g.add_tensor(name, Shape::new(dims), DType::F32, TensorKind::Activation)
    }

    #[test]
    fn cse_merges_identical_nodes() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[4]), DType::F32, TensorKind::Input);
        let a = act(&mut g, "a", &[4]);
        let b = act(&mut g, "b", &[4]);
        g.add_node("r1", OpKind::Relu, vec![x], vec![a]);
        g.add_node("r2", OpKind::Relu, vec![x], vec![b]);
        let o = g.add_tensor("o", Shape::new(&[4]), DType::F32, TensorKind::Output);
        g.add_node("add", OpKind::Add, vec![a, b], vec![o]);
        let (opt, stats) = optimize(&g);
        assert_eq!(stats.cse_removed, 1);
        assert_eq!(opt.nodes.len(), 2);
        opt.validate().unwrap();
    }

    #[test]
    fn cancelling_conversions_removed() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[4]), DType::F16, TensorKind::Input);
        let up = g.add_tensor("up", Shape::new(&[4]), DType::F32, TensorKind::Activation);
        let down = g.add_tensor("down", Shape::new(&[4]), DType::F16, TensorKind::Activation);
        g.add_node("c1", OpKind::ConvertTo, vec![x], vec![up]);
        g.add_node("c2", OpKind::ConvertTo, vec![up], vec![down]);
        let o = g.add_tensor("o", Shape::new(&[4]), DType::F16, TensorKind::Output);
        g.add_node("relu", OpKind::Relu, vec![down], vec![o]);
        let (opt, stats) = optimize(&g);
        assert!(stats.conversions_removed >= 1, "{stats:?}");
        assert!(opt.nodes.len() <= 2);
        opt.validate().unwrap();
    }

    #[test]
    fn conv_add_fusion() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[1, 8, 8, 16]), DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", Shape::new(&[3, 3, 16, 16]), DType::I8, TensorKind::Weight);
        let y = act(&mut g, "y", &[1, 8, 8, 16]);
        g.add_node(
            "conv",
            OpKind::Conv { groups: 1, stride: 1, kh: 3, kw: 3, quantized: true },
            vec![x, w],
            vec![y],
        );
        let o = g.add_tensor("o", Shape::new(&[1, 8, 8, 16]), DType::F32, TensorKind::Output);
        g.add_node("add", OpKind::Add, vec![y, x], vec![o]);
        let (opt, stats) = optimize(&g);
        assert_eq!(stats.conv_add_fused, 1);
        assert_eq!(opt.nodes.len(), 1);
        assert!(matches!(opt.nodes[0].kind, OpKind::ConvAddFused { .. }));
        opt.validate().unwrap();
    }

    #[test]
    fn dequant_swish_quant_fusion() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[8]), DType::I8, TensorKind::Input);
        let d = act(&mut g, "d", &[8]);
        g.add_node("dq", OpKind::Dequantize, vec![x], vec![d]);
        let s = act(&mut g, "s", &[8]);
        g.add_node("swish", OpKind::Swish, vec![d], vec![s]);
        let q = g.add_tensor("q", Shape::new(&[8]), DType::I8, TensorKind::Activation);
        g.add_node("qz", OpKind::Quantize, vec![s], vec![q]);
        let o = g.add_tensor("o", Shape::new(&[8]), DType::I8, TensorKind::Output);
        g.add_node("relu", OpKind::Relu, vec![q], vec![o]);
        let (opt, stats) = optimize(&g);
        assert_eq!(stats.quant_chains_fused, 1);
        assert!(opt.nodes.len() == 2, "{:?}", opt.nodes);
        opt.validate().unwrap();
    }

    #[test]
    fn dce_removes_dead_chain() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[4]), DType::F32, TensorKind::Input);
        let dead1 = act(&mut g, "d1", &[4]);
        let dead2 = act(&mut g, "d2", &[4]);
        g.add_node("n1", OpKind::Relu, vec![x], vec![dead1]);
        g.add_node("n2", OpKind::Relu, vec![dead1], vec![dead2]);
        let o = g.add_tensor("o", Shape::new(&[4]), DType::F32, TensorKind::Output);
        g.add_node("keep", OpKind::Relu, vec![x], vec![o]);
        let (opt, stats) = optimize(&g);
        // CSE may fold n1 into keep before DCE runs; either way the dead
        // chain disappears and only the live node remains.
        assert!(stats.dead_removed + stats.cse_removed >= 2, "{stats:?}");
        assert_eq!(opt.nodes.len(), 1);
        opt.validate().unwrap();
    }

    #[test]
    fn optimize_idempotent_on_clean_graph() {
        let g = crate::graph::models::ModelId::XlmR.build();
        let (o1, _) = optimize(&g);
        let (o2, s2) = optimize(&o1);
        assert_eq!(o1.nodes.len(), o2.nodes.len());
        assert_eq!(s2.cse_removed + s2.conversions_removed + s2.dead_removed, 0, "{s2:?}");
    }

    #[test]
    fn optimize_preserves_model_outputs() {
        for id in crate::graph::models::ModelId::ALL {
            let g = id.build();
            let (o, _) = optimize(&g);
            o.validate().unwrap();
            let outs_before =
                g.tensors.iter().filter(|t| t.kind == TensorKind::Output).count();
            let outs_after =
                o.tensors.iter().filter(|t| t.kind == TensorKind::Output).count();
            assert_eq!(outs_before, outs_after, "{}", g.name);
        }
    }
}
