//! `fbia` — CLI for the inference-accelerator platform reproduction.
//!
//! Subcommands:
//!   info              platform summary (paper §III headline numbers)
//!   simulate          run the platform simulator for one or all models
//!   compile-report    show the compiler's decisions for a model
//!   serve             serve a model for N requests over the active backend
//!                     (`--backend {ref,sim,pjrt}` selects execution,
//!                     `--threads N` keeps N requests in flight; `sim` runs
//!                     reference numerics on the modeled card clock)
//!   validate-numerics run the §V-C reference-vs-backend validation
//!   capacity          print the Fig. 1 capacity series

use fbia::capacity::{capacity_series, GrowthScenario};
use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::numerics::validate;
use fbia::numerics::weights::WeightGen;
use fbia::runtime::Engine;
use fbia::serving::{CvServer, NlpServer, RecsysServer, WEIGHT_SEED};
use fbia::sim::simulate_model;
use fbia::util::cli::Args;
use fbia::util::error::{bail, err, Result};
use fbia::util::table::{f2, ms, pct, Table};
use fbia::workloads::{CvGen, NlpGen, RecsysGen};
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args = Args::from_env(true);
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compile-report") => cmd_compile_report(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate-numerics") => cmd_validate(&args),
        Some("capacity") => cmd_capacity(&args),
        Some("info") | None => cmd_info(&args),
        Some(other) => Err(err!(
            "unknown subcommand '{other}' (try: info, simulate, compile-report, serve, validate-numerics, capacity)"
        )),
    };
    if let Err(e) = result {
        eprintln!("fbia: error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<Config> {
    match args.get("config") {
        Some(path) => Config::from_file(Path::new(path)),
        None => Ok(Config::default()),
    }
}

fn parse_model(name: &str) -> Result<ModelId> {
    Ok(match name {
        "recsys" | "recsys-base" => ModelId::RecsysBase,
        "recsys-complex" | "dlrm" => ModelId::RecsysComplex,
        "resnext" | "resnext101" => ModelId::ResNeXt101,
        "regnety" => ModelId::RegNetY,
        "fbnetv3" | "detection" => ModelId::FbNetV3,
        "resnext3d" | "video" => ModelId::ResNeXt3D,
        "xlmr" | "nlp" => ModelId::XlmR,
        other => bail!("unknown model '{other}'"),
    })
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n = &cfg.node;
    println!("fbia {} — inference accelerator platform (paper reproduction)", fbia::VERSION);
    println!();
    println!("node: {} cards + host, PCIe switch", n.cards);
    println!("  peak int8 : {:.0} TOPS ({}x{:.1})", n.total_tops_int8(), n.cards, n.card.peak_tops_int8);
    println!("  peak fp16 : {:.0} TFLOPS", n.total_tflops_fp16());
    println!("  LPDDR     : {} GB accel + {} GB host", n.total_lpddr() >> 30, n.host.mem_bytes >> 30);
    println!("  power     : {:.0} W (cards + switch)", n.accel_power_w());
    println!("  efficiency: {:.1} TOPS/W", n.tops_per_watt());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n = args.get_usize("requests", 200);
    let models: Vec<ModelId> = match args.get("model") {
        Some(m) => vec![parse_model(m)?],
        None => ModelId::ALL.to_vec(),
    };
    let mut t = Table::new(&["model", "batch", "latency", "budget", "ok", "QPS", "items/s", "util", "bottleneck"]);
    for id in models {
        let r = simulate_model(id, &cfg, n)?;
        t.row(&[
            id.name().to_string(),
            r.batch.to_string(),
            ms(r.latency_s),
            ms(id.latency_budget_s()),
            if r.meets_budget { "yes".into() } else { "NO".into() },
            format!("{:.0}", r.qps),
            format!("{:.0}", r.items_per_s),
            pct(r.core_utilization),
            r.pipeline.bottleneck.clone(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_compile_report(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let id = parse_model(args.get_or("model", "dlrm"))?;
    let g = id.build();
    let c = fbia::compiler::compile(&g, &cfg)?;
    println!(
        "model: {} ({} nodes, {:.1} MParams, {:.2} GFLOPs/batch)",
        g.name,
        g.nodes.len(),
        g.param_count() as f64 / 1e6,
        g.total_flops() / 1e9
    );
    println!("opt: {:?}", c.opt_stats);
    if let Some(q) = &c.quant_report {
        println!("quant: {} int8, {} fp16 fallback, {} skipped", q.int8_ops, q.fp16_fallbacks, q.skipped);
    }
    if let Some(sc) = c.sls_cores {
        println!("sls cores per card: {sc} of {}", cfg.node.card.accel_cores);
    }
    let mut t = Table::new(&["partition", "kind", "card", "ops", "weights (MB)", "makespan", "util", "hints rejected"]);
    for (p, s) in c.plan.partitions.iter().zip(&c.schedules) {
        t.row(&[
            p.id.to_string(),
            format!("{:?}", p.kind),
            p.card.map(|c| c.to_string()).unwrap_or_else(|| "host".into()),
            p.nodes.len().to_string(),
            format!("{:.1}", p.weight_bytes as f64 / 1e6),
            s.as_ref().map(|s| ms(s.makespan_s)).unwrap_or_else(|| "-".into()),
            s.as_ref().map(|s| pct(s.core_utilization)).unwrap_or_else(|| "-".into()),
            s.as_ref().map(|s| s.hints_rejected.to_string()).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    println!("\nop breakdown (Table II analogue):");
    let mut t2 = Table::new(&["op", "share"]);
    for (k, v) in fbia::sim::op_breakdown(&c).iter().take(8) {
        t2.row(&[k.clone(), pct(*v)]);
    }
    t2.print();
    Ok(())
}

/// Engine for the serving/validation subcommands: AOT artifacts when the
/// directory exists, the builtin manifest otherwise. `--backend
/// {ref,sim,pjrt}` (or `FBIA_BACKEND`) selects execution; unknown names
/// error with the valid list.
fn engine(args: &Args) -> Result<Arc<Engine>> {
    let dir = args.get_or("artifacts", "artifacts");
    let eng = Engine::auto_with(Path::new(dir), args.get("backend"))?;
    let manifest_dir = eng.manifest().dir.display().to_string();
    eprintln!(
        "[fbia] backend: {} ({} devices, {} clock, manifest: {manifest_dir})",
        eng.backend_name(),
        eng.device_count(),
        eng.clock().name(),
    );
    Ok(Arc::new(eng))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let n = args.get_usize("requests", 50);
    // `--threads N` (default 1): N whole requests in flight; for DLRM the
    // per-card SLS shards of each request also fan out across N threads
    let threads = args.get_usize("threads", 1).max(1);
    match args.get_or("model", "dlrm") {
        "dlrm" | "recsys" => {
            let batch = args.get_usize("batch", 32);
            let precision = args.get_or("precision", "int8");
            let server =
                Arc::new(RecsysServer::with_threads(eng.clone(), batch, precision, threads)?);
            let mut gen = RecsysGen::from_manifest(1, batch, eng.manifest())?;
            let reqs: Vec<_> = (0..n).map(|_| gen.next()).collect();
            // threads == 1 keeps the Fig. 6 pipelined path; > 1 serves with
            // N requests in flight
            let metrics = if threads > 1 {
                server.serve_workers(reqs, threads)?
            } else {
                server.serve(reqs)?
            };
            print_metrics("dlrm", &metrics);
            print_budget_check(&metrics, ModelId::RecsysComplex);
        }
        "xlmr" | "nlp" => {
            let server = Arc::new(NlpServer::new(eng.clone())?);
            let m = eng.manifest();
            let mut gen = NlpGen::new(1, m.config_usize("xlmr", "vocab")?, 128, 100.0);
            let reqs: Vec<_> = (0..n).map(|_| gen.next()).collect();
            let (metrics, waste) = server.serve(
                reqs,
                args.get_usize("max-batch", 4),
                !args.flag("naive-batching"),
                threads,
            )?;
            print_metrics("xlmr", &metrics);
            print_budget_check(&metrics, ModelId::XlmR);
            println!("  pad waste : {}", pct(waste));
        }
        "cv" => {
            let server = Arc::new(CvServer::new(eng.clone())?);
            let mut gen = CvGen::new(1, server.image);
            let batch = args.get_usize("batch", 1);
            let metrics = server.serve(n, batch, &mut gen, threads)?;
            print_metrics("cv", &metrics);
            print_budget_check(&metrics, ModelId::ResNeXt101);
        }
        other => bail!("serve: unknown model '{other}' (dlrm | xlmr | cv)"),
    }
    Ok(())
}

fn print_metrics(name: &str, m: &fbia::serving::ServerMetrics) {
    let clock = match m.clock {
        fbia::runtime::Clock::Wall => String::new(),
        fbia::runtime::Clock::Modeled => " (modeled card time)".to_string(),
    };
    println!("{name}: {} requests in {:.2}s{clock}", m.completed, m.wall_s);
    println!("  QPS       : {:.1} ({:.1} items/s)", m.qps(), m.items_per_s());
    println!(
        "  latency   : p50 {} p95 {} p99 {}",
        ms(m.latency.p50()),
        ms(m.latency.p95()),
        ms(m.latency.p99())
    );
}

/// On the modeled clock, check the p50 against the model family's Table I
/// latency budget — the fig7 acceptance the sim backend exists to report.
fn print_budget_check(m: &fbia::serving::ServerMetrics, id: ModelId) {
    if m.clock != fbia::runtime::Clock::Modeled {
        return;
    }
    let budget = id.latency_budget_s();
    let p50 = m.latency.p50();
    println!(
        "  budget    : p50 {} vs {} ({}) -> {}",
        ms(p50),
        ms(budget),
        id.name(),
        if p50 <= budget { "within budget" } else { "EXCEEDS BUDGET" }
    );
}

fn cmd_validate(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let manifest = eng.manifest().clone();
    let only: Option<&str> = args.get("artifact");
    let mut failures = 0;
    let mut t = Table::new(&["artifact", "max abs err", "cosine", "pass"]);
    for art in &manifest.artifacts {
        if let Some(o) = only {
            if art.name != o {
                continue;
            }
        }
        let inputs = fbia::serving::test_inputs_for(&manifest, art, 7)?;
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let reference = validate::reference_outputs(&manifest, art, &mut gen, &inputs)?;
        let mut gen2 = WeightGen::new(WEIGHT_SEED);
        let weights = gen2.weights_for(art);
        let prepared = eng.prepare(&art.name, weights)?;
        let measured = prepared.run(&inputs)?;
        let v = validate::compare(
            &art.name,
            reference[0].as_f32().ok_or_else(|| err!("ref output not f32"))?,
            measured[0].as_f32().ok_or_else(|| err!("out not f32"))?,
        );
        if !v.passed {
            failures += 1;
        }
        t.row(&[
            v.artifact.clone(),
            format!("{:.2e}", v.max_abs_err),
            format!("{:.6}", v.cosine),
            if v.passed { "yes".into() } else { "NO".into() },
        ]);
    }
    t.print();
    if failures > 0 {
        bail!("{failures} artifacts failed numerics validation");
    }
    println!("all checked artifacts match the reference implementations (§V-C)");
    Ok(())
}

fn cmd_capacity(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    for (scenario, model) in [
        (GrowthScenario::recommendation(), ModelId::RecsysComplex),
        (GrowthScenario::other_ml(), ModelId::XlmR),
    ] {
        println!("\nFig. 1 ({}):", scenario.name);
        let pts = capacity_series(model, &scenario, &cfg)?;
        let mut t = Table::new(&["quarter", "demand (QPS)", "CPU servers", "accel servers", "growth (norm)"]);
        for p in &pts {
            t.row(&[
                p.quarter.to_string(),
                format!("{:.0}", p.demand_qps),
                format!("{:.0}", p.cpu_servers),
                format!("{:.0}", p.accel_servers),
                f2(p.cpu_norm),
            ]);
        }
        t.print();
    }
    Ok(())
}
