//! `fbia` — CLI for the inference-accelerator platform reproduction.
//!
//! Subcommands:
//!   info              platform summary (paper §III headline numbers)
//!   simulate          run the platform simulator for one or all models
//!   compile-report    show the compiler's decisions for a model
//!   serve             serve a model for N requests over the active backend
//!                     (`--backend {ref,sim,pjrt}` selects execution,
//!                     `--threads N` keeps N requests in flight; `sim` runs
//!                     reference numerics on the modeled card clock;
//!                     `--window-ms W` adds windowed telemetry on the
//!                     single-worker streaming path)
//!   validate-numerics run the §V-C reference-vs-backend validation
//!   fleet             route a mixed recsys/nlp/cv stream across the cards
//!                     (`--mix 70/20/10 --policy la --replicas 4`); on
//!                     `--backend sim` compares routing policies on the
//!                     modeled clock and checks latency-aware vs round-robin
//!   capacity          print the Fig. 1 capacity series (accelerator side
//!                     measured by the fleet router on a mixed trace)
//!   cluster           multi-node tier: route a mixed stream across N
//!                     NIC-limited nodes (`--nodes 3 --policy weighted`),
//!                     inject node failures/drains (`--fail 0@0.5`), and
//!                     size the tier with failure headroom (`--qps/--headroom`)
//!   des               discrete-event core smoke: static vs queue-triggered
//!                     dynamic batching on one seeded trace, with
//!                     determinism and conservation checks (sim backend)
//!   trace             replay a seeded cluster scenario with request-level
//!                     tracing on (`--mix/--policy/--out trace.json`,
//!                     optional `--fail/--drain` node events):
//!                     verifies tracing-off bit-identity, stage-sum and
//!                     utilization invariants, compares a NIC-throttled
//!                     rerun against the unconstrained stage breakdown,
//!                     and writes a Perfetto-loadable Chrome trace JSON
//!   monitor           windowed telemetry + SLO drill on the same replay
//!                     plumbing as `trace`: derives fixed-width series from
//!                     a node-fail scenario (probe-calibrated so the kill
//!                     always has in-flight work to shed), evaluates
//!                     multi-window error-budget burn rules, and checks the
//!                     alert fires within bounded windows, clears after
//!                     recovery, reconciles with the report totals, and is
//!                     bit-deterministic (`--window-ms/--p99-budget-ms`)
//!   bench-diff        regression gate: diff fresh BENCH_*.json reports
//!                     against the committed baselines in bench/baselines
//!                     with per-metric direction-aware tolerances
//!                     (`--tol qps=0.10`); exits nonzero on any regression
//!   lint              static analysis, nothing prepared or simulated:
//!                     per-op shape/dtype inference over the model graphs,
//!                     a memory-fit proof against the node spec, and
//!                     deployment-config rules (`--model dlrm` or
//!                     `--all-models`, `--sla-ms/--qps/--mix` for the
//!                     deployment layer, `--json out.json` for the BENCH
//!                     schema). The same analyzer gates `--config` loading
//!                     and every `prepare`; `--no-lint` bypasses the gates
//!
//! `fleet`, `cluster` and `des` all drive their tiers through the unified
//! [`Simulation`] builder; policy names resolve through
//! [`fbia::serving::policy`], so an unknown name errors with the valid
//! list everywhere.

use fbia::capacity::GrowthScenario;
use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::numerics::validate;
use fbia::numerics::weights::WeightGen;
use fbia::obs::{
    chrome_trace, chrome_trace_monitored, MonitorReport, SegKind, SloSpec, Stage, StageStats,
    Tracer, WindowedSeries,
};
use fbia::platform::NodeSpec;
use fbia::runtime::{Clock, Engine, Precision, SimBackend};
use fbia::serving::cluster::{
    self, Cluster, ClusterMetrics, EventKind, NodeEvent, NodePolicy, Scenario,
};
use fbia::serving::fleet::{
    plan::plan_capacity, Arrival, DynamicBatch, Family, FamilyMix, Fleet, FleetConfig,
    FleetMetrics, FleetRequest, RoutePolicy, TrafficGen,
};
use fbia::serving::policy::{card_policy_by_name, node_policy_by_name, placement_by_name};
use fbia::serving::simulation::{SimReport, Simulation};
use fbia::serving::{CvServer, NlpServer, RecsysServer, ServeOptions, WEIGHT_SEED};
use fbia::sim::simulate_model;
use fbia::util::bench::{compare, BenchReport};
use fbia::util::cli::Args;
use fbia::util::error::{bail, err, Result};
use fbia::util::json::Json;
use fbia::util::table::{f2, ms, pct, Table};
use fbia::workloads::{CvGen, NlpGen, RecsysGen};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    let args = Args::from_env(true);
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compile-report") => cmd_compile_report(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate-numerics") => cmd_validate(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("capacity") => cmd_capacity(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("des") => cmd_des(&args),
        Some("trace") => cmd_trace(&args),
        Some("monitor") => cmd_monitor(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        Some("lint") => cmd_lint(&args),
        Some("info") | None => cmd_info(&args),
        Some(other) => Err(err!(
            "unknown subcommand '{other}' (try: info, simulate, compile-report, serve, validate-numerics, fleet, capacity, cluster, des, trace, monitor, bench-diff, lint)"
        )),
    };
    if let Err(e) = result {
        eprintln!("fbia: error: {e:#}");
        std::process::exit(1);
    }
}

/// Shared stage-latency-attribution table ([`fbia::obs`]): one row per
/// labeled scope, "mean/p99" milliseconds per stage plus the dominant
/// stage — the regime label (compute-bound, NIC-bound, queue-bound).
fn print_stage_table(title: &str, rows: &[(String, &StageStats)]) {
    println!("\n{title}");
    let mut t = Table::new(&[
        "scope", "queue", "batch wait", "transfer", "compute", "network", "dominant",
    ]);
    for (label, s) in rows {
        if s.count() == 0 {
            continue;
        }
        let cell =
            |stage: Stage| format!("{:.2}/{:.2}", s.mean(stage) * 1e3, s.p99(stage) * 1e3);
        t.row(&[
            label.clone(),
            cell(Stage::Queue),
            cell(Stage::BatchWait),
            cell(Stage::Transfer),
            cell(Stage::Compute),
            cell(Stage::Network),
            s.dominant().map(|d| d.name().to_string()).unwrap_or_default(),
        ]);
    }
    t.print();
}

fn load_config(args: &Args) -> Result<Config> {
    match args.get("config") {
        // the static analyzer vets configs at load time; --no-lint bypasses
        Some(path) => Config::from_file_with(Path::new(path), !args.flag("no-lint")),
        None => Ok(Config::default()),
    }
}

fn parse_model(name: &str) -> Result<ModelId> {
    Ok(match name {
        "recsys" | "recsys-base" => ModelId::RecsysBase,
        "recsys-complex" | "dlrm" => ModelId::RecsysComplex,
        "resnext" | "resnext101" => ModelId::ResNeXt101,
        "regnety" => ModelId::RegNetY,
        "fbnetv3" | "detection" => ModelId::FbNetV3,
        "resnext3d" | "video" => ModelId::ResNeXt3D,
        "xlmr" | "nlp" => ModelId::XlmR,
        other => bail!("unknown model '{other}'"),
    })
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n = &cfg.node;
    println!("fbia {} — inference accelerator platform (paper reproduction)", fbia::VERSION);
    println!();
    println!("node: {} cards + host, PCIe switch", n.cards);
    println!("  peak int8 : {:.0} TOPS ({}x{:.1})", n.total_tops_int8(), n.cards, n.card.peak_tops_int8);
    println!("  peak fp16 : {:.0} TFLOPS", n.total_tflops_fp16());
    println!("  LPDDR     : {} GB accel + {} GB host", n.total_lpddr() >> 30, n.host.mem_bytes >> 30);
    println!("  power     : {:.0} W (cards + switch)", n.accel_power_w());
    println!("  efficiency: {:.1} TOPS/W", n.tops_per_watt());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n = args.get_usize("requests", 200);
    let models: Vec<ModelId> = match args.get("model") {
        Some(m) => vec![parse_model(m)?],
        None => ModelId::ALL.to_vec(),
    };
    let mut t = Table::new(&["model", "batch", "latency", "budget", "ok", "QPS", "items/s", "util", "bottleneck"]);
    for id in models {
        let r = simulate_model(id, &cfg, n)?;
        t.row(&[
            id.name().to_string(),
            r.batch.to_string(),
            ms(r.latency_s),
            ms(id.latency_budget_s()),
            if r.meets_budget { "yes".into() } else { "NO".into() },
            format!("{:.0}", r.qps),
            format!("{:.0}", r.items_per_s),
            pct(r.core_utilization),
            r.pipeline.bottleneck.clone(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_compile_report(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let id = parse_model(args.get_or("model", "dlrm"))?;
    let g = id.build();
    let c = fbia::compiler::compile(&g, &cfg)?;
    println!(
        "model: {} ({} nodes, {:.1} MParams, {:.2} GFLOPs/batch)",
        g.name,
        g.nodes.len(),
        g.param_count() as f64 / 1e6,
        g.total_flops() / 1e9
    );
    println!("opt: {:?}", c.opt_stats);
    if let Some(q) = &c.quant_report {
        println!("quant: {} int8, {} fp16 fallback, {} skipped", q.int8_ops, q.fp16_fallbacks, q.skipped);
    }
    if let Some(sc) = c.sls_cores {
        println!("sls cores per card: {sc} of {}", cfg.node.card.accel_cores);
    }
    let mut t = Table::new(&["partition", "kind", "card", "ops", "weights (MB)", "makespan", "util", "hints rejected"]);
    for (p, s) in c.plan.partitions.iter().zip(&c.schedules) {
        t.row(&[
            p.id.to_string(),
            format!("{:?}", p.kind),
            p.card.map(|c| c.to_string()).unwrap_or_else(|| "host".into()),
            p.nodes.len().to_string(),
            format!("{:.1}", p.weight_bytes as f64 / 1e6),
            s.as_ref().map(|s| ms(s.makespan_s)).unwrap_or_else(|| "-".into()),
            s.as_ref().map(|s| pct(s.core_utilization)).unwrap_or_else(|| "-".into()),
            s.as_ref().map(|s| s.hints_rejected.to_string()).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    println!("\nop breakdown (Table II analogue):");
    let mut t2 = Table::new(&["op", "share"]);
    for (k, v) in fbia::sim::op_breakdown(&c).iter().take(8) {
        t2.row(&[k.clone(), pct(*v)]);
    }
    t2.print();
    Ok(())
}

/// Engine for the serving/validation subcommands: AOT artifacts when the
/// directory exists, the builtin manifest otherwise. `--backend
/// {ref,sim,pjrt}` (or `FBIA_BACKEND`) selects execution; unknown names
/// error with the valid list.
fn engine(args: &Args) -> Result<Arc<Engine>> {
    let dir = args.get_or("artifacts", "artifacts");
    let mut eng = Engine::auto_with(Path::new(dir), args.get("backend"))?;
    if args.flag("no-lint") {
        eng.set_lint(false);
    }
    let manifest_dir = eng.manifest().dir.display().to_string();
    eprintln!(
        "[fbia] backend: {} ({} devices, {} clock, manifest: {manifest_dir})",
        eng.backend_name(),
        eng.device_count(),
        eng.clock().name(),
    );
    Ok(Arc::new(eng))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let n = args.get_usize("requests", 50);
    // `--threads N` (default 1): N whole requests in flight; for DLRM the
    // per-card SLS shards of each request also fan out across N threads
    let threads = args.get_usize("threads", 1).max(1);
    // `--window-ms W`: windowed telemetry on the streaming (single-worker)
    // serve paths — wall seconds on real backends, modeled seconds on sim
    let window_s = args
        .get("window-ms")
        .map(|v| {
            let w: f64 = v.parse().map_err(|_| err!("--window-ms must be a number (ms)"))?;
            if !w.is_finite() || w <= 0.0 {
                bail!("--window-ms must be positive (got {w})");
            }
            Ok(w * 1e-3)
        })
        .transpose()?;
    let metrics = match args.get_or("model", "dlrm") {
        "dlrm" | "recsys" => {
            let batch = args.get_usize("batch", 32);
            // DLRM defaults to int8 (the paper's production path); xlm-r/cv
            // below default to f32 and opt into --precision int8
            let precision = args.get_or("precision", "int8");
            let server =
                Arc::new(RecsysServer::with_threads(eng.clone(), batch, precision, threads)?);
            let mut gen = RecsysGen::from_manifest(1, batch, eng.manifest())?;
            let reqs: Vec<_> = (0..n).map(|_| gen.next()).collect();
            // workers == 1 keeps the Fig. 6 pipelined path; > 1 serves with
            // N requests in flight
            let metrics = server.serve_with(
                reqs,
                &ServeOptions { workers: threads, window_s, ..ServeOptions::default() },
            )?;
            print_metrics("dlrm", &metrics);
            print_budget_check(&metrics, ModelId::RecsysComplex);
            metrics
        }
        "xlmr" | "nlp" => {
            let precision = Precision::parse(args.get_or("precision", "f32"))?;
            let server = Arc::new(NlpServer::with_precision(eng.clone(), precision)?);
            let m = eng.manifest();
            let mut gen = NlpGen::new(1, m.config_usize("xlmr", "vocab")?, 128, 100.0);
            let reqs: Vec<_> = (0..n).map(|_| gen.next()).collect();
            let (metrics, waste) = server.serve_with(
                reqs,
                &ServeOptions {
                    max_batch: args.get_usize("max-batch", 4),
                    length_aware: !args.flag("naive-batching"),
                    workers: threads,
                    window_s,
                    ..ServeOptions::default()
                },
            )?;
            print_metrics("xlmr", &metrics);
            print_budget_check(&metrics, ModelId::XlmR);
            println!("  pad waste : {}", pct(waste));
            metrics
        }
        "cv" => {
            let precision = Precision::parse(args.get_or("precision", "f32"))?;
            let server = Arc::new(CvServer::with_precision(eng.clone(), precision)?);
            let mut gen = CvGen::new(1, server.image);
            let batch = args.get_usize("batch", 1);
            let metrics = server.serve_with(
                n,
                batch,
                &mut gen,
                &ServeOptions { workers: threads, window_s, ..ServeOptions::default() },
            )?;
            print_metrics("cv", &metrics);
            print_budget_check(&metrics, ModelId::ResNeXt101);
            metrics
        }
        other => bail!("serve: unknown model '{other}' (dlrm | xlmr | cv)"),
    };
    match (&metrics.windows, window_s) {
        (Some(w), _) => print_window_table("windowed telemetry:", w),
        (None, Some(_)) => println!(
            "  (windowed telemetry needs the streaming path: --threads 1; \
             fan-out completion order is scheduler-dependent)"
        ),
        (None, None) => {}
    }
    Ok(())
}

fn print_metrics(name: &str, m: &fbia::serving::ServerMetrics) {
    let clock = match m.clock {
        fbia::runtime::Clock::Wall => String::new(),
        fbia::runtime::Clock::Modeled => " (modeled card time)".to_string(),
    };
    println!("{name}: {} requests in {:.2}s{clock}", m.completed, m.wall_s);
    println!("  QPS       : {:.1} ({:.1} items/s)", m.qps(), m.items_per_s());
    println!(
        "  latency   : p50 {} p95 {} p99 {}",
        ms(m.latency.p50()),
        ms(m.latency.p95()),
        ms(m.latency.p99())
    );
}

/// On the modeled clock, check the p50 against the model family's Table I
/// latency budget — the fig7 acceptance the sim backend exists to report.
fn print_budget_check(m: &fbia::serving::ServerMetrics, id: ModelId) {
    if m.clock != fbia::runtime::Clock::Modeled {
        return;
    }
    let budget = id.latency_budget_s();
    let p50 = m.latency.p50();
    println!(
        "  budget    : p50 {} vs {} ({}) -> {}",
        ms(p50),
        ms(budget),
        id.name(),
        if p50 <= budget { "within budget" } else { "EXCEEDS BUDGET" }
    );
}

fn cmd_validate(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let manifest = eng.manifest().clone();
    let only: Option<&str> = args.get("artifact");
    let mut failures = 0;
    let mut t = Table::new(&["artifact", "max abs err", "cosine", "pass"]);
    for art in &manifest.artifacts {
        if let Some(o) = only {
            if art.name != o {
                continue;
            }
        }
        let inputs = fbia::serving::test_inputs_for(&manifest, art, 7)?;
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let reference = validate::reference_outputs(&manifest, art, &mut gen, &inputs)?;
        let mut gen2 = WeightGen::new(WEIGHT_SEED);
        let weights = gen2.weights_for(art);
        let prepared = eng.prepare(&art.name, weights)?;
        let measured = prepared.run(&inputs)?;
        let v = validate::compare(
            &art.name,
            reference[0].as_f32().ok_or_else(|| err!("ref output not f32"))?,
            measured[0].as_f32().ok_or_else(|| err!("out not f32"))?,
        );
        if !v.passed {
            failures += 1;
        }
        t.row(&[
            v.artifact.clone(),
            format!("{:.2e}", v.max_abs_err),
            format!("{:.6}", v.cosine),
            if v.passed { "yes".into() } else { "NO".into() },
        ]);
    }
    t.print();
    if failures > 0 {
        bail!("{failures} artifacts failed numerics validation");
    }
    println!("all checked artifacts match the reference implementations (§V-C)");
    Ok(())
}

/// Modeled-clock engine for fleet planning: the (possibly `--config`
/// overridden) node behind a [`SimBackend`], with the runtime's usual
/// manifest resolution (AOT artifacts when present, builtin otherwise).
fn sim_engine(args: &Args, cfg: &Config) -> Result<Arc<Engine>> {
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    let mut eng = Engine::auto_with_backend(dir, Arc::new(SimBackend::new(cfg.clone())))?;
    if args.flag("no-lint") {
        eng.set_lint(false);
    }
    Ok(Arc::new(eng))
}

/// FleetConfig from the shared CLI knobs; policy-shaped knobs default to
/// the (possibly `--config` overridden) `serving` section and resolve
/// through the [`fbia::serving::policy`] registry.
fn fleet_config(args: &Args, cfg: &Config) -> Result<FleetConfig> {
    let d = FleetConfig::default();
    Ok(FleetConfig {
        replicas: args.get_usize("replicas", d.replicas).max(1),
        placement: placement_by_name(args.get_or("placement", cfg.serving.placement.name()))?,
        recsys_batch: args.get_usize("batch", d.recsys_batch),
        recsys_precision: args.get_or("precision", &d.recsys_precision).to_string(),
        max_queue: args.get_usize("max-queue", d.max_queue).max(1),
        sla_budget_s: args
            .get("sla-ms")
            .map(|v| -> Result<f64> {
                let x: f64 = v.parse().map_err(|_| err!("--sla-ms must be a number"))?;
                if !(x > 0.0) {
                    bail!("--sla-ms must be positive (got {x})");
                }
                Ok(x / 1e3)
            })
            .transpose()?,
        des_seed: args.get_u64("des-seed", d.des_seed),
        dynamic_batch: args.flag("dynamic-batch").then(|| DynamicBatch {
            depth_hi: args.get_usize("batch-depth", DynamicBatch::default().depth_hi).max(1),
            max_batch: args.get_usize("batch-cap", DynamicBatch::default().max_batch).max(2),
            marginal: DynamicBatch::default().marginal,
        }),
    })
}

fn cmd_fleet(args: &Args) -> Result<()> {
    // `--config` describes the node (card count, vendor-mix overrides,
    // transfer knobs) — that only changes behavior on the modeled clock, so
    // a sim-backend request goes through the config-aware engine builder;
    // wall-clock backends keep the shared `engine()` path
    let cfg = load_config(args)?;
    let requested = args
        .get("backend")
        .map(str::to_string)
        .or_else(|| std::env::var("FBIA_BACKEND").ok());
    let eng = if requested.as_deref() == Some("sim") {
        let e = sim_engine(args, &cfg)?;
        eprintln!(
            "[fbia] backend: sim ({} devices, modeled clock, manifest: {})",
            e.device_count(),
            e.manifest().dir.display()
        );
        e
    } else {
        if args.get("config").is_some() {
            eprintln!("[fbia] note: --config only affects the sim backend's modeled node");
        }
        engine(args)?
    };
    let fcfg = fleet_config(args, &cfg)?;
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10"))?;
    let arrival = match args.get_or("arrival", "burst") {
        "burst" => Arrival::Burst,
        "poisson" => Arrival::Poisson { rate_qps: args.get_f64("rate", 200.0) },
        other => bail!("unknown arrival pattern '{other}' (burst | poisson)"),
    };
    let requests = args.get_usize("requests", 120).max(1);
    let threads = args.get_usize("threads", 4).max(1);
    let seed = args.get_u64("seed", 1);
    let policies: Vec<RoutePolicy> = match args.get_or("policy", "all") {
        "all" => RoutePolicy::ALL.to_vec(),
        p => vec![card_policy_by_name(p)?],
    };
    let modeled = eng.clock() == Clock::Modeled;

    let fleet = Arc::new(Fleet::new(eng.clone(), fcfg.clone())?);
    let mut traffic =
        TrafficGen::new(seed, mix, arrival, eng.manifest(), fcfg.recsys_batch)?;
    let reqs = traffic.take(requests);
    println!(
        "fleet: {} cards, {} replicas/family ({}), mix {} over {requests} requests",
        fleet.replicas().cards,
        fcfg.replicas,
        fcfg.placement.name(),
        mix.label(),
    );

    // policy sweep through the unified Simulation builder: route-only on
    // the modeled clock (deterministic, cheap), full execution on wall
    // clocks (there is nothing to report otherwise)
    let mut results: Vec<FleetMetrics> = Vec::new();
    for &p in &policies {
        let mut sim = Simulation::fleet(Arc::clone(&fleet)).card_policy(p).trace(reqs.clone());
        if !modeled {
            sim = sim.execute(threads);
        }
        let m = sim.run()?.fleet.expect("fleet tier yields fleet metrics");
        results.push(m);
    }
    let mut t = Table::new(&[
        "policy", "admitted", "shed", "shed%", "node QPS", "items/s", "p50", "p99",
    ]);
    for m in &results {
        t.row(&[
            m.policy.name().to_string(),
            m.node.completed.to_string(),
            m.shed.to_string(),
            pct(m.shed_rate()),
            format!("{:.1}", m.node_qps()),
            format!("{:.1}", m.node.items_per_s()),
            ms(m.node.latency.p50()),
            ms(m.node.latency.p99()),
        ]);
    }
    t.print();

    // detail breakdown for the requested (or default latency-aware) policy
    let detail_policy = match args.get("policy") {
        Some(p) if p != "all" => card_policy_by_name(p)?,
        _ => RoutePolicy::LatencyAware,
    };
    if let Some(m) = results.iter().find(|m| m.policy == detail_policy) {
        let span = m.node.wall_s;
        println!("\nper-card ({}):", detail_policy.name());
        let mut tc = Table::new(&["card", "completed", "items", "busy", "util", "p50"]);
        for c in &m.per_card {
            tc.row(&[
                c.card.to_string(),
                c.metrics.completed.to_string(),
                c.metrics.items.to_string(),
                ms(c.busy_s),
                pct(c.utilization(span)),
                ms(c.metrics.latency.p50()),
            ]);
        }
        tc.print();
        println!("\nper-family ({}):", detail_policy.name());
        let mut tf = Table::new(&["family", "offered", "completed", "shed", "p50", "budget"]);
        for f in &m.per_family {
            tf.row(&[
                f.family.name().to_string(),
                f.offered.to_string(),
                f.metrics.completed.to_string(),
                f.shed.to_string(),
                ms(f.metrics.latency.p50()),
                ms(f.family.latency_budget_s()),
            ]);
        }
        tf.print();
        if m.node.stages.count() > 0 {
            let mut rows: Vec<(String, &StageStats)> =
                vec![("node".to_string(), &m.node.stages)];
            for f in &m.per_family {
                rows.push((f.family.name().to_string(), &f.metrics.stages));
            }
            print_stage_table(
                &format!("stage latency attribution ({}, mean/p99 ms):", detail_policy.name()),
                &rows,
            );
        }
    }

    // the acceptance check this subsystem exists for: cost-aware routing
    // must buy modeled node throughput, not just shuffle requests
    let rr = results.iter().find(|m| m.policy == RoutePolicy::RoundRobin);
    let la = results.iter().find(|m| m.policy == RoutePolicy::LatencyAware);
    let mut la_beats_rr = None;
    if let (Some(rr), Some(la)) = (rr, la) {
        if modeled {
            let holds = la.node_qps() > rr.node_qps() && la.shed_rate() <= rr.shed_rate();
            println!(
                "\nlatency-aware vs round-robin: {:.1} vs {:.1} node QPS at shed {} vs {} -> {}",
                la.node_qps(),
                rr.node_qps(),
                pct(la.shed_rate()),
                pct(rr.shed_rate()),
                if holds { "holds" } else { "VIOLATED" }
            );
            la_beats_rr = Some(holds);
        }
    }

    // execute the detail policy's plan with real numerics (route-only
    // sweeps above never touch the kernels); skip with --no-execute
    if modeled && !args.flag("no-execute") {
        let m = Simulation::fleet(Arc::clone(&fleet))
            .card_policy(detail_policy)
            .trace(reqs.clone())
            .execute(threads)
            .run()?
            .fleet
            .expect("fleet tier yields fleet metrics");
        println!(
            "\nexecuted {} admitted requests' numerics on {} ({} workers, modeled clock)",
            m.node.completed,
            eng.backend_name(),
            threads
        );
    }

    if let Some(path) = args.get("json") {
        // shared BENCH_*.json schema: headline numbers from the detail
        // policy, the full sweep under `policies`
        let headline = results
            .iter()
            .find(|m| m.policy == detail_policy)
            .or_else(|| results.first())
            .ok_or_else(|| err!("fleet: no policy results to report"))?;
        let mut bench = BenchReport::new("fleet_smoke", eng.backend_name(), eng.clock().name());
        bench.offered = headline.offered;
        bench.completed = headline.node.completed;
        bench.shed = headline.shed;
        bench.qps = headline.node_qps();
        bench.p50_ms = headline.node.latency.p50() * 1e3;
        bench.p99_ms = headline.node.latency.p99() * 1e3;
        if let Some(holds) = la_beats_rr {
            bench = bench.accept("latency_aware_beats_round_robin", holds);
        }
        let bench = bench
            .with("cards", Json::num(fleet.replicas().cards as f64))
            .with("replicas", Json::num(fcfg.replicas as f64))
            .with("placement", Json::str(fcfg.placement.name()))
            .with("mix", Json::str(&mix.label()))
            .with("requests", Json::num(requests as f64))
            .with("headline_policy", Json::str(detail_policy.name()))
            .with(
                "policies",
                Json::arr(
                    results
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("policy", Json::str(m.policy.name())),
                                ("node_qps", Json::num(m.node_qps())),
                                ("items_per_s", Json::num(m.node.items_per_s())),
                                ("offered", Json::num(m.offered as f64)),
                                ("completed", Json::num(m.node.completed as f64)),
                                ("shed", Json::num(m.shed as f64)),
                                ("shed_rate", Json::num(m.shed_rate())),
                                ("p50_ms", Json::num(m.node.latency.p50() * 1e3)),
                                ("p99_ms", Json::num(m.node.latency.p99() * 1e3)),
                                ("span_s", Json::num(m.node.wall_s)),
                                (
                                    "per_card",
                                    Json::arr(
                                        m.per_card
                                            .iter()
                                            .map(|c| {
                                                Json::obj(vec![
                                                    ("card", Json::num(c.card as f64)),
                                                    (
                                                        "completed",
                                                        Json::num(c.metrics.completed as f64),
                                                    ),
                                                    ("busy_s", Json::num(c.busy_s)),
                                                    (
                                                        "util",
                                                        Json::num(c.utilization(m.node.wall_s)),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                                (
                                    "per_family",
                                    Json::arr(
                                        m.per_family
                                            .iter()
                                            .map(|f| {
                                                Json::obj(vec![
                                                    ("family", Json::str(f.family.name())),
                                                    ("offered", Json::num(f.offered as f64)),
                                                    (
                                                        "completed",
                                                        Json::num(f.metrics.completed as f64),
                                                    ),
                                                    ("shed", Json::num(f.shed as f64)),
                                                    (
                                                        "p50_ms",
                                                        Json::num(
                                                            f.metrics.latency.p50() * 1e3,
                                                        ),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            );
        bench.write(path)?;
    }
    Ok(())
}

/// `fbia cluster`: the multi-node tier. Sweeps node policies on a burst
/// trace, sizes the tier with failure headroom (`--qps`, `--headroom`),
/// and runs a node-fail/drain scenario (`--fail 0@0.5`, `--drain 1@0.2`;
/// a default drill kills node 0 mid-trace when neither is given).
/// Modeled clock only, like `fbia capacity`.
fn cmd_cluster(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let requested = args
        .get("backend")
        .map(str::to_string)
        .or_else(|| std::env::var("FBIA_BACKEND").ok());
    if let Some(b) = requested {
        if b != "sim" {
            fbia::runtime::backend_by_name(&b)?;
            bail!(
                "fbia cluster plans multi-node tiers on the modeled clock; \
                 only --backend sim is supported (got '{b}')"
            );
        }
    }
    let fcfg = fleet_config(args, &cfg)?;
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10"))?;
    let requests = args.get_usize("requests", 150).max(1);
    let seed = args.get_u64("seed", 1);
    let threads = args.get_usize("threads", 4).max(1);
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    // node list: the config's cluster spec, or --nodes copies of its node
    let (specs, default_headroom) = match &cfg.cluster {
        Some(cl) => (cl.nodes.clone(), cl.headroom),
        None => (vec![cfg.node.clone(); args.get_usize("nodes", 3).max(1)], 1),
    };
    let headroom = args.get_usize("headroom", default_headroom);
    let card_policy =
        card_policy_by_name(args.get_or("card-policy", cfg.serving.card_policy.name()))?;
    let policies: Vec<NodePolicy> = match args.get_or("policy", "all") {
        "all" => NodePolicy::ALL.to_vec(),
        p => vec![node_policy_by_name(p)?],
    };
    let detail_policy = *policies.last().unwrap();

    let cluster = Arc::new(Cluster::new(dir, &cfg, &specs, fcfg.clone())?);
    eprintln!(
        "[fbia] cluster: {} nodes ({} cards each at default), sim backend, modeled clock",
        cluster.node_count(),
        specs[0].cards,
    );

    // --- policy sweep on a burst trace (saturation throughput) -----------
    let mut traffic =
        TrafficGen::new(seed, mix, Arrival::Burst, cluster.manifest(), fcfg.recsys_batch)?;
    let burst = traffic.take(requests);
    let mut sweep: Vec<ClusterMetrics> = Vec::new();
    for &p in &policies {
        let m = Simulation::cluster(Arc::clone(&cluster))
            .node_policy(p)
            .card_policy(card_policy)
            .trace(burst.clone())
            .run()?
            .cluster
            .expect("cluster tier yields cluster metrics");
        sweep.push(m);
    }
    println!(
        "cluster: {} nodes, mix {} over {requests} requests (burst, card policy {})",
        cluster.node_count(),
        mix.label(),
        card_policy.name()
    );
    let mut t = Table::new(&["node policy", "completed", "shed", "cluster QPS", "p50", "p99"]);
    for m in &sweep {
        t.row(&[
            m.node_policy.name().to_string(),
            m.cluster.completed.to_string(),
            m.shed().to_string(),
            format!("{:.1}", m.cluster_qps()),
            ms(m.cluster.latency.p50()),
            ms(m.cluster.latency.p99()),
        ]);
    }
    t.print();

    // --- capacity planning with failure headroom -------------------------
    let report = cluster::plan::plan_capacity(
        dir,
        &cfg,
        &fcfg,
        mix,
        detail_policy,
        card_policy,
        args.get_f64("qps", 0.0),
        headroom,
        requests,
    )?;
    println!("\ncapacity plan (failure drill kills 1 of {} at target load):", report.nodes_total);
    let mut tc = Table::new(&[
        "node QPS", "target QPS", "nodes", "headroom", "total", "SLA shed", "in-flight lost",
        "verdict",
    ]);
    tc.row(&[
        format!("{:.1}", report.node_qps),
        format!("{:.1}", report.target_qps),
        report.nodes_needed.to_string(),
        report.headroom.to_string(),
        report.nodes_total.to_string(),
        report.sla_shed_after_failure.to_string(),
        report.failure_shed.to_string(),
        if report.survives_single_node_failure {
            "headroom holds".to_string()
        } else {
            "HEADROOM INSUFFICIENT".to_string()
        },
    ]);
    tc.print();
    let mut tg = Table::new(&["quarter", "demand (QPS)", "nodes (incl. headroom)"]);
    for (q, demand, nodes) in &report.growth {
        tg.row(&[q.to_string(), format!("{demand:.0}"), nodes.to_string()]);
    }
    tg.print();

    // --- drain/fail scenario at mid-tier load ----------------------------
    let mut events = Vec::new();
    let mut horizon_rate = report.node_qps * cluster.node_count() as f64 * 0.5;
    if !(horizon_rate > 0.0) {
        horizon_rate = 100.0;
    }
    let mut traffic = TrafficGen::new(
        seed ^ 0xD1CE,
        mix,
        Arrival::Poisson { rate_qps: horizon_rate },
        cluster.manifest(),
        fcfg.recsys_batch,
    )?;
    let open = traffic.take(requests);
    let horizon = open.last().map(|r| r.arrival_s()).unwrap_or(0.0);
    if let Some(s) = args.get("drain") {
        events.extend(cluster::parse_events(EventKind::Drain, s)?);
    }
    if let Some(s) = args.get("fail") {
        events.extend(cluster::parse_events(EventKind::Fail, s)?);
    }
    if events.is_empty() {
        // default drill: node 0 dies 40% into the trace
        events.push(fbia::serving::cluster::NodeEvent {
            at_s: 0.4 * horizon,
            node: 0,
            kind: EventKind::Fail,
        });
    }
    let mut sim = Simulation::cluster(Arc::clone(&cluster))
        .node_policy(detail_policy)
        .card_policy(card_policy)
        .scenario(Scenario::new(events))
        .trace(open);
    if !args.flag("no-execute") {
        // execute the admitted requests' real numerics too
        sim = sim.execute(threads);
    }
    let fail_run = sim.run()?.cluster.expect("cluster tier yields cluster metrics");
    println!(
        "\nscenario ({} @ {:.0} QPS open-loop): completed {}, shed {} \
         (queue-full {}, sla {}, no-bucket {}, failed {}, unroutable {})",
        detail_policy.name(),
        horizon_rate,
        fail_run.cluster.completed,
        fail_run.shed(),
        fail_run.shed_causes.queue_full,
        fail_run.shed_causes.sla,
        fail_run.shed_causes.no_bucket,
        fail_run.shed_failed,
        fail_run.shed_unroutable
    );
    let span = fail_run.cluster.wall_s;
    let mut tn = Table::new(&[
        "node", "offered", "completed", "shed", "busy", "card util", "NIC rx", "availability",
        "state",
    ]);
    for nm in &fail_run.per_node {
        let state = if nm.failed_at_s.is_some() {
            "FAILED"
        } else if nm.drained_at_s.is_some() {
            "drained"
        } else {
            "up"
        };
        // mean compute utilization across the node's cards over the span
        let util = if span > 0.0 {
            (nm.busy_s / (span * specs[nm.node].cards as f64)).min(1.0)
        } else {
            0.0
        };
        tn.row(&[
            nm.node.to_string(),
            nm.offered.to_string(),
            nm.metrics.completed.to_string(),
            (nm.shed_admission + nm.shed_failed).to_string(),
            ms(nm.busy_s),
            pct(util),
            ms(nm.nic_rx_busy_s),
            pct(nm.availability(span)),
            state.to_string(),
        ]);
    }
    tn.print();
    if fail_run.cluster.stages.count() > 0 {
        let mut rows: Vec<(String, &StageStats)> =
            vec![("cluster".to_string(), &fail_run.cluster.stages)];
        for f in &fail_run.per_family {
            rows.push((f.family.name().to_string(), &f.metrics.stages));
        }
        print_stage_table("stage latency attribution (fail scenario, mean/p99 ms):", &rows);
    }

    if let Some(path) = args.get("json") {
        // shared BENCH_*.json schema: headline numbers from the fail-run
        // (the scenario the tier must survive), sweep + capacity as detail
        let mut bench = BenchReport::new("cluster_smoke", "sim", "modeled");
        bench.offered = fail_run.offered;
        bench.completed = fail_run.cluster.completed;
        bench.shed = fail_run.shed();
        bench.qps = fail_run.cluster_qps();
        bench.p50_ms = fail_run.cluster.latency.p50() * 1e3;
        bench.p99_ms = fail_run.cluster.latency.p99() * 1e3;
        let bench = bench
            .accept(
                "headroom_satisfies_sla_under_single_node_failure",
                report.survives_single_node_failure,
            )
            .accept(
                "conservation",
                fail_run.cluster.completed + fail_run.shed() == fail_run.offered,
            )
            .with("nodes", Json::num(cluster.node_count() as f64))
            .with("mix", Json::str(&mix.label()))
            .with("requests", Json::num(requests as f64))
            .with("card_policy", Json::str(card_policy.name()))
            .with(
                "policies",
                Json::arr(
                    sweep
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("policy", Json::str(m.node_policy.name())),
                                ("cluster_qps", Json::num(m.cluster_qps())),
                                ("completed", Json::num(m.cluster.completed as f64)),
                                ("shed", Json::num(m.shed() as f64)),
                                ("shed_rate", Json::num(m.shed_rate())),
                                ("p50_ms", Json::num(m.cluster.latency.p50() * 1e3)),
                                ("p99_ms", Json::num(m.cluster.latency.p99() * 1e3)),
                            ])
                        })
                        .collect(),
                ),
            )
            .with(
                "capacity",
                Json::obj(vec![
                    ("node_qps", Json::num(report.node_qps)),
                    ("target_qps", Json::num(report.target_qps)),
                    ("nodes_needed", Json::num(report.nodes_needed as f64)),
                    ("headroom", Json::num(report.headroom as f64)),
                    ("nodes_total", Json::num(report.nodes_total as f64)),
                    (
                        "sla_shed_after_failure",
                        Json::num(report.sla_shed_after_failure as f64),
                    ),
                    ("failure_shed", Json::num(report.failure_shed as f64)),
                    (
                        "headroom_satisfies_sla_under_single_node_failure",
                        Json::Bool(report.survives_single_node_failure),
                    ),
                ]),
            )
            .with(
                "fail_scenario",
                Json::obj(vec![
                    ("policy", Json::str(fail_run.node_policy.name())),
                    ("rate_qps", Json::num(horizon_rate)),
                    ("offered", Json::num(fail_run.offered as f64)),
                    ("completed", Json::num(fail_run.cluster.completed as f64)),
                    ("cluster_qps", Json::num(fail_run.cluster_qps())),
                    ("shed_admission", Json::num(fail_run.shed_admission as f64)),
                    ("shed_queue_full", Json::num(fail_run.shed_causes.queue_full as f64)),
                    ("shed_sla", Json::num(fail_run.shed_causes.sla as f64)),
                    ("shed_no_bucket", Json::num(fail_run.shed_causes.no_bucket as f64)),
                    ("shed_failed", Json::num(fail_run.shed_failed as f64)),
                    ("shed_unroutable", Json::num(fail_run.shed_unroutable as f64)),
                    ("shed_rate", Json::num(fail_run.shed_rate())),
                    ("stages", fail_run.cluster.stages.to_json()),
                    (
                        "availability",
                        Json::arr(
                            fail_run
                                .per_node
                                .iter()
                                .map(|nm| Json::num(nm.availability(span)))
                                .collect(),
                        ),
                    ),
                ]),
            );
        bench.write(path)?;
    }
    Ok(())
}

/// `fbia des`: the discrete-event core's acceptance drill. One seeded
/// burst trace routed twice through the [`Simulation`] builder — once with
/// static batching, once with queue-depth-triggered dynamic batch growth
/// — plus a repeat of each run to demonstrate bit-determinism. Emits the
/// shared BENCH schema with the `dynamic_batch_beats_static` flag CI gates
/// on. Modeled clock only, like `fbia capacity`.
fn cmd_des(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let requested = args
        .get("backend")
        .map(str::to_string)
        .or_else(|| std::env::var("FBIA_BACKEND").ok());
    if let Some(b) = requested {
        if b != "sim" {
            fbia::runtime::backend_by_name(&b)?;
            bail!(
                "fbia des compares batching policies on the modeled clock; \
                 only --backend sim is supported (got '{b}')"
            );
        }
    }
    let eng = sim_engine(args, &cfg)?;
    let mut static_cfg = fleet_config(args, &cfg)?;
    static_cfg.dynamic_batch = None;
    let dynb = DynamicBatch {
        depth_hi: args.get_usize("batch-depth", DynamicBatch::default().depth_hi).max(1),
        max_batch: args.get_usize("batch-cap", DynamicBatch::default().max_batch).max(2),
        marginal: DynamicBatch::default().marginal,
    };
    let mut dyn_cfg = static_cfg.clone();
    dyn_cfg.dynamic_batch = Some(dynb);
    // single-family NLP burst: same-shape queue pressure is where growth
    // windows pay; recsys never batches dynamically (multi-card fan-out)
    let mix = FamilyMix::parse(args.get_or("mix", "0/100/0"))?;
    let requests = args.get_usize("requests", 96).max(1);
    let seed = args.get_u64("seed", 1);
    let policy = card_policy_by_name(args.get_or("policy", cfg.serving.card_policy.name()))?;

    let static_fleet = Arc::new(Fleet::new(eng.clone(), static_cfg.clone())?);
    let dyn_fleet = Arc::new(Fleet::new(eng.clone(), dyn_cfg)?);
    let mut traffic =
        TrafficGen::new(seed, mix, Arrival::Burst, eng.manifest(), static_cfg.recsys_batch)?;
    let reqs = traffic.take(requests);
    let run = |fleet: &Arc<Fleet>| {
        Simulation::fleet(Arc::clone(fleet)).card_policy(policy).trace(reqs.clone()).run()
    };
    let stat = run(&static_fleet)?;
    let dynr = run(&dyn_fleet)?;
    // the determinism the seeded heap promises: identical reruns
    let stat2 = run(&static_fleet)?;
    let dyn2 = run(&dyn_fleet)?;
    let deterministic = stat.qps == stat2.qps
        && stat.p99_ms == stat2.p99_ms
        && stat.shed == stat2.shed
        && dynr.qps == dyn2.qps
        && dynr.p99_ms == dyn2.p99_ms
        && dynr.shed == dyn2.shed;
    let conserved = stat.conserved() && dynr.conserved();
    let beats = dynr.qps > stat.qps && dynr.shed <= stat.shed;

    println!(
        "des: static vs dynamic batching, mix {} over {requests} requests (burst, {} policy, des seed {:#x})",
        mix.label(),
        policy.name(),
        static_cfg.des_seed,
    );
    let mut t = Table::new(&["batching", "completed", "shed", "node QPS", "p50", "p99", "span"]);
    for (name, r) in [
        ("static".to_string(), &stat),
        (format!("dynamic (depth>={}, cap {})", dynb.depth_hi, dynb.max_batch), &dynr),
    ] {
        t.row(&[
            name,
            r.completed.to_string(),
            r.shed.to_string(),
            format!("{:.1}", r.qps),
            ms(r.p50_ms / 1e3),
            ms(r.p99_ms / 1e3),
            format!("{:.2}s", r.span_s),
        ]);
    }
    t.print();
    if stat.stages.count() > 0 || dynr.stages.count() > 0 {
        print_stage_table(
            "stage latency attribution (mean/p99 ms):",
            &[
                ("static".to_string(), &stat.stages),
                ("dynamic".to_string(), &dynr.stages),
            ],
        );
    }
    println!(
        "\ndynamic vs static: {:.1} vs {:.1} node QPS at shed {} vs {} -> {}",
        dynr.qps,
        stat.qps,
        dynr.shed,
        stat.shed,
        if beats { "reactive batching wins" } else { "NO WIN" },
    );
    println!(
        "invariants: conservation {} | bit-deterministic rerun {}",
        if conserved { "holds" } else { "VIOLATED" },
        if deterministic { "holds" } else { "VIOLATED" },
    );

    if let Some(path) = args.get("json") {
        dynr.bench_report("des_smoke", "sim")
            .accept("dynamic_batch_beats_static", beats)
            .accept("conservation", conserved)
            .accept("deterministic", deterministic)
            .with("mix", Json::str(&mix.label()))
            .with("requests", Json::num(requests as f64))
            .with("des_seed", Json::num(static_cfg.des_seed as f64))
            .with("batch_depth_hi", Json::num(dynb.depth_hi as f64))
            .with("batch_cap", Json::num(dynb.max_batch as f64))
            .with("static_qps", Json::num(stat.qps))
            .with("static_p99_ms", Json::num(stat.p99_ms))
            .with("static_shed", Json::num(stat.shed as f64))
            .write(path)?;
    }
    Ok(())
}

/// Shared replay plumbing for the observability subcommands (`fbia trace`,
/// `fbia monitor`): one seeded, modeled-clock cluster scenario — node
/// specs, policies, an open-loop Poisson trace at a deliberate fraction of
/// tier capacity, and the optional `--fail`/`--drain` event list — built
/// from one flag set so the two commands cannot drift apart.
struct Replay {
    fcfg: FleetConfig,
    mix: FamilyMix,
    requests: usize,
    dir: PathBuf,
    specs: Vec<NodeSpec>,
    node_policy: NodePolicy,
    card_policy: RoutePolicy,
    cluster: Arc<Cluster>,
    /// Mix-weighted mean modeled request cost on node 0 (seconds).
    mean_cost_s: f64,
    rate_qps: f64,
    reqs: Vec<FleetRequest>,
    /// Last arrival time of the generated trace.
    horizon_s: f64,
    /// Parsed `--fail`/`--drain` events (empty when neither flag is given;
    /// each command picks its own default drill).
    events: Vec<NodeEvent>,
}

/// Build the [`Replay`] for `cmd` from the shared flag set. `load_divisor`
/// sets the open-loop Poisson rate to `nodes / (load_divisor × mean
/// request cost)` — large divisors keep the tier mostly idle (the
/// *intrinsic* regime, what `trace` wants), small ones leave queues with
/// work in them (what `monitor`'s failure drill kills).
fn replay(
    args: &Args,
    cmd: &str,
    purpose: &str,
    cfg: &Config,
    default_nodes: usize,
    default_requests: usize,
    load_divisor: f64,
) -> Result<Replay> {
    let requested = args
        .get("backend")
        .map(str::to_string)
        .or_else(|| std::env::var("FBIA_BACKEND").ok());
    if let Some(b) = requested {
        if b != "sim" {
            fbia::runtime::backend_by_name(&b)?;
            bail!(
                "fbia {cmd} {purpose} on the modeled clock; \
                 only --backend sim is supported (got '{b}')"
            );
        }
    }
    let fcfg = fleet_config(args, cfg)?;
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10"))?;
    let requests = args.get_usize("requests", default_requests).max(1);
    let seed = args.get_u64("seed", 1);
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let specs = match &cfg.cluster {
        Some(cl) => cl.nodes.clone(),
        None => vec![cfg.node.clone(); args.get_usize("nodes", default_nodes).max(1)],
    };
    let node_policy = node_policy_by_name(args.get_or("policy", "weighted"))?;
    let card_policy =
        card_policy_by_name(args.get_or("card-policy", cfg.serving.card_policy.name()))?;
    let cluster = Arc::new(Cluster::new(&dir, cfg, &specs, fcfg.clone())?);
    let mean_cost_s = reqs_mean_cost(&cluster.nodes()[0].fam_cost_s, mix).max(1e-6);
    let rate_qps = cluster.node_count() as f64 / (load_divisor * mean_cost_s);
    let mut traffic = TrafficGen::new(
        seed,
        mix,
        Arrival::Poisson { rate_qps },
        cluster.manifest(),
        fcfg.recsys_batch,
    )?;
    let reqs = traffic.take(requests);
    let horizon_s = reqs.last().map(|r| r.arrival_s()).unwrap_or(0.0);
    let mut events = Vec::new();
    if let Some(s) = args.get("drain") {
        events.extend(cluster::parse_events(EventKind::Drain, s)?);
    }
    if let Some(s) = args.get("fail") {
        events.extend(cluster::parse_events(EventKind::Fail, s)?);
    }
    Ok(Replay {
        fcfg,
        mix,
        requests,
        dir,
        specs,
        node_policy,
        card_policy,
        cluster,
        mean_cost_s,
        rate_qps,
        reqs,
        horizon_s,
        events,
    })
}

/// The headline bits two [`SimReport`]s must share for the tracing /
/// monitoring cost contract ("telemetry off ⇒ bit-identical run").
fn reports_bit_identical(a: &SimReport, b: &SimReport) -> bool {
    a.qps.to_bits() == b.qps.to_bits()
        && a.p50_ms.to_bits() == b.p50_ms.to_bits()
        && a.p99_ms.to_bits() == b.p99_ms.to_bits()
        && a.span_s.to_bits() == b.span_s.to_bits()
        && a.completed == b.completed
        && a.shed == b.shed
}

/// Shared windowed-telemetry table ([`fbia::obs::metrics`]): one row per
/// fixed-width window, sampled down to ~16 rows for long series.
fn print_window_table(title: &str, s: &WindowedSeries) {
    if s.windows == 0 {
        return;
    }
    println!("\n{title}");
    let mut t = Table::new(&[
        "window", "start", "offered", "done", "shed", "QPS", "p50 ms", "p99 ms", "card", "NIC",
    ]);
    let step = s.windows.div_ceil(16).max(1);
    for w in (0..s.windows).step_by(step) {
        t.row(&[
            w.to_string(),
            format!("{:.3}s", w as f64 * s.width_s),
            s.offered[w].to_string(),
            s.completed[w].to_string(),
            s.shed(w).to_string(),
            format!("{:.1}", s.qps[w]),
            format!("{:.2}", s.p50_ms[w]),
            format!("{:.2}", s.p99_ms[w]),
            pct(s.card_util[w]),
            pct(s.nic_util[w]),
        ]);
    }
    t.print();
}

/// `fbia trace`: the observability drill ([`fbia::obs`]). Replays one
/// seeded cluster scenario twice — untraced and traced — and checks the
/// tracing cost contract (bit-identical reports, in-bounds utilization,
/// stage sums matching latency), then reruns the same seed with every
/// node's NIC bandwidth throttled until the wire provably dominates the
/// cards, demonstrating the stage breakdown separates the NIC-bound regime
/// from the compute-bound one. Writes the Perfetto-loadable Chrome trace
/// JSON to `--out` (default trace.json) and validates its schema by
/// parsing it back. Exits nonzero if any acceptance flag fails, so CI can
/// gate on it. Modeled clock only, like `fbia cluster`.
fn cmd_trace(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rp = replay(args, "trace", "replays scenarios", &cfg, 2, 120, 12.0)?;
    // The large load divisor keeps the tier mostly idle: queueing is
    // negligible and the breakdown shows the *intrinsic* regime
    // (compute-bound stock, network-bound throttled) instead of saturation
    // queueing drowning both.
    let out = args.get_or("out", "trace.json");
    println!(
        "trace: {} nodes, mix {} over {} requests ({:.0} QPS open-loop, {} / {})",
        rp.cluster.node_count(),
        rp.mix.label(),
        rp.requests,
        rp.rate_qps,
        rp.node_policy.name(),
        rp.card_policy.name()
    );

    let sim = |cl: &Arc<Cluster>| {
        let mut s = Simulation::cluster(Arc::clone(cl))
            .node_policy(rp.node_policy)
            .card_policy(rp.card_policy)
            .trace(rp.reqs.clone());
        if !rp.events.is_empty() {
            s = s.scenario(Scenario::new(rp.events.clone()));
        }
        s
    };
    // the cost contract: a rerun is bit-identical, and turning tracing ON
    // must not perturb a single report bit either
    let plain = sim(&rp.cluster).run()?;
    let plain2 = sim(&rp.cluster).run()?;
    let (traced, tracer) = sim(&rp.cluster).run_traced()?;
    let bit_identical =
        reports_bit_identical(&plain, &plain2) && reports_bit_identical(&plain, &traced);

    // every completed request's stage decomposition sums to its latency
    let stage_sums = tracer
        .requests()
        .iter()
        .filter(|r| r.completed())
        .all(|r| (r.stage.total_s() - r.latency_s()).abs() <= 1e-9 * r.latency_s().max(1.0));
    // merged occupancy on every recorded track stays within the span
    let mut tracks: Vec<(SegKind, usize, usize)> = Vec::new();
    for s in tracer.segs() {
        if !tracks.contains(&(s.kind, s.node, s.lane)) {
            tracks.push((s.kind, s.node, s.lane));
        }
    }
    let util_le_one =
        tracks.iter().all(|&(k, n, l)| tracer.utilization(k, n, l) <= 1.0 + 1e-9);

    if traced.stages.count() > 0 {
        print_stage_table(
            "stage latency attribution (unconstrained, mean/p99 ms):",
            &[("cluster".to_string(), &traced.stages)],
        );
    }
    println!("\nresource occupancy (merged busy over {:.3}s span):", tracer.span_s());
    let mut tu = Table::new(&["resource", "node", "lane", "busy", "utilization"]);
    for &(k, n, l) in &tracks {
        tu.row(&[
            k.name().to_string(),
            n.to_string(),
            l.to_string(),
            ms(tracer.busy_s(k, n, l)),
            pct(tracer.utilization(k, n, l)),
        ]);
    }
    tu.print();

    // same seed, NIC throttled: halve bw_bits (and keep halving) until the
    // mix's mean wire time provably dominates its mean modeled card cost,
    // flipping the dominant stage from compute to network
    let mean_wire_bytes = rp
        .reqs
        .iter()
        .map(|r| {
            let (i, o) = rp.cluster.wire().bytes(r);
            (i + o) as f64
        })
        .sum::<f64>()
        / rp.reqs.len().max(1) as f64;
    let mut bw_bits = rp.specs[0].nic.bw_bits / 2.0;
    while mean_wire_bytes * 8.0 / bw_bits < 4.0 * rp.mean_cost_s && bw_bits > 1.0 {
        bw_bits /= 2.0;
    }
    let mut slow_specs = rp.specs.clone();
    for s in &mut slow_specs {
        s.nic.bw_bits = bw_bits;
    }
    let slow_cluster = Arc::new(Cluster::new(&rp.dir, &cfg, &slow_specs, rp.fcfg.clone())?);
    let slow = sim(&slow_cluster).run()?;
    let compute_bound = traced.stages.dominant() == Some(Stage::Compute);
    let network_bound = slow.stages.dominant() == Some(Stage::Network);
    println!(
        "\nNIC throttle drill: bw {:.2e} -> {:.2e} bits/s; dominant stage {} -> {}",
        rp.specs[0].nic.bw_bits,
        bw_bits,
        traced.stages.dominant().map(Stage::name).unwrap_or("-"),
        slow.stages.dominant().map(Stage::name).unwrap_or("-"),
    );
    if slow.stages.count() > 0 {
        print_stage_table(
            "stage latency attribution (NIC-throttled, mean/p99 ms):",
            &[("cluster".to_string(), &slow.stages)],
        );
    }

    // export + schema sanity: parse the file back and require the Chrome
    // trace-event essentials on every event
    let doc = chrome_trace(&tracer);
    std::fs::write(out, doc.to_string()).map_err(|e| err!("writing {out}: {e}"))?;
    let parsed = Json::parse(
        &std::fs::read_to_string(out).map_err(|e| err!("reading back {out}: {e}"))?,
    )
    .map_err(|e| err!("{out} is not valid JSON: {e}"))?;
    let events = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("{out}: missing traceEvents array"))?;
    let schema_valid = !events.is_empty()
        && events.iter().all(|e| {
            e.get("ph").and_then(Json::as_str).is_some()
                && e.get("ts").and_then(Json::as_f64).is_some()
                && e.get("pid").and_then(Json::as_f64).is_some()
                && e.get("tid").and_then(Json::as_f64).is_some()
        });
    println!(
        "\nwrote {out}: {} events ({} occupancy segments, {} request spans) — load in Perfetto (ui.perfetto.dev)",
        events.len(),
        tracer.segs().len(),
        tracer.requests().len(),
    );

    let checks = [
        ("tracing_off_bit_identical", bit_identical),
        ("stage_sums_match_latency", stage_sums),
        ("utilization_le_one", util_le_one),
        ("compute_bound_unconstrained", compute_bound),
        ("network_bound_when_bw_halved", network_bound),
        ("trace_schema_valid", schema_valid),
        ("conservation", traced.conserved() && slow.conserved()),
    ];
    println!();
    for (name, holds) in &checks {
        println!("  {:<32} {}", name, if *holds { "holds" } else { "VIOLATED" });
    }

    if let Some(path) = args.get("json") {
        let mut bench = traced.bench_report("trace_smoke", "sim");
        for (name, holds) in &checks {
            bench = bench.accept(name, *holds);
        }
        bench
            .with("nodes", Json::num(rp.cluster.node_count() as f64))
            .with("mix", Json::str(&rp.mix.label()))
            .with("requests", Json::num(rp.requests as f64))
            .with("rate_qps", Json::num(rp.rate_qps))
            .with("node_policy", Json::str(rp.node_policy.name()))
            .with("card_policy", Json::str(rp.card_policy.name()))
            .with("trace_out", Json::str(out))
            .with("trace_events", Json::num(events.len() as f64))
            .with(
                "nic_throttle",
                Json::obj(vec![
                    ("bw_bits_stock", Json::num(rp.specs[0].nic.bw_bits)),
                    ("bw_bits_throttled", Json::num(bw_bits)),
                    (
                        "dominant_unconstrained",
                        Json::str(traced.stages.dominant().map(Stage::name).unwrap_or("-")),
                    ),
                    (
                        "dominant_throttled",
                        Json::str(slow.stages.dominant().map(Stage::name).unwrap_or("-")),
                    ),
                    ("stages_throttled", slow.stages.to_json()),
                ]),
            )
            .write(path)?;
    }
    if let Some((name, _)) = checks.iter().find(|(_, holds)| !holds) {
        bail!("trace acceptance check '{name}' failed");
    }
    Ok(())
}

/// Mix-weighted mean modeled request cost (seconds) over one node's
/// per-family cost estimates (indexed recsys/nlp/cv like `fam_cost_s`).
fn reqs_mean_cost(fam_cost_s: &[f64; 3], mix: FamilyMix) -> f64 {
    let w = [mix.recsys, mix.nlp, mix.cv];
    let total: f64 = w.iter().sum();
    if total <= 0.0 {
        return fam_cost_s.iter().sum::<f64>() / 3.0;
    }
    fam_cost_s.iter().zip(w.iter()).map(|(c, w)| c * w).sum::<f64>() / total
}

/// Scan a probe run for the busiest admitted moment on `node`: sweep the
/// completed requests' `[arrival, finish]` intervals and return the
/// in-flight count `k` and midpoint `t*` of the widest interval holding a
/// maximal simultaneous count with midpoint ≤ `t_max` (capping `t*` keeps
/// enough run after the kill for burn rules to observe recovery). Failing
/// the node at `t*` kills that admitted-but-undelivered work: the
/// monitored rerun shares every event before `t*` with the probe (same
/// seed, same trace — DES runs are bit-reproducible), so the kill and the
/// alerts it trips are deterministic too.
fn probe_inflight_peak(tracer: &Tracer, node: usize, t_max: f64) -> (usize, f64) {
    let mut edges: Vec<(f64, i64)> = Vec::new();
    for r in tracer.requests() {
        if r.node == node && r.completed() && r.finish_s > r.arrival_s {
            edges.push((r.arrival_s, 1));
            edges.push((r.finish_s, -1));
        }
    }
    // ties: process the -1 first so touching intervals don't overcount
    edges.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    });
    let mut cur = 0i64;
    // (in-flight count, interval width, interval midpoint)
    let mut best = (0i64, -1.0f64, 0.0f64);
    for i in 0..edges.len().saturating_sub(1) {
        cur += edges[i].1;
        let (a, b) = (edges[i].0, edges[i + 1].0);
        let mid = 0.5 * (a + b);
        if cur > 0 && mid <= t_max && (cur, b - a) > (best.0, best.1) {
            best = (cur, b - a, mid);
        }
    }
    (best.0.max(0) as usize, best.2)
}

/// One monitored drill: everything [`cmd_monitor`]'s acceptance checks
/// need from a single DES seed.
struct Drill {
    report: SimReport,
    tracer: Tracer,
    monitor: MonitorReport,
    /// Second monitored run of the identical scenario (bit-determinism).
    monitor2: MonitorReport,
    /// Same scenario with all telemetry off (cost contract).
    plain: SimReport,
    window_s: f64,
    /// `--fail`/`--drain` given (`false`) or the calibrated default drill
    /// (`true`) — the burn-alert acceptance checks only apply to the latter.
    calibrated: bool,
    /// Time of the (first) fail event; NaN when the scenario has none.
    fail_at_s: f64,
    /// In-flight peak the probe found (calibrated drill only).
    probed_k: usize,
}

/// Run the monitored drill for `rp` at `des_seed`. With no user
/// `--fail`/`--drain` events, calibrates the default drill: a probe run
/// (no scenario, traced) finds node 0's in-flight peak `(k, t*)`, the
/// window width is sized so the `k` kills at `t*` dominate their window
/// (`2k` expected arrivals per window, far over the 1% availability
/// budget), and the scenario becomes a single node-0 Fail at `t*`.
fn monitor_drill(
    rp: &Replay,
    cfg: &Config,
    spec: &SloSpec,
    des_seed: u64,
    window_override_s: Option<f64>,
) -> Result<Drill> {
    let cluster = if des_seed == rp.fcfg.des_seed {
        Arc::clone(&rp.cluster)
    } else {
        let mut fcfg = rp.fcfg.clone();
        fcfg.des_seed = des_seed;
        Arc::new(Cluster::new(&rp.dir, cfg, &rp.specs, fcfg)?)
    };
    let sim = |events: &[NodeEvent]| {
        let mut s = Simulation::cluster(Arc::clone(&cluster))
            .node_policy(rp.node_policy)
            .card_policy(rp.card_policy)
            .trace(rp.reqs.clone());
        if !events.is_empty() {
            s = s.scenario(Scenario::new(events.to_vec()));
        }
        s
    };
    let calibrated = rp.events.is_empty();
    let (events, probed_k) = if calibrated {
        let (_, probe) = sim(&[]).run_traced()?;
        let (k, t_star) = probe_inflight_peak(&probe, 0, 0.7 * rp.horizon_s);
        // nothing in flight on node 0 anywhere (pathological custom flags):
        // fail mid-run anyway and let the acceptance checks report it
        let (k, t_star) = if k == 0 { (1, 0.35 * rp.horizon_s) } else { (k, t_star) };
        (vec![NodeEvent { at_s: t_star, node: 0, kind: EventKind::Fail }], k)
    } else {
        (rp.events.clone(), 0)
    };
    let fail_at_s = events
        .iter()
        .filter(|e| e.kind == EventKind::Fail)
        .map(|e| e.at_s)
        .fold(f64::NAN, |acc, t| if acc.is_nan() { t } else { acc.min(t) });
    // width: small enough that the kill dominates its window (~2k expected
    // arrivals), large enough that the run still spans >= ~24 windows
    let window_s = window_override_s
        .unwrap_or_else(|| {
            (rp.horizon_s / 24.0).min(2.0 * probed_k.max(1) as f64 / rp.rate_qps)
        })
        .max(1e-6);
    let (report, tracer, monitor) = sim(&events).run_monitored(window_s, spec)?;
    let (_, _, monitor2) = sim(&events).run_monitored(window_s, spec)?;
    let plain = sim(&events).run()?;
    Ok(Drill {
        report,
        tracer,
        monitor,
        monitor2,
        plain,
        window_s,
        calibrated,
        fail_at_s,
        probed_k,
    })
}

/// `fbia monitor`: windowed telemetry + SLO burn-rate monitoring over one
/// seeded cluster scenario ([`fbia::obs::metrics`] / [`fbia::obs::slo`]).
/// Shares `fbia trace`'s replay plumbing (same flags, same seeded trace) at
/// a heavier load divisor so queues hold work worth killing. By default it
/// calibrates its own failure drill — probe the unperturbed run for node
/// 0's in-flight peak, fail the node right there — and checks that the
/// availability burn alert fires within the detection bound, clears after
/// recovery, and does both deterministically (bit-identical alert streams
/// on a rerun, fires-and-clears again under a different DES seed). With
/// explicit `--fail`/`--drain` events it monitors that scenario instead
/// and keeps the invariant checks (windowed conservation, telemetry-off
/// bit-identity). `--out` writes the Chrome trace with SLO counter tracks;
/// `--json` emits the shared BENCH schema. Exits nonzero if any acceptance
/// check fails, so CI can gate on it. Modeled clock only.
fn cmd_monitor(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rp = replay(args, "monitor", "monitors scenarios", &cfg, 3, 360, 4.0)?;
    // load divisor 4: per-node utilization ~25% with 3 nodes up, ~37.5%
    // after one dies — busy enough to keep in-flight work, enough headroom
    // that the survivors absorb the rerouted load without shedding (the
    // alert must *clear*)
    let p99_budget_ms = match args.get("p99-budget-ms") {
        Some(v) => {
            let b: f64 =
                v.parse().map_err(|_| err!("--p99-budget-ms must be a number (ms)"))?;
            if !b.is_finite() || b <= 0.0 {
                bail!("--p99-budget-ms must be positive (got {b})");
            }
            b
        }
        // loosest Table I family budget: the mix shares one tier, so the
        // latency objective watches the slackest contract
        None => Family::ALL
            .iter()
            .map(|f| f.latency_budget_s() * 1e3)
            .fold(f64::MIN, f64::max),
    };
    let spec = SloSpec::deployment_default(p99_budget_ms);
    let window_override_s = args
        .get("window-ms")
        .map(|v| {
            let w: f64 = v.parse().map_err(|_| err!("--window-ms must be a number (ms)"))?;
            if !w.is_finite() || w <= 0.0 {
                bail!("--window-ms must be positive (got {w})");
            }
            Ok(w * 1e-3)
        })
        .transpose()?;

    let d = monitor_drill(&rp, &cfg, &spec, rp.fcfg.des_seed, window_override_s)?;
    println!(
        "monitor: {} nodes, mix {} over {} requests ({:.0} QPS open-loop, {} / {}), \
         {:.1} ms windows",
        rp.cluster.node_count(),
        rp.mix.label(),
        rp.requests,
        rp.rate_qps,
        rp.node_policy.name(),
        rp.card_policy.name(),
        d.window_s * 1e3,
    );
    if d.calibrated {
        println!(
            "default drill: probe found {} in flight on node 0; failing it at {:.4}s",
            d.probed_k, d.fail_at_s,
        );
    } else if rp.events.is_empty() {
        println!("scenario: none (steady state)");
    } else {
        for e in &rp.events {
            println!("scenario: {} node {} at {:.4}s", e.kind.name(), e.node, e.at_s);
        }
    }
    println!(
        "\nheadline: {} offered, {} completed, {} shed ({} to node failure) — \
         {:.1} QPS, p50 {:.2} ms, p99 {:.2} ms",
        d.report.offered,
        d.report.completed,
        d.report.shed,
        d.report.shed_failed,
        d.report.qps,
        d.report.p50_ms,
        d.report.p99_ms,
    );
    print_window_table("windowed telemetry (fixed-width, derived post-hoc):", &d.monitor.series);

    println!("\nSLO spec: {}", spec.to_json());
    if d.monitor.alerts.is_empty() {
        println!("alerts: none (no burn rule tripped)");
    } else {
        println!("alerts:");
        for a in &d.monitor.alerts {
            println!("  {}", a.describe());
        }
    }

    // acceptance: invariants on any scenario, the burn-alert lifecycle on
    // the calibrated drill (whose kill is constructed to trip it)
    let mut checks: Vec<(&str, bool)> = vec![
        ("windows_conserve_totals", d.report.windows_reconcile()),
        ("metrics_off_bit_identical", reports_bit_identical(&d.plain, &d.report)),
        ("alerts_bit_deterministic", d.monitor == d.monitor2),
        ("conservation", d.report.conserved()),
    ];
    let mut reseeded: Option<Drill> = None;
    if d.calibrated {
        let w_fail = (d.fail_at_s / d.window_s) as usize;
        // sheds are attributed at *arrival*, so the burn can show up a few
        // windows before the kill; allow the detection bound on both sides
        let slack = spec.max_detection_windows();
        let from = w_fail.saturating_sub(slack);
        let fires = d.monitor.fires_within("availability", from, 2 * slack);
        checks.push(("burn_alert_fires_within_bound", fires));
        checks.push(("burn_alert_clears_after_recovery", d.monitor.cleared("availability")));
        // same drill re-calibrated under a different DES tie-break seed:
        // detection and recovery must hold there too, not just at one seed
        let d2 = monitor_drill(&rp, &cfg, &spec, rp.fcfg.des_seed ^ 0x5EED, window_override_s)?;
        let w2 = (d2.fail_at_s / d2.window_s) as usize;
        let fires2 = d2.monitor.fires_within("availability", w2.saturating_sub(slack), 2 * slack);
        checks.push((
            "fires_and_clears_across_des_seeds",
            fires2 && d2.monitor.cleared("availability") && d2.monitor == d2.monitor2,
        ));
        reseeded = Some(d2);
    }
    println!();
    for (name, holds) in &checks {
        println!("  {:<36} {}", name, if *holds { "holds" } else { "VIOLATED" });
    }

    if let Some(out) = args.get("out") {
        let doc = chrome_trace_monitored(&d.tracer, Some(&d.monitor));
        std::fs::write(out, doc.to_string()).map_err(|e| err!("writing {out}: {e}"))?;
        println!(
            "\nwrote {out}: {} trace events + SLO counter tracks — load in Perfetto",
            d.tracer.segs().len() + d.tracer.requests().len(),
        );
    }

    if let Some(path) = args.get("json") {
        let mut bench = d.report.bench_report("monitor_smoke", "sim");
        for (name, holds) in &checks {
            bench = bench.accept(name, *holds);
        }
        let mut bench = bench
            .with("nodes", Json::num(rp.cluster.node_count() as f64))
            .with("mix", Json::str(&rp.mix.label()))
            .with("requests", Json::num(rp.requests as f64))
            .with("rate_qps", Json::num(rp.rate_qps))
            .with("node_policy", Json::str(rp.node_policy.name()))
            .with("card_policy", Json::str(rp.card_policy.name()))
            .with("window_ms", Json::num(d.window_s * 1e3))
            .with("p99_budget_ms", Json::num(p99_budget_ms))
            .with("slo", spec.to_json())
            .with("alert_count", Json::num(d.monitor.alerts.len() as f64));
        if d.calibrated {
            bench = bench
                .with("fail_at_s", Json::num(d.fail_at_s))
                .with("probed_in_flight", Json::num(d.probed_k as f64))
                .with("killed_in_flight", Json::num(d.report.shed_failed as f64));
            if let Some(d2) = &reseeded {
                bench = bench.with(
                    "reseeded",
                    Json::obj(vec![
                        ("fail_at_s", Json::num(d2.fail_at_s)),
                        ("killed_in_flight", Json::num(d2.report.shed_failed as f64)),
                        ("alert_count", Json::num(d2.monitor.alerts.len() as f64)),
                    ]),
                );
            }
        }
        bench.write(path)?;
    }
    if let Some((name, _)) = checks.iter().find(|(_, holds)| !holds) {
        bail!("monitor acceptance check '{name}' failed");
    }
    Ok(())
}

/// `fbia bench-diff`: the bench regression gate
/// ([`fbia::util::bench::compare`]). Diffs fresh `BENCH_*.json` reports
/// (positional paths and/or `--fresh a.json,b.json`) against the committed
/// baselines in `--baseline-dir` (default `bench/baselines`), matching on
/// the `bench` identity field. Baselines are partial by design — only the
/// metrics a baseline pins are gated (see `bench/baselines/README.md` for
/// the refresh procedure). `--tol metric=rel` relaxes one metric's
/// relative tolerance; `--json` writes the machine verdict. Exits nonzero
/// on any regression, missing pinned metric, or fresh report without a
/// committed baseline — the blocking CI step.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let base_dir = Path::new(args.get_or("baseline-dir", "bench/baselines"));
    let mut fresh_paths: Vec<String> = args.positional.clone();
    if let Some(list) = args.get("fresh") {
        fresh_paths
            .extend(list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from));
    }
    if fresh_paths.is_empty() {
        bail!(
            "usage: fbia bench-diff [--baseline-dir bench/baselines] <BENCH_*.json>... \
             (or --fresh a.json,b.json)"
        );
    }

    let mut tol = compare::Tolerances::default();
    if let Some(spec) = args.get("tol") {
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (metric, rel) = part.split_once('=').ok_or_else(|| {
                err!("--tol entries are metric=rel (e.g. qps=0.10); got '{part}'")
            })?;
            let rel_v: f64 = rel
                .trim()
                .parse()
                .map_err(|_| err!("--tol {metric}: '{rel}' is not a number"))?;
            tol.set_rel(metric.trim(), rel_v)?;
        }
    }

    // committed baselines, indexed by their `bench` identity field
    let entries = std::fs::read_dir(base_dir)
        .map_err(|e| err!("reading baseline dir {}: {e}", base_dir.display()))?;
    let mut base_paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    base_paths.sort();
    let mut baselines: Vec<(String, Json)> = Vec::new();
    for p in &base_paths {
        let text =
            std::fs::read_to_string(p).map_err(|e| err!("reading {}: {e}", p.display()))?;
        let doc = Json::parse(&text).map_err(|e| err!("{}: {e}", p.display()))?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("{}: baseline has no 'bench' field", p.display()))?
            .to_string();
        baselines.push((bench, doc));
    }
    if baselines.is_empty() {
        bail!("no *.json baselines in {}", base_dir.display());
    }

    let mut t = Table::new(&["bench", "metric", "baseline", "fresh", "delta", "verdict"]);
    let mut failures: Vec<String> = Vec::new();
    let mut diffs: Vec<Json> = Vec::new();
    for path in &fresh_paths {
        let text = std::fs::read_to_string(path).map_err(|e| err!("reading {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| err!("{path}: {e}"))?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("{path}: fresh report has no 'bench' field"))?;
        let Some((_, base)) = baselines.iter().find(|(b, _)| b == bench) else {
            // a bench without a baseline must fail loudly, or new benches
            // would silently escape the gate forever
            failures.push(format!(
                "{bench}: no committed baseline in {} (seed one per bench/baselines/README.md)",
                base_dir.display()
            ));
            continue;
        };
        let d = compare::compare(base, &doc, &tol)?;
        for m in &d.metrics {
            t.row(&[
                d.bench.clone(),
                m.metric.clone(),
                format!("{:.4}", m.base),
                format!("{:.4}", m.fresh),
                format!("{:+.2}%", 100.0 * m.delta_rel),
                (if m.within { "ok" } else { "REGRESSED" }).to_string(),
            ]);
        }
        failures.extend(d.failures());
        diffs.push(d.to_json());
    }
    t.print();

    if let Some(path) = args.get("json") {
        let doc = Json::obj(vec![
            ("pass", Json::Bool(failures.is_empty())),
            ("diffs", Json::arr(diffs)),
            ("failures", Json::arr(failures.iter().map(|f| Json::str(f)).collect())),
        ]);
        std::fs::write(path, doc.to_string()).map_err(|e| err!("writing {path}: {e}"))?;
    }
    if failures.is_empty() {
        println!(
            "\nbench-diff: {} report(s) within tolerance of the committed baselines",
            fresh_paths.len()
        );
        Ok(())
    } else {
        eprintln!();
        for f in &failures {
            eprintln!("bench-diff: {f}");
        }
        bail!("{} bench regression(s) against committed baselines", failures.len());
    }
}

/// `fbia lint`: the static analyzer standalone — nothing is prepared,
/// executed or simulated unless a rule needs the analytic cost model
/// (`--sla-ms` floors). Lints every builtin model (or `--model <id>`)
/// through shape/dtype inference and the memory-fit proof, then the
/// deployment layer from the shared fleet knobs. Exits nonzero on any
/// Error-severity diagnostic, so CI can gate on it; `--json` emits the
/// shared BENCH schema with a `zero_diagnostics` acceptance flag.
fn cmd_lint(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let models: Vec<ModelId> = match args.get("model") {
        Some(m) if !args.flag("all-models") => vec![parse_model(m)?],
        _ => ModelId::ALL.to_vec(),
    };
    let mut total = fbia::analysis::Report::new();
    let mut t = Table::new(&["model", "nodes", "errors", "warnings"]);
    let mut model_rows: Vec<Json> = Vec::new();
    for id in &models {
        let g = id.build();
        let r = fbia::analysis::lint_built_graph(&g, &cfg);
        t.row(&[
            id.name().to_string(),
            g.nodes.len().to_string(),
            r.errors().to_string(),
            r.warnings().to_string(),
        ]);
        model_rows.push(Json::obj(vec![
            ("model", Json::str(id.name())),
            ("errors", Json::num(r.errors() as f64)),
            ("warnings", Json::num(r.warnings() as f64)),
        ]));
        total.merge(r);
    }
    t.print();

    // deployment layer: the fleet knobs against the (possibly --config
    // overridden) node/cluster; --qps adds the NIC-bandwidth rule
    let fcfg = fleet_config(args, &cfg)?;
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10"))?;
    let qps = args
        .get("qps")
        .map(|v| v.parse::<f64>().map_err(|_| err!("--qps must be a number")))
        .transpose()?;
    total.merge(fcfg.lint(&cfg, mix, qps)?);

    // `--precision int8`: the quantization-accuracy-budget rule — the
    // static per-layer view of the runtime's int8 serving plan (which
    // weights quantize, which fall back to f32 and why)
    if Precision::parse(args.get_or("precision", "f32"))? == Precision::Int8 {
        let dir = Path::new(args.get_or("artifacts", "artifacts"));
        let manifest = if dir.join("manifest.json").exists() {
            fbia::runtime::artifact::Manifest::load(dir)?
        } else {
            fbia::runtime::builtin::builtin_manifest()
        };
        println!("\nint8 serving plan (per-layer estimated error vs budget):");
        let mut tq = Table::new(&["artifact", "weight", "k", "est err", "decision"]);
        // batch variants share weights — show each (weight, k) once, under
        // the first artifact that carries it
        let mut seen = std::collections::HashSet::new();
        for art in &manifest.artifacts {
            for d in validate::int8_plan(art) {
                if !seen.insert((d.name.clone(), d.k)) {
                    continue;
                }
                tq.row(&[
                    art.name.clone(),
                    d.name.clone(),
                    d.k.to_string(),
                    format!("{:.4}", d.est_error),
                    if d.table {
                        "int8 (table)".into()
                    } else if d.quantize {
                        "int8".into()
                    } else {
                        "f32 fallback".into()
                    },
                ]);
            }
        }
        tq.print();
        total.merge(fbia::analysis::lint_quantization(&manifest));
    }

    if total.is_empty() {
        println!(
            "\nlint: {} model(s) + deployment config clean ({} rules)",
            models.len(),
            fbia::analysis::RuleId::ALL.len()
        );
    } else {
        println!("\n{}", total.render().trim_end());
        println!("\nlint: {} error(s), {} warning(s)", total.errors(), total.warnings());
    }

    if let Some(path) = args.get("json") {
        BenchReport::new("lint_smoke", "static", "none")
            .accept("zero_diagnostics", total.is_empty())
            .accept("no_errors", !total.has_errors())
            .with("models", Json::arr(model_rows))
            .with("diagnostics", total.to_json())
            .write(path)?;
    }
    if total.has_errors() {
        bail!("lint found {} error(s)", total.errors());
    }
    Ok(())
}

fn cmd_capacity(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    // Fig. 1's accelerator side now comes from the fleet: a modeled-clock
    // engine routes a mixed trace and the measured node QPS sizes the
    // fleet. Capacity planning only makes sense on the sim backend, so a
    // request for anything else is an error (unknown names keep the strict
    // valid-names message), never a silent substitution.
    let requested = args
        .get("backend")
        .map(str::to_string)
        .or_else(|| std::env::var("FBIA_BACKEND").ok());
    if let Some(b) = requested {
        if b != "sim" {
            fbia::runtime::backend_by_name(&b)?;
            bail!(
                "fbia capacity sizes fleets on the modeled clock; \
                 only --backend sim is supported (got '{b}')"
            );
        }
    }
    let eng = sim_engine(args, &cfg)?;
    let fcfg = fleet_config(args, &cfg)?;
    let requests = args.get_usize("requests", 96).max(1);
    let policy = match args.get("policy") {
        Some(p) => card_policy_by_name(p)?,
        None => cfg.serving.card_policy,
    };
    // replica placement is mix-independent: build the fleet once and route
    // both scenarios' traces through it
    let fleet = Fleet::new(eng, fcfg)?;
    for (scenario, mix) in [
        (GrowthScenario::recommendation(), FamilyMix::new(1.0, 0.0, 0.0)?),
        (GrowthScenario::other_ml(), FamilyMix::new(0.0, 1.0, 1.0)?),
    ] {
        let report = plan_capacity(&fleet, mix, policy, &scenario, &cfg, requests)?;
        println!(
            "\nFig. 1 ({}): fleet-measured node throughput {:.1} items/s (mix {}, {} policy, shed {})",
            scenario.name,
            report.node_items_per_s,
            report.mix.label(),
            report.policy.name(),
            pct(report.shed_rate),
        );
        let mut t = Table::new(&["quarter", "demand (QPS)", "CPU servers", "accel servers", "growth (norm)"]);
        for p in &report.points {
            t.row(&[
                p.quarter.to_string(),
                format!("{:.0}", p.demand_qps),
                format!("{:.0}", p.cpu_servers),
                format!("{:.0}", p.accel_servers),
                f2(p.cpu_norm),
            ]);
        }
        t.print();
    }
    Ok(())
}
