//! The fleet router: dispatch a mixed request stream to replicas.
//!
//! Routing is a deterministic planning pass over the stream in arrival
//! order. Each card serializes its compute segments; each card's PCIe link
//! serializes its transfer segments ([`LinkOccupancy`] — two requests
//! landing on one card contend for the same x4 link). A DLRM request first
//! fans its SLS segments out to the shard cards (the stage costs the
//! slowest one, Fig. 6 left) and then runs the dense partition on its
//! replica's card; NLP and CV requests are single segments.
//!
//! Admission control sheds a request when its primary card's bounded queue
//! is full, or — with an SLA budget configured — when queue depth × modeled
//! cost would blow the budget (the request could not finish in time anyway,
//! so shedding it early is strictly better than serving it late).
//!
//! Because the planner's only state is modeled costs and arrival times, the
//! resulting metrics are bit-deterministic across runs and across worker
//! counts on the modeled clock; the worker pool only executes numerics.

use crate::serving::fleet::{Family, FleetConfig, FleetRequest};
use crate::serving::fleet::replica::ReplicaManager;
use crate::sim::transfer::LinkOccupancy;
use crate::util::error::{bail, Result};
use std::collections::VecDeque;

/// Dispatch policy for choosing among a family's replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Per-family rotation, blind to load — the naive baseline.
    RoundRobin,
    /// Fewest outstanding segments on the candidate's primary card.
    LeastOutstanding,
    /// Smallest projected completion time, priced with the sim backend's
    /// modeled per-run costs and the link occupancy accumulator. Degrades
    /// to queue balancing on wall-clock backends (uniform placeholder
    /// costs).
    LatencyAware,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::LatencyAware];

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastOutstanding => "least-outstanding",
            RoutePolicy::LatencyAware => "latency-aware",
        }
    }

    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "round-robin" | "rr" => RoutePolicy::RoundRobin,
            "least-outstanding" | "lo" => RoutePolicy::LeastOutstanding,
            "latency-aware" | "la" => RoutePolicy::LatencyAware,
            other => bail!(
                "unknown routing policy '{other}' \
                 (valid: round-robin, least-outstanding, latency-aware)"
            ),
        })
    }
}

/// Where an admitted request went.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Recsys { replica: usize },
    Nlp { replica: usize, bucket: usize },
    Cv { replica: usize },
}

/// An admitted request's routing outcome on the planner's clock.
#[derive(Debug, Clone, Copy)]
pub struct Routed {
    pub decision: Decision,
    /// Primary card (dense card for recsys) — metrics attribution.
    pub card: usize,
    pub latency_s: f64,
    pub finish_s: f64,
}

/// One planned request: family/arrival always, route only when admitted.
#[derive(Debug, Clone)]
pub struct PlannedRequest {
    pub family: Family,
    pub arrival_s: f64,
    pub items: usize,
    pub route: Option<Routed>,
}

/// The full plan: per-request outcomes plus node-level accounting.
#[derive(Debug, Clone)]
pub struct RoutePlan {
    pub planned: Vec<PlannedRequest>,
    /// Modeled run span: last admitted finish minus first arrival.
    pub span_s: f64,
    /// Modeled compute seconds per card (SLS segments included).
    pub busy_s: Vec<f64>,
}

/// Mutable planner state over the node.
struct NodeState {
    compute_busy: Vec<f64>,
    link: LinkOccupancy,
    /// Outstanding segment finish times per card, nondecreasing (compute
    /// on a card is serialized, so each new finish is the card's largest).
    outstanding: Vec<VecDeque<f64>>,
    busy_s: Vec<f64>,
}

impl NodeState {
    fn new(cards: usize) -> NodeState {
        NodeState {
            compute_busy: vec![0.0; cards],
            link: LinkOccupancy::new(cards),
            outstanding: vec![VecDeque::new(); cards],
            busy_s: vec![0.0; cards],
        }
    }

    /// Drop segments finished by `t` (arrivals are nondecreasing, so a
    /// front-prune is exact).
    fn prune(&mut self, t: f64) {
        for q in &mut self.outstanding {
            while q.front().is_some_and(|&f| f <= t) {
                q.pop_front();
            }
        }
    }

    fn depth(&self, card: usize) -> usize {
        self.outstanding[card].len()
    }

    /// Earliest a fresh segment on `card` could start.
    fn ready(&self, card: usize, t: f64) -> f64 {
        self.compute_busy[card].max(self.link.busy_until(card)).max(t)
    }

    /// Commit one segment: transfer serializes on the card's link, compute
    /// on the card. Returns the segment's finish time.
    fn commit(&mut self, card: usize, ready_s: f64, cost: crate::runtime::ModeledCost) -> f64 {
        let delivered = self.link.occupy(card, ready_s, cost.transfer_s);
        let start = delivered.max(self.compute_busy[card]);
        let finish = start + cost.compute_s;
        self.compute_busy[card] = finish;
        self.outstanding[card].push_back(finish);
        self.busy_s[card] += cost.compute_s;
        finish
    }
}

/// One node's routing state, reusable a request at a time.
///
/// [`plan`] drives it over a whole stream; the cluster tier
/// ([`crate::serving::cluster`]) instead holds one planner per node and
/// feeds each request to whichever node its node-level policy picked, so
/// the per-node serve logic exists exactly once.
pub struct NodePlanner {
    state: NodeState,
    rr: [usize; 3],
}

impl NodePlanner {
    pub fn new(cards: usize) -> NodePlanner {
        NodePlanner { state: NodeState::new(cards), rr: [0; 3] }
    }

    /// Drop segments finished by `t` (callers must feed nondecreasing
    /// times — arrivals, or NIC delivery times, which inherit the order).
    pub fn prune(&mut self, t: f64) {
        self.state.prune(t);
    }

    /// Outstanding segments across all cards — the node-level
    /// join-shortest-queue signal.
    pub fn outstanding(&self) -> usize {
        self.state.outstanding.iter().map(VecDeque::len).sum()
    }

    /// Modeled compute seconds accumulated per card.
    pub fn busy_s(&self) -> &[f64] {
        &self.state.busy_s
    }

    /// Forget all state (a failed node sheds its in-flight work; what
    /// replaces it starts cold). Accumulated busy time is cleared too —
    /// snapshot it first if the caller wants to attribute the lost work.
    pub fn reset(&mut self) {
        let cards = self.state.busy_s.len();
        *self = NodePlanner::new(cards);
    }

    /// Route one request that becomes available to this node at `t`
    /// (its arrival, or the time its bytes cleared the node's NIC).
    /// Returns `None` when admission control sheds it. Identical to one
    /// step of [`plan`].
    pub fn route_one(
        &mut self,
        replicas: &ReplicaManager,
        req: &FleetRequest,
        t: f64,
        policy: RoutePolicy,
        cfg: &FleetConfig,
    ) -> Option<Routed> {
        let NodePlanner { state, rr } = self;
        state.prune(t);
        let family = req.family();
        match req {
            FleetRequest::Recsys { .. } => {
                // candidate-independent SLS-stage estimate (slowest shard
                // card, each priced with its current compute/link backlog)
                // — hoisted so the per-candidate score is one lookup, not
                // a shard scan per replica
                let sls_done_est = replicas
                    .sls
                    .iter()
                    .map(|s| state.ready(s.card, t) + s.cost.total_s())
                    .fold(t, f64::max);
                let ri = choose(policy, &mut rr[family.index()], replicas.recsys.len(), |i| {
                    let r = &replicas.recsys[i];
                    (r.card, state.ready(r.card, sls_done_est) + r.cost.total_s())
                }, state);
                let r = &replicas.recsys[ri];
                admit(state, r.card, replicas.recsys_request_cost_s(ri), cfg).then(|| {
                    let mut sls_done = t;
                    for shard in &replicas.sls {
                        let fin = state.commit(shard.card, t, shard.cost);
                        sls_done = sls_done.max(fin);
                    }
                    let finish = state.commit(r.card, sls_done, r.cost);
                    Routed {
                        decision: Decision::Recsys { replica: ri },
                        card: r.card,
                        latency_s: finish - t,
                        finish_s: finish,
                    }
                })
            }
            FleetRequest::Nlp { req, .. } => {
                match replicas.nlp_bucket_for(req.tokens.len()) {
                    // longer than every compiled bucket: shed at admission
                    None => None,
                    Some(bucket) => {
                        // a replica without a net for this bucket projects
                        // at infinity (never chosen while an alternative
                        // exists) and sheds rather than being priced with
                        // a placeholder
                        let ri =
                            choose(policy, &mut rr[family.index()], replicas.nlp.len(), |i| {
                                let r = &replicas.nlp[i];
                                let c = r
                                    .cost(bucket)
                                    .map(|c| c.total_s())
                                    .unwrap_or(f64::INFINITY);
                                (r.card, state.ready(r.card, t) + c)
                            }, state);
                        let r = &replicas.nlp[ri];
                        r.cost(bucket).and_then(|cost| {
                            admit(state, r.card, cost.total_s(), cfg).then(|| {
                                let finish = state.commit(r.card, t, cost);
                                Routed {
                                    decision: Decision::Nlp { replica: ri, bucket },
                                    card: r.card,
                                    latency_s: finish - t,
                                    finish_s: finish,
                                }
                            })
                        })
                    }
                }
            }
            FleetRequest::Cv { .. } => {
                let ri = choose(policy, &mut rr[family.index()], replicas.cv.len(), |i| {
                    let r = &replicas.cv[i];
                    (r.card, state.ready(r.card, t) + r.cost.total_s())
                }, state);
                let r = &replicas.cv[ri];
                admit(state, r.card, r.cost.total_s(), cfg).then(|| {
                    let finish = state.commit(r.card, t, r.cost);
                    Routed {
                        decision: Decision::Cv { replica: ri },
                        card: r.card,
                        latency_s: finish - t,
                        finish_s: finish,
                    }
                })
            }
        }
    }
}

/// Shared precondition checks for planning over a replica set.
pub fn validate(replicas: &ReplicaManager, cfg: &FleetConfig) -> Result<()> {
    if replicas.recsys.is_empty() || replicas.nlp.is_empty() || replicas.cv.is_empty() {
        bail!("fleet replica set must cover every family");
    }
    if cfg.max_queue == 0 {
        bail!("fleet max_queue must be >= 1");
    }
    Ok(())
}

/// Plan the routing of `reqs` (nondecreasing arrival order) over the
/// replica set.
pub fn plan(
    replicas: &ReplicaManager,
    reqs: &[FleetRequest],
    policy: RoutePolicy,
    cfg: &FleetConfig,
) -> Result<RoutePlan> {
    validate(replicas, cfg)?;
    let mut planner = NodePlanner::new(replicas.cards);
    let mut planned = Vec::with_capacity(reqs.len());
    let mut last_arrival = f64::NEG_INFINITY;
    let mut max_finish: Option<f64> = None;
    for req in reqs {
        let t = req.arrival_s();
        if t < last_arrival {
            bail!(
                "fleet requests must arrive in nondecreasing order \
                 ({t} after {last_arrival})"
            );
        }
        last_arrival = t;
        let route = planner.route_one(replicas, req, t, policy, cfg);
        if let Some(r) = &route {
            max_finish = Some(max_finish.map_or(r.finish_s, |m: f64| m.max(r.finish_s)));
        }
        planned.push(PlannedRequest { family: req.family(), arrival_s: t, items: req.items(), route });
    }
    let span_s = match (reqs.first(), max_finish) {
        (Some(first), Some(finish)) => (finish - first.arrival_s()).max(0.0),
        _ => 0.0,
    };
    Ok(RoutePlan { planned, span_s, busy_s: planner.state.busy_s.clone() })
}

/// Pick a replica index among `n` candidates. `score(i)` returns the
/// candidate's (primary card, projected completion time). Every policy
/// breaks ties toward the lowest index, so the choice is deterministic.
fn choose<F: Fn(usize) -> (usize, f64)>(
    policy: RoutePolicy,
    rr: &mut usize,
    n: usize,
    score: F,
    state: &NodeState,
) -> usize {
    match policy {
        RoutePolicy::RoundRobin => {
            let i = *rr % n;
            *rr += 1;
            i
        }
        RoutePolicy::LeastOutstanding => {
            let mut best = 0usize;
            let mut best_depth = usize::MAX;
            for i in 0..n {
                let (card, _) = score(i);
                let d = state.depth(card);
                if d < best_depth {
                    best = i;
                    best_depth = d;
                }
            }
            best
        }
        RoutePolicy::LatencyAware => {
            // projection first; exact projection ties (common for recsys,
            // whose finish is gated by the shared SLS stage) break toward
            // the card with the smallest compute backlog, so tied replicas
            // still spread instead of piling onto the first card
            let mut best = 0usize;
            let mut best_key = (f64::INFINITY, f64::INFINITY);
            for i in 0..n {
                let (card, proj) = score(i);
                let key = (proj, state.compute_busy[card]);
                if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                    best = i;
                    best_key = key;
                }
            }
            best
        }
    }
}

/// Admission: bounded queue on the primary card, then the SLA rule — shed
/// when (queue depth + 1) × modeled request cost exceeds the budget.
fn admit(state: &NodeState, card: usize, request_cost_s: f64, cfg: &FleetConfig) -> bool {
    let depth = state.depth(card);
    if depth >= cfg.max_queue {
        return false;
    }
    match cfg.sla_budget_s {
        Some(budget) => (depth + 1) as f64 * request_cost_s <= budget,
        None => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModeledCost;

    #[test]
    fn policy_parse_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("lo").unwrap(), RoutePolicy::LeastOutstanding);
        assert_eq!(RoutePolicy::parse("la").unwrap(), RoutePolicy::LatencyAware);
        assert!(RoutePolicy::parse("random").is_err());
    }

    #[test]
    fn node_state_serializes_compute_and_prunes() {
        let mut s = NodeState::new(2);
        let c = ModeledCost { compute_s: 1.0, transfer_s: 0.5, dram_occupancy: 1.0 };
        let f1 = s.commit(0, 0.0, c);
        assert!((f1 - 1.5).abs() < 1e-12);
        // second segment on the same card: transfer waits for the first
        // transfer (0.5..1.0), compute for the first compute (ends 1.5)
        let f2 = s.commit(0, 0.0, c);
        assert!((f2 - 2.5).abs() < 1e-12, "{f2}");
        assert_eq!(s.depth(0), 2);
        // the other card is untouched
        assert_eq!(s.depth(1), 0);
        assert!((s.busy_s[0] - 2.0).abs() < 1e-12);
        s.prune(1.6);
        assert_eq!(s.depth(0), 1);
        s.prune(3.0);
        assert_eq!(s.depth(0), 0);
    }

    #[test]
    fn admission_rules() {
        let mut s = NodeState::new(1);
        let cfg = FleetConfig { max_queue: 2, sla_budget_s: Some(1.0), ..FleetConfig::default() };
        // empty card, cheap request: admitted
        assert!(admit(&s, 0, 0.4, &cfg));
        // cost alone exceeding the budget: shed even on an empty card
        assert!(!admit(&s, 0, 1.5, &cfg));
        s.commit(0, 0.0, ModeledCost { compute_s: 1.0, transfer_s: 0.0, dram_occupancy: 1.0 });
        // depth 1: (1+1) * 0.6 > 1.0 -> shed
        assert!(!admit(&s, 0, 0.6, &cfg));
        assert!(admit(&s, 0, 0.4, &cfg));
        s.commit(0, 0.0, ModeledCost { compute_s: 1.0, transfer_s: 0.0, dram_occupancy: 1.0 });
        // bounded queue full
        assert!(!admit(&s, 0, 1e-6, &cfg));
    }
}
