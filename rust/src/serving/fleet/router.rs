//! The fleet router: dispatch a mixed request stream to replicas.
//!
//! Routing runs on the discrete-event core ([`crate::sim::des`]): every
//! request arrival, segment completion and policy timer is an event on the
//! seeded heap, popped in modeled-time order. Each card serializes its
//! compute segments; each card's PCIe link serializes its transfer segments
//! ([`LinkOccupancy`] — two requests landing on one card contend for the
//! same x4 link). A DLRM request first fans its SLS segments out to the
//! shard cards (the stage costs the slowest one, Fig. 6 left) and then runs
//! the dense partition on its replica's card; NLP and CV requests are
//! single segments.
//!
//! Admission control sheds a request when its primary card's bounded queue
//! is full, or — with an SLA budget configured — when queue depth × modeled
//! cost would blow the budget (the request could not finish in time anyway,
//! so shedding it early is strictly better than serving it late).
//!
//! Because the simulator's only state is modeled costs, arrival times and
//! the seeded heap, the resulting metrics are bit-deterministic across runs
//! and across worker counts on the modeled clock; the worker pool only
//! executes numerics.
//!
//! The event clock also unlocks *reactive* policies the old arrival-ordered
//! planning pass could not express: with [`FleetConfig::dynamic_batch`]
//! set, a queued NLP/CV request opens a growth window until its modeled
//! start, and later same-shape requests under queue pressure merge into it
//! at a marginal cost instead of queueing their full solo cost
//! (queue-depth-triggered dynamic batch growth, §IV-C).

use crate::obs::{RequestTrace, SegKind, SegRecord, StageBreakdown, Tracer};
use crate::runtime::ModeledCost;
use crate::serving::fleet::replica::ReplicaManager;
use crate::serving::fleet::{DynamicBatch, Family, FleetConfig, FleetRequest};
use crate::sim::des::{class, EventHeap, EventId};
use crate::sim::transfer::LinkOccupancy;
use crate::util::error::{bail, Result};
use std::collections::VecDeque;

/// Why admission control (or bucket coverage) shed a request. Named causes
/// keep availability drills distinguishable: a full bounded queue means the
/// node is saturated, an SLA shed means the request could not have finished
/// in budget anyway, and a missing bucket means no compiled net covers the
/// request's shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedCause {
    /// The primary card's bounded queue was full.
    QueueFull,
    /// (queue depth + 1) × modeled cost exceeded the SLA budget.
    SlaBudget,
    /// No compiled bucket/net covers the request's shape.
    NoBucket,
}

impl ShedCause {
    pub fn name(self) -> &'static str {
        match self {
            ShedCause::QueueFull => "shed-queue-full",
            ShedCause::SlaBudget => "shed-sla",
            ShedCause::NoBucket => "shed-no-bucket",
        }
    }
}

/// Per-cause shed counters; the tiers' conservation checks gate on the sum.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShedCounts {
    pub queue_full: usize,
    pub sla: usize,
    pub no_bucket: usize,
}

impl ShedCounts {
    pub fn count(&mut self, cause: ShedCause) {
        match cause {
            ShedCause::QueueFull => self.queue_full += 1,
            ShedCause::SlaBudget => self.sla += 1,
            ShedCause::NoBucket => self.no_bucket += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.queue_full + self.sla + self.no_bucket
    }

    pub fn merge(&mut self, other: &ShedCounts) {
        self.queue_full += other.queue_full;
        self.sla += other.sla;
        self.no_bucket += other.no_bucket;
    }
}

/// Dispatch policy for choosing among a family's replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Per-family rotation, blind to load — the naive baseline.
    RoundRobin,
    /// Fewest outstanding segments on the candidate's primary card.
    LeastOutstanding,
    /// Smallest projected completion time, priced with the sim backend's
    /// modeled per-run costs and the link occupancy accumulator. Degrades
    /// to queue balancing on wall-clock backends (uniform placeholder
    /// costs).
    LatencyAware,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::LatencyAware];

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastOutstanding => "least-outstanding",
            RoutePolicy::LatencyAware => "latency-aware",
        }
    }

    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "round-robin" | "rr" => RoutePolicy::RoundRobin,
            "least-outstanding" | "lo" => RoutePolicy::LeastOutstanding,
            "latency-aware" | "la" => RoutePolicy::LatencyAware,
            other => bail!(
                "unknown routing policy '{other}' \
                 (valid: round-robin, least-outstanding, latency-aware)"
            ),
        })
    }
}

/// Where an admitted request went.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Recsys { replica: usize },
    Nlp { replica: usize, bucket: usize },
    Cv { replica: usize },
}

/// An admitted request's routing outcome on the simulator's clock.
#[derive(Debug, Clone, Copy)]
pub struct Routed {
    pub decision: Decision,
    /// Primary card (dense card for recsys) — metrics attribution.
    pub card: usize,
    pub latency_s: f64,
    pub finish_s: f64,
    /// Stage decomposition of `latency_s` on the critical path (queue is
    /// the residual, so the components sum to the latency exactly).
    pub stage: StageBreakdown,
}

/// One planned request: family/arrival always, route only when admitted.
#[derive(Debug, Clone)]
pub struct PlannedRequest {
    pub family: Family,
    pub arrival_s: f64,
    pub items: usize,
    pub route: Option<Routed>,
    /// Why the request was shed, when `route` is `None`.
    pub shed_cause: Option<ShedCause>,
}

/// The full plan: per-request outcomes plus node-level accounting.
#[derive(Debug, Clone)]
pub struct RoutePlan {
    pub planned: Vec<PlannedRequest>,
    /// Modeled run span: last admitted finish minus first arrival.
    pub span_s: f64,
    /// Modeled compute seconds per card (SLS segments included).
    pub busy_s: Vec<f64>,
    /// Per-cause shed accounting (sums to the number of unrouted requests).
    pub shed: ShedCounts,
}

/// Handle to a dynamic-batch growth window a routed request opened. The
/// driver must schedule a [`class::TIMER`] event at `start_s` and call
/// [`NodePlanner::close_batch`] when it fires — once the batch has started
/// on the card, nothing can join it.
#[derive(Debug, Clone, Copy)]
pub struct BatchTicket {
    pub card: usize,
    pub gen: u64,
    pub start_s: f64,
}

/// The outcome of one simulation step for one request.
pub enum RouteStep {
    /// Admission control (or bucket coverage) shed the request.
    Shed(ShedCause),
    /// Routed as its own service segment. `opened` is the growth window to
    /// arm a close timer for, when dynamic batching applies.
    Routed { routed: Routed, opened: Option<BatchTicket> },
    /// Merged into an open batch window: `members` are the indices of the
    /// earlier requests in the batch, whose completion events must be
    /// rescheduled to the (shared, later) `routed.finish_s`.
    Merged { routed: Routed, members: Vec<usize> },
}

/// A committed service segment on a card's timeline.
#[derive(Debug, Clone, Copy)]
struct Seg {
    /// When the PCIe transfer started on the card's link.
    xfer_start_s: f64,
    /// When the link delivered the inputs (compute cannot start earlier).
    delivered_s: f64,
    start_s: f64,
    finish_s: f64,
}

/// What an open growth window batches over: same family, same replica,
/// same compiled shape (bucket; 0 for CV) — members must share one net.
#[derive(Debug, Clone, Copy, PartialEq)]
struct BatchKey {
    family: Family,
    replica: usize,
    bucket: usize,
}

/// An open dynamic-batch growth window on one card: the head request has
/// committed but not started, and same-key requests may merge until
/// `start_s` (or until the member cap / link headroom runs out).
struct OpenBatch {
    gen: u64,
    key: BatchKey,
    start_s: f64,
    /// The head request's solo compute cost — each member added on top
    /// costs `marginal × solo`.
    solo_compute_s: f64,
    /// Request indices in the batch, head first.
    members: Vec<usize>,
}

/// Mutable planner state over the node.
struct NodeState {
    compute_busy: Vec<f64>,
    link: LinkOccupancy,
    /// Outstanding segment finish times per card, nondecreasing (compute
    /// on a card is serialized, so each new finish is the card's largest).
    outstanding: Vec<VecDeque<f64>>,
    busy_s: Vec<f64>,
}

impl NodeState {
    fn new(cards: usize) -> NodeState {
        NodeState {
            compute_busy: vec![0.0; cards],
            link: LinkOccupancy::new(cards),
            outstanding: vec![VecDeque::new(); cards],
            busy_s: vec![0.0; cards],
        }
    }

    /// Drop segments finished by `t` (the simulator clock is monotone, so
    /// a front-prune is exact).
    fn prune(&mut self, t: f64) {
        for q in &mut self.outstanding {
            while q.front().is_some_and(|&f| f <= t) {
                q.pop_front();
            }
        }
    }

    fn depth(&self, card: usize) -> usize {
        self.outstanding[card].len()
    }

    /// Earliest a fresh segment on `card` could start.
    fn ready(&self, card: usize, t: f64) -> f64 {
        self.compute_busy[card].max(self.link.busy_until(card)).max(t)
    }

    /// Commit one segment: transfer serializes on the card's link, compute
    /// on the card. Returns the segment's start and finish times.
    fn commit(&mut self, card: usize, ready_s: f64, cost: ModeledCost) -> Seg {
        let xfer_start = self.link.busy_until(card).max(ready_s);
        let delivered = self.link.occupy(card, ready_s, cost.transfer_s);
        let start = delivered.max(self.compute_busy[card]);
        let finish = start + cost.compute_s;
        self.compute_busy[card] = finish;
        self.outstanding[card].push_back(finish);
        self.busy_s[card] += cost.compute_s;
        Seg { xfer_start_s: xfer_start, delivered_s: delivered, start_s: start, finish_s: finish }
    }
}

/// One node's routing state, driven an event at a time.
///
/// [`plan`] drives it over a whole stream on its own event heap; the
/// cluster tier ([`crate::serving::cluster`]) instead holds one planner per
/// node, feeds each request to whichever node its node-level policy picked,
/// and relays completion/timer events from its own heap — so the per-node
/// serve logic exists exactly once.
pub struct NodePlanner {
    state: NodeState,
    rr: [usize; 3],
    /// Open dynamic-batch growth window per card.
    open: Vec<Option<OpenBatch>>,
    /// Window generation counter — survives [`NodePlanner::reset`] so a
    /// stale close timer can never close a post-reset window.
    next_gen: u64,
    /// Occupancy tape ([`crate::obs`]): `None` (the default) records
    /// nothing and allocates nothing — an empty `Vec` is never even
    /// constructed on the planning path, so untraced runs are untouched.
    tape: Option<Vec<SegRecord>>,
}

impl NodePlanner {
    pub fn new(cards: usize) -> NodePlanner {
        NodePlanner {
            state: NodeState::new(cards),
            rr: [0; 3],
            open: (0..cards).map(|_| None).collect(),
            next_gen: 0,
            tape: None,
        }
    }

    /// Start recording PCIe-link and card-compute occupancy segments. The
    /// tape survives [`NodePlanner::reset`] so work a failed node already
    /// did stays visible in the timelines.
    pub fn enable_tape(&mut self) {
        if self.tape.is_none() {
            self.tape = Some(Vec::new());
        }
    }

    /// Drain the recorded occupancy segments (empty when tracing was off).
    /// Recording stays enabled if it was.
    pub fn take_tape(&mut self) -> Vec<SegRecord> {
        match self.tape.as_mut() {
            Some(tape) => std::mem::take(tape),
            None => Vec::new(),
        }
    }

    /// Record one committed segment's link and compute occupancy. A no-op
    /// (two `Copy` comparisons, no allocation) while the tape is disabled.
    fn record_seg(&mut self, card: usize, seg: Seg, cost: ModeledCost, req: usize) {
        if let Some(tape) = self.tape.as_mut() {
            if cost.transfer_s > 0.0 {
                tape.push(SegRecord {
                    kind: SegKind::Link,
                    node: 0,
                    lane: card,
                    start_s: seg.xfer_start_s,
                    end_s: seg.delivered_s,
                    req,
                    dram: 0.0,
                });
            }
            if cost.compute_s > 0.0 {
                tape.push(SegRecord {
                    kind: SegKind::Compute,
                    node: 0,
                    lane: card,
                    start_s: seg.start_s,
                    end_s: seg.finish_s,
                    req,
                    dram: cost.dram_occupancy,
                });
            }
        }
    }

    /// Drop segments finished by `t` — the completion-event handler
    /// (callers feed nondecreasing times; the event heap guarantees it).
    pub fn prune(&mut self, t: f64) {
        self.state.prune(t);
    }

    /// Outstanding segments across all cards — the node-level
    /// join-shortest-queue signal.
    pub fn outstanding(&self) -> usize {
        self.state.outstanding.iter().map(VecDeque::len).sum()
    }

    /// Modeled compute seconds accumulated per card.
    pub fn busy_s(&self) -> &[f64] {
        &self.state.busy_s
    }

    /// Forget all state (a failed node sheds its in-flight work; what
    /// replaces it starts cold). Accumulated busy time is cleared too —
    /// snapshot it first if the caller wants to attribute the lost work.
    pub fn reset(&mut self) {
        let cards = self.state.busy_s.len();
        let gen = self.next_gen;
        let tape = self.tape.take();
        *self = NodePlanner::new(cards);
        self.next_gen = gen;
        self.tape = tape;
    }

    /// Close a growth window when its batch starts (the [`BatchTicket`]
    /// timer firing). A stale generation is a no-op: the window was
    /// already superseded.
    pub fn close_batch(&mut self, card: usize, gen: u64) {
        if self.open[card].as_ref().is_some_and(|b| b.gen == gen) {
            self.open[card] = None;
        }
    }

    /// Simulate one request that becomes available to this node at `t`
    /// (its arrival, or the time its bytes cleared the node's NIC). `idx`
    /// is the request's index in the driver's stream, used to label batch
    /// members. One arrival-event step of [`plan`].
    pub fn step(
        &mut self,
        replicas: &ReplicaManager,
        req: &FleetRequest,
        idx: usize,
        t: f64,
        policy: RoutePolicy,
        cfg: &FleetConfig,
    ) -> RouteStep {
        self.state.prune(t);
        let family = req.family();
        match req {
            FleetRequest::Recsys { .. } => {
                let ri = {
                    let NodePlanner { state, rr, .. } = self;
                    // candidate-independent SLS-stage estimate (slowest
                    // shard card, each priced with its current compute/link
                    // backlog) — hoisted so the per-candidate score is one
                    // lookup, not a shard scan per replica
                    let sls_done_est = replicas
                        .sls
                        .iter()
                        .map(|s| state.ready(s.card, t) + s.cost.total_s())
                        .fold(t, f64::max);
                    choose(policy, &mut rr[family.index()], replicas.recsys.len(), |i| {
                        let r = &replicas.recsys[i];
                        (r.card, state.ready(r.card, sls_done_est) + r.cost.total_s())
                    }, state)
                };
                let r = &replicas.recsys[ri];
                if let Some(cause) = admit(&self.state, r.card, replicas.recsys_request_cost_s(ri), cfg) {
                    return RouteStep::Shed(cause);
                }
                // recsys never joins a growth window (its SLS fan-out is
                // multi-card); committing plainly also closes any window on
                // the cards it touches, keeping their timelines exact.
                // The stage decomposition follows the critical path: the
                // slowest shard's transfer+compute, then the dense segment's.
                let mut sls_done = t;
                let (mut crit_transfer, mut crit_compute) = (0.0f64, 0.0f64);
                for shard in &replicas.sls {
                    let seg = self.commit_plain(idx, shard.card, t, shard.cost);
                    if seg.finish_s > sls_done {
                        sls_done = seg.finish_s;
                        crit_transfer = shard.cost.transfer_s;
                        crit_compute = shard.cost.compute_s;
                    }
                }
                let seg = self.commit_plain(idx, r.card, sls_done, r.cost);
                let latency_s = seg.finish_s - t;
                RouteStep::Routed {
                    routed: Routed {
                        decision: Decision::Recsys { replica: ri },
                        card: r.card,
                        latency_s,
                        finish_s: seg.finish_s,
                        stage: StageBreakdown::attribute(
                            latency_s,
                            0.0,
                            crit_transfer + r.cost.transfer_s,
                            crit_compute + r.cost.compute_s,
                            0.0,
                        ),
                    },
                    opened: None,
                }
            }
            FleetRequest::Nlp { req, .. } => {
                // longer than every compiled bucket: shed at admission
                let Some(bucket) = replicas.nlp_bucket_for(req.tokens.len()) else {
                    return RouteStep::Shed(ShedCause::NoBucket);
                };
                let ri = {
                    let NodePlanner { state, rr, .. } = self;
                    // a replica without a net for this bucket projects at
                    // infinity (never chosen while an alternative exists)
                    // and sheds rather than being priced with a placeholder
                    choose(policy, &mut rr[family.index()], replicas.nlp.len(), |i| {
                        let r = &replicas.nlp[i];
                        let c = r.cost(bucket).map(|c| c.total_s()).unwrap_or(f64::INFINITY);
                        (r.card, state.ready(r.card, t) + c)
                    }, state)
                };
                let r = &replicas.nlp[ri];
                let Some(cost) = r.cost(bucket) else {
                    return RouteStep::Shed(ShedCause::NoBucket);
                };
                if let Some(cause) = admit(&self.state, r.card, cost.total_s(), cfg) {
                    return RouteStep::Shed(cause);
                }
                self.finish_single(
                    idx,
                    t,
                    r.card,
                    cost,
                    Decision::Nlp { replica: ri, bucket },
                    BatchKey { family, replica: ri, bucket },
                    cfg,
                )
            }
            FleetRequest::Cv { .. } => {
                let ri = {
                    let NodePlanner { state, rr, .. } = self;
                    choose(policy, &mut rr[family.index()], replicas.cv.len(), |i| {
                        let r = &replicas.cv[i];
                        (r.card, state.ready(r.card, t) + r.cost.total_s())
                    }, state)
                };
                let r = &replicas.cv[ri];
                if let Some(cause) = admit(&self.state, r.card, r.cost.total_s(), cfg) {
                    return RouteStep::Shed(cause);
                }
                self.finish_single(
                    idx,
                    t,
                    r.card,
                    r.cost,
                    Decision::Cv { replica: ri },
                    BatchKey { family, replica: ri, bucket: 0 },
                    cfg,
                )
            }
        }
    }

    /// Route a single-segment (NLP/CV) request: merge into an open batch
    /// window when dynamic batching allows, otherwise commit a fresh
    /// segment (possibly opening a window of its own).
    fn finish_single(
        &mut self,
        idx: usize,
        t: f64,
        card: usize,
        cost: ModeledCost,
        decision: Decision,
        key: BatchKey,
        cfg: &FleetConfig,
    ) -> RouteStep {
        if let Some(dynb) = cfg.dynamic_batch {
            if let Some((routed, members)) = self.try_merge(idx, t, card, key, cost, decision, dynb)
            {
                return RouteStep::Merged { routed, members };
            }
        }
        let (seg, opened) = self.commit_open(idx, t, card, t, cost, key, cfg);
        let latency_s = seg.finish_s - t;
        RouteStep::Routed {
            routed: Routed {
                decision,
                card,
                latency_s,
                finish_s: seg.finish_s,
                // the residual (link backlog + compute backlog) is queueing
                stage: StageBreakdown::attribute(
                    latency_s,
                    0.0,
                    cost.transfer_s,
                    cost.compute_s,
                    0.0,
                ),
            },
            opened,
        }
    }

    /// Commit a segment and close any window on the card (its timeline
    /// just changed). Used for recsys stages, which never batch.
    fn commit_plain(&mut self, idx: usize, card: usize, ready_s: f64, cost: ModeledCost) -> Seg {
        self.open[card] = None;
        let seg = self.state.commit(card, ready_s, cost);
        self.record_seg(card, seg, cost, idx);
        seg
    }

    /// Commit a segment; when dynamic batching is on and the segment has
    /// to queue (`start > now`), open a growth window until its start.
    fn commit_open(
        &mut self,
        idx: usize,
        now_s: f64,
        card: usize,
        ready_s: f64,
        cost: ModeledCost,
        key: BatchKey,
        cfg: &FleetConfig,
    ) -> (Seg, Option<BatchTicket>) {
        self.open[card] = None;
        let seg = self.state.commit(card, ready_s, cost);
        self.record_seg(card, seg, cost, idx);
        let opened = match cfg.dynamic_batch {
            Some(_) if seg.start_s > now_s => {
                let gen = self.next_gen;
                self.next_gen += 1;
                self.open[card] = Some(OpenBatch {
                    gen,
                    key,
                    start_s: seg.start_s,
                    solo_compute_s: cost.compute_s,
                    members: vec![idx],
                });
                Some(BatchTicket { card, gen, start_s: seg.start_s })
            }
            _ => None,
        };
        (seg, opened)
    }

    /// Try to merge request `idx` into the card's open growth window.
    /// Requires queue pressure (`depth >= depth_hi`), a matching batch key,
    /// member headroom, and enough link headroom to deliver the joiner's
    /// bytes before the batch starts. On success the whole batch finishes
    /// together at the new (marginally later) finish, and the earlier
    /// members' outstanding segments are retro-extended to it.
    fn try_merge(
        &mut self,
        idx: usize,
        t: f64,
        card: usize,
        key: BatchKey,
        cost: ModeledCost,
        decision: Decision,
        dynb: DynamicBatch,
    ) -> Option<(Routed, Vec<usize>)> {
        let (start_s, solo, n_old) = match &self.open[card] {
            Some(b) if b.key == key && b.members.len() < dynb.max_batch && t < b.start_s => {
                (b.start_s, b.solo_compute_s, b.members.len())
            }
            _ => return None,
        };
        // the reactive trigger: only grow when the card is backed up
        if self.state.depth(card) < dynb.depth_hi {
            return None;
        }
        // the joiner's activations must clear the PCIe link before the
        // batch starts, or growing it would delay the whole batch
        let xfer_start = self.state.link.busy_until(card).max(t);
        if xfer_start + cost.transfer_s > start_s {
            return None;
        }
        let delivered = self.state.link.occupy(card, t, cost.transfer_s);
        let old_finish = self.state.compute_busy[card];
        let new_finish = start_s + solo * (1.0 + dynb.marginal * n_old as f64);
        self.state.compute_busy[card] = new_finish;
        self.state.busy_s[card] += dynb.marginal * solo;
        if self.tape.is_some() {
            // the joiner's transfer, plus the batch compute growing from
            // the superseded finish to the shared one
            let seg = Seg {
                xfer_start_s: xfer_start,
                delivered_s: delivered,
                start_s: old_finish,
                finish_s: new_finish,
            };
            self.record_seg(
                card,
                seg,
                ModeledCost {
                    compute_s: new_finish - old_finish,
                    transfer_s: cost.transfer_s,
                    dram_occupancy: cost.dram_occupancy,
                },
                idx,
            );
        }
        // retro-extend the existing members' segments to the shared finish
        // (they are the card's newest entries; the queue stays nondecreasing
        // because new_finish exceeds the previous batch finish)
        for v in self.state.outstanding[card].iter_mut().rev().take(n_old) {
            *v = new_finish;
        }
        self.state.outstanding[card].push_back(new_finish);
        let b = self.open[card].as_mut().expect("window checked above");
        let members = b.members.clone();
        b.members.push(idx);
        let latency_s = new_finish - t;
        // the merge precondition guarantees t + transfer <= start_s, so
        // the batch-wait term is non-negative and the residual is zero
        let stage = StageBreakdown::attribute(
            latency_s,
            start_s - t - cost.transfer_s,
            cost.transfer_s,
            new_finish - start_s,
            0.0,
        );
        Some((Routed { decision, card, latency_s, finish_s: new_finish, stage }, members))
    }
}

/// Shared precondition checks for planning over a replica set.
pub fn validate(replicas: &ReplicaManager, cfg: &FleetConfig) -> Result<()> {
    if replicas.recsys.is_empty() || replicas.nlp.is_empty() || replicas.cv.is_empty() {
        bail!("fleet replica set must cover every family");
    }
    if cfg.max_queue == 0 {
        bail!("fleet max_queue must be >= 1");
    }
    Ok(())
}

/// Node-tier event payloads.
enum Ev {
    /// Request `i` arrives at the node.
    Arrive(usize),
    /// Request `i`'s service segment completes.
    Complete(usize),
    /// A dynamic-batch growth window's batch starts.
    CloseBatch { card: usize, gen: u64 },
}

/// Simulate the routing of `reqs` over the replica set on a seeded event
/// heap ([`FleetConfig::des_seed`]): arrivals, completions and batch-window
/// timers pop in modeled-time order, with seeded tie-breaks at equal
/// instants — bit-deterministic for a given seed and trace.
pub fn plan(
    replicas: &ReplicaManager,
    reqs: &[FleetRequest],
    policy: RoutePolicy,
    cfg: &FleetConfig,
) -> Result<RoutePlan> {
    plan_traced(replicas, reqs, policy, cfg, None)
}

/// [`plan`] with an optional tracing sink. `None` is the zero-cost path:
/// no tape, no request traces, bit-identical outcomes and allocations to
/// a tracerless run. `Some` additionally records occupancy segments and
/// per-request lifecycle spans — the routing arithmetic and event-heap
/// schedule are untouched either way.
pub fn plan_traced(
    replicas: &ReplicaManager,
    reqs: &[FleetRequest],
    policy: RoutePolicy,
    cfg: &FleetConfig,
    tracer: Option<&mut Tracer>,
) -> Result<RoutePlan> {
    validate(replicas, cfg)?;
    let mut planner = NodePlanner::new(replicas.cards);
    if tracer.is_some() {
        planner.enable_tape();
    }
    let mut heap: EventHeap<Ev> = EventHeap::new(cfg.des_seed);
    let mut planned: Vec<PlannedRequest> = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        let t = req.arrival_s();
        if !t.is_finite() {
            bail!("fleet request {i} has a non-finite arrival time {t}");
        }
        planned.push(PlannedRequest {
            family: req.family(),
            arrival_s: t,
            items: req.items(),
            route: None,
            shed_cause: None,
        });
        heap.push(t, Ev::Arrive(i));
    }
    let mut shed = ShedCounts::default();
    let mut complete_ev: Vec<Option<EventId>> = vec![None; reqs.len()];
    while let Some(e) = heap.pop() {
        let t = e.at_s;
        match e.kind {
            Ev::Arrive(i) => match planner.step(replicas, &reqs[i], i, t, policy, cfg) {
                RouteStep::Shed(cause) => {
                    planned[i].shed_cause = Some(cause);
                    shed.count(cause);
                }
                RouteStep::Routed { routed, opened } => {
                    complete_ev[i] = Some(heap.push_class(
                        routed.finish_s,
                        class::COMPLETION,
                        Ev::Complete(i),
                    ));
                    planned[i].route = Some(routed);
                    if let Some(tk) = opened {
                        heap.push_class(
                            tk.start_s,
                            class::TIMER,
                            Ev::CloseBatch { card: tk.card, gen: tk.gen },
                        );
                    }
                }
                RouteStep::Merged { routed, members } => {
                    // the batch grew: every member finishes together at the
                    // new (later) finish — supersede their completions
                    for m in members {
                        if let Some(id) = complete_ev[m].take() {
                            heap.cancel(id);
                        }
                        complete_ev[m] = Some(heap.push_class(
                            routed.finish_s,
                            class::COMPLETION,
                            Ev::Complete(m),
                        ));
                        if let Some(r) = planned[m].route.as_mut() {
                            // the batch grew under this member: the extra
                            // time is compute (the batch runs longer), so the
                            // member's stage sums keep matching its latency
                            r.stage.compute_s += routed.finish_s - r.finish_s;
                            r.finish_s = routed.finish_s;
                            r.latency_s = routed.finish_s - planned[m].arrival_s;
                        }
                    }
                    complete_ev[i] = Some(heap.push_class(
                        routed.finish_s,
                        class::COMPLETION,
                        Ev::Complete(i),
                    ));
                    planned[i].route = Some(routed);
                }
            },
            Ev::Complete(i) => {
                complete_ev[i] = None;
                planner.prune(t);
            }
            Ev::CloseBatch { card, gen } => planner.close_batch(card, gen),
        }
    }
    let first_arrival = planned.iter().map(|p| p.arrival_s).fold(f64::INFINITY, f64::min);
    let max_finish = planned
        .iter()
        .filter_map(|p| p.route.as_ref().map(|r| r.finish_s))
        .fold(f64::NEG_INFINITY, f64::max);
    let span_s = if first_arrival.is_finite() && max_finish.is_finite() {
        (max_finish - first_arrival).max(0.0)
    } else {
        0.0
    };
    if let Some(tr) = tracer {
        tr.extend_segs(0, planner.take_tape());
        for (i, p) in planned.iter().enumerate() {
            tr.request(match (&p.route, p.shed_cause) {
                (Some(r), _) => RequestTrace {
                    req: i,
                    family: p.family.name(),
                    node: 0,
                    card: r.card,
                    arrival_s: p.arrival_s,
                    finish_s: r.finish_s,
                    stage: r.stage,
                    outcome: "completed",
                },
                (None, cause) => RequestTrace {
                    req: i,
                    family: p.family.name(),
                    node: 0,
                    card: 0,
                    arrival_s: p.arrival_s,
                    finish_s: p.arrival_s,
                    stage: StageBreakdown::default(),
                    outcome: cause.map(ShedCause::name).unwrap_or("shed"),
                },
            });
        }
    }
    Ok(RoutePlan { planned, span_s, busy_s: planner.busy_s().to_vec(), shed })
}

/// Pick a replica index among `n` candidates. `score(i)` returns the
/// candidate's (primary card, projected completion time). Every policy
/// breaks ties toward the lowest index, so the choice is deterministic.
fn choose<F: Fn(usize) -> (usize, f64)>(
    policy: RoutePolicy,
    rr: &mut usize,
    n: usize,
    score: F,
    state: &NodeState,
) -> usize {
    match policy {
        RoutePolicy::RoundRobin => {
            let i = *rr % n;
            *rr += 1;
            i
        }
        RoutePolicy::LeastOutstanding => {
            let mut best = 0usize;
            let mut best_depth = usize::MAX;
            for i in 0..n {
                let (card, _) = score(i);
                let d = state.depth(card);
                if d < best_depth {
                    best = i;
                    best_depth = d;
                }
            }
            best
        }
        RoutePolicy::LatencyAware => {
            // projection first; exact projection ties (common for recsys,
            // whose finish is gated by the shared SLS stage) break toward
            // the card with the smallest compute backlog, so tied replicas
            // still spread instead of piling onto the first card
            let mut best = 0usize;
            let mut best_key = (f64::INFINITY, f64::INFINITY);
            for i in 0..n {
                let (card, proj) = score(i);
                let key = (proj, state.compute_busy[card]);
                if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                    best = i;
                    best_key = key;
                }
            }
            best
        }
    }
}

/// Admission: bounded queue on the primary card, then the SLA rule — shed
/// when (queue depth + 1) × modeled request cost exceeds the budget.
/// Returns the shed cause, or `None` when the request is admitted.
fn admit(state: &NodeState, card: usize, request_cost_s: f64, cfg: &FleetConfig) -> Option<ShedCause> {
    let depth = state.depth(card);
    if depth >= cfg.max_queue {
        return Some(ShedCause::QueueFull);
    }
    match cfg.sla_budget_s {
        Some(budget) if (depth + 1) as f64 * request_cost_s > budget => {
            Some(ShedCause::SlaBudget)
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModeledCost;

    #[test]
    fn policy_parse_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("lo").unwrap(), RoutePolicy::LeastOutstanding);
        assert_eq!(RoutePolicy::parse("la").unwrap(), RoutePolicy::LatencyAware);
        assert!(RoutePolicy::parse("random").is_err());
    }

    #[test]
    fn node_state_serializes_compute_and_prunes() {
        let mut s = NodeState::new(2);
        let c = ModeledCost { compute_s: 1.0, transfer_s: 0.5, dram_occupancy: 1.0 };
        let f1 = s.commit(0, 0.0, c).finish_s;
        assert!((f1 - 1.5).abs() < 1e-12);
        // second segment on the same card: transfer waits for the first
        // transfer (0.5..1.0), compute for the first compute (ends 1.5)
        let f2 = s.commit(0, 0.0, c).finish_s;
        assert!((f2 - 2.5).abs() < 1e-12, "{f2}");
        assert_eq!(s.depth(0), 2);
        // the other card is untouched
        assert_eq!(s.depth(1), 0);
        assert!((s.busy_s[0] - 2.0).abs() < 1e-12);
        s.prune(1.6);
        assert_eq!(s.depth(0), 1);
        s.prune(3.0);
        assert_eq!(s.depth(0), 0);
    }

    #[test]
    fn admission_rules() {
        let mut s = NodeState::new(1);
        let cfg = FleetConfig { max_queue: 2, sla_budget_s: Some(1.0), ..FleetConfig::default() };
        // empty card, cheap request: admitted
        assert_eq!(admit(&s, 0, 0.4, &cfg), None);
        // cost alone exceeding the budget: shed even on an empty card
        assert_eq!(admit(&s, 0, 1.5, &cfg), Some(ShedCause::SlaBudget));
        s.commit(0, 0.0, ModeledCost { compute_s: 1.0, transfer_s: 0.0, dram_occupancy: 1.0 });
        // depth 1: (1+1) * 0.6 > 1.0 -> shed
        assert_eq!(admit(&s, 0, 0.6, &cfg), Some(ShedCause::SlaBudget));
        assert_eq!(admit(&s, 0, 0.4, &cfg), None);
        s.commit(0, 0.0, ModeledCost { compute_s: 1.0, transfer_s: 0.0, dram_occupancy: 1.0 });
        // bounded queue full
        assert_eq!(admit(&s, 0, 1e-6, &cfg), Some(ShedCause::QueueFull));
    }

    #[test]
    fn shed_counts_sum_and_merge() {
        let mut a = ShedCounts::default();
        a.count(ShedCause::QueueFull);
        a.count(ShedCause::SlaBudget);
        a.count(ShedCause::SlaBudget);
        let mut b = ShedCounts::default();
        b.count(ShedCause::NoBucket);
        a.merge(&b);
        assert_eq!(a.queue_full, 1);
        assert_eq!(a.sla, 2);
        assert_eq!(a.no_bucket, 1);
        assert_eq!(a.total(), 4);
        for c in [ShedCause::QueueFull, ShedCause::SlaBudget, ShedCause::NoBucket] {
            assert!(c.name().starts_with("shed-"));
        }
    }

    #[test]
    fn dynamic_batch_window_merges_and_retro_extends() {
        let dynb = DynamicBatch { depth_hi: 1, max_batch: 4, marginal: 0.5 };
        let cfg = FleetConfig { dynamic_batch: Some(dynb), ..FleetConfig::default() };
        let key = BatchKey { family: Family::Nlp, replica: 0, bucket: 0 };
        let cost = ModeledCost { compute_s: 1.0, transfer_s: 0.0, dram_occupancy: 1.0 };
        let decision = Decision::Nlp { replica: 0, bucket: 0 };
        let mut p = NodePlanner::new(1);
        // first request starts immediately: nothing to grow, no window
        let (seg0, opened0) = p.commit_open(0, 0.0, 0, 0.0, cost, key, &cfg);
        assert!((seg0.finish_s - 1.0).abs() < 1e-12);
        assert!(opened0.is_none());
        // second queues behind it: a growth window opens until its start
        let (seg1, opened1) = p.commit_open(1, 0.0, 0, 0.0, cost, key, &cfg);
        assert!((seg1.start_s - 1.0).abs() < 1e-12);
        let ticket = opened1.expect("queued request must open a window");
        assert_eq!(ticket.card, 0);
        assert!((ticket.start_s - 1.0).abs() < 1e-12);
        // a third request at t=0.5 merges: batch of 2 costs 1.5x solo, and
        // both members finish together at 1.0 + 1.5 = 2.5
        let (routed, members) = p
            .try_merge(2, 0.5, 0, key, cost, decision, dynb)
            .expect("merge under queue pressure");
        assert_eq!(members, vec![1]);
        assert!((routed.finish_s - 2.5).abs() < 1e-12, "{}", routed.finish_s);
        assert!((routed.latency_s - 2.0).abs() < 1e-12);
        // the joiner's stage decomposition covers its whole latency:
        // batch-wait until the batch starts, then the grown compute
        assert!((routed.stage.total_s() - routed.latency_s).abs() < 1e-12);
        assert!((routed.stage.batch_wait_s - 0.5).abs() < 1e-12);
        assert!((routed.stage.compute_s - 1.5).abs() < 1e-12);
        // after the window closes (batch started), nothing can join
        p.close_batch(0, ticket.gen);
        assert!(p.try_merge(3, 0.6, 0, key, cost, decision, dynb).is_none());
    }
}
