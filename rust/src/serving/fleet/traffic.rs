//! Deterministic mixed-traffic generator.
//!
//! The node serves all three families at once (§II); this generator
//! replaces the single-family loops the individual servers use with one
//! seeded stream: each request draws its family from a configurable mix
//! (e.g. 70/20/10 recsys/nlp/cv) and its payload from the family's
//! workload generator, stamped with a burst or Poisson arrival time.
//! Everything derives from [`crate::util::rng::Rng`], so two generators
//! with the same seed and knobs emit bit-identical streams — the property
//! the fleet's policy comparisons and determinism tests stand on.

use crate::runtime::artifact::Manifest;
use crate::serving::fleet::{Family, FleetRequest};
use crate::util::error::{bail, Result};
use crate::util::rng::Rng;
use crate::workloads::{CvGen, NlpGen, RecsysGen};

/// Relative family weights (any nonnegative scale; normalized on use).
#[derive(Debug, Clone, Copy)]
pub struct FamilyMix {
    pub recsys: f64,
    pub nlp: f64,
    pub cv: f64,
}

impl FamilyMix {
    pub fn new(recsys: f64, nlp: f64, cv: f64) -> Result<FamilyMix> {
        let m = FamilyMix { recsys, nlp, cv };
        if !(recsys >= 0.0 && nlp >= 0.0 && cv >= 0.0) {
            bail!("family mix weights must be nonnegative");
        }
        if m.total() <= 0.0 {
            bail!("family mix must have at least one positive weight");
        }
        Ok(m)
    }

    /// Parse "70/20/10" (recsys/nlp/cv).
    pub fn parse(s: &str) -> Result<FamilyMix> {
        let parts: Vec<&str> = s.split('/').collect();
        if parts.len() != 3 {
            bail!("mix must be recsys/nlp/cv, e.g. 70/20/10 (got '{s}')");
        }
        let mut w = [0.0f64; 3];
        for (i, p) in parts.iter().enumerate() {
            w[i] = p
                .trim()
                .parse::<f64>()
                .map_err(|_| crate::err!("mix component '{p}' is not a number"))?;
        }
        FamilyMix::new(w[0], w[1], w[2])
    }

    fn total(&self) -> f64 {
        self.recsys + self.nlp + self.cv
    }

    /// Normalized share of one family.
    pub fn share(&self, f: Family) -> f64 {
        let w = match f {
            Family::Recsys => self.recsys,
            Family::Nlp => self.nlp,
            Family::Cv => self.cv,
        };
        w / self.total()
    }

    /// The canonical "70/20/10" label.
    pub fn label(&self) -> String {
        format!("{:.0}/{:.0}/{:.0}", self.recsys, self.nlp, self.cv)
    }
}

impl Default for FamilyMix {
    /// The smoke mix: recsys-dominated like the paper's fleet (Fig. 1a).
    fn default() -> FamilyMix {
        FamilyMix { recsys: 70.0, nlp: 20.0, cv: 10.0 }
    }
}

/// When requests arrive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Everything available at t=0 — the closed-loop saturation shape the
    /// policy comparisons use (throughput is service-limited, not
    /// arrival-limited).
    Burst,
    /// Open-loop Poisson arrivals at `rate_qps`.
    Poisson { rate_qps: f64 },
}

/// The mixed-stream generator.
pub struct TrafficGen {
    mix: FamilyMix,
    arrival: Arrival,
    rng: Rng,
    recsys: RecsysGen,
    nlp: NlpGen,
    cv: CvGen,
    clock: f64,
    next_id: usize,
}

impl TrafficGen {
    /// Build from a manifest's model shapes. `recsys_batch` must match a
    /// compiled DLRM variant (the fleet validates this again at replica
    /// load).
    pub fn new(
        seed: u64,
        mix: FamilyMix,
        arrival: Arrival,
        manifest: &Manifest,
        recsys_batch: usize,
    ) -> Result<TrafficGen> {
        if let Arrival::Poisson { rate_qps } = arrival {
            if rate_qps <= 0.0 {
                bail!("poisson arrival rate must be positive (got {rate_qps})");
            }
        }
        // independent per-family streams forked off the master seed, so the
        // family-choice sequence does not disturb the payloads
        let mut master = Rng::new(seed);
        let recsys_seed = master.next_u64();
        let nlp_seed = master.next_u64();
        let cv_seed = master.next_u64();
        let vocab = manifest.config_usize("xlmr", "vocab")?;
        let max_seq = manifest
            .select("xlmr", "full")
            .into_iter()
            .filter_map(|a| a.seq)
            .max()
            .unwrap_or(128);
        let image = manifest.config_usize("cv", "image")?;
        Ok(TrafficGen {
            mix,
            arrival,
            rng: master,
            recsys: RecsysGen::from_manifest(recsys_seed, recsys_batch, manifest)?,
            // the NlpGen arrival clock is unused here (TrafficGen stamps
            // arrivals itself); rate 1.0 is a placeholder
            nlp: NlpGen::new(nlp_seed, vocab, max_seq, 1.0),
            cv: CvGen::new(cv_seed, image),
            clock: 0.0,
            next_id: 0,
        })
    }

    /// Requests emitted so far.
    pub fn emitted(&self) -> usize {
        self.next_id
    }

    pub fn next(&mut self) -> FleetRequest {
        let arrival_s = match self.arrival {
            Arrival::Burst => 0.0,
            Arrival::Poisson { rate_qps } => {
                self.clock += self.rng.exponential(rate_qps);
                self.clock
            }
        };
        let u = self.rng.f64() * self.mix.total();
        self.next_id += 1;
        if u < self.mix.recsys {
            FleetRequest::Recsys { arrival_s, req: self.recsys.next() }
        } else if u < self.mix.recsys + self.mix.nlp {
            FleetRequest::Nlp { arrival_s, req: self.nlp.next() }
        } else {
            FleetRequest::Cv { arrival_s, req: self.cv.next(1) }
        }
    }

    /// The next `n` requests (arrival order).
    pub fn take(&mut self, n: usize) -> Vec<FleetRequest> {
        (0..n).map(|_| self.next()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin::builtin_manifest;

    #[test]
    fn mix_parse_and_shares() {
        let m = FamilyMix::parse("70/20/10").unwrap();
        assert!((m.share(Family::Recsys) - 0.7).abs() < 1e-12);
        assert!((m.share(Family::Nlp) - 0.2).abs() < 1e-12);
        assert!((m.share(Family::Cv) - 0.1).abs() < 1e-12);
        assert_eq!(m.label(), "70/20/10");
        // weights need not sum to 100
        let m = FamilyMix::parse("1/1/2").unwrap();
        assert!((m.share(Family::Cv) - 0.5).abs() < 1e-12);
        assert!(FamilyMix::parse("70/20").is_err());
        assert!(FamilyMix::parse("a/b/c").is_err());
        assert!(FamilyMix::parse("0/0/0").is_err());
        assert!(FamilyMix::parse("-1/2/3").is_err());
    }

    #[test]
    fn stream_is_deterministic() {
        let m = builtin_manifest();
        let mix = FamilyMix::default();
        let mut a = TrafficGen::new(7, mix, Arrival::Poisson { rate_qps: 500.0 }, &m, 16).unwrap();
        let mut b = TrafficGen::new(7, mix, Arrival::Poisson { rate_qps: 500.0 }, &m, 16).unwrap();
        for _ in 0..40 {
            let (x, y) = (a.next(), b.next());
            assert_eq!(x.family(), y.family());
            assert_eq!(x.arrival_s(), y.arrival_s());
            assert_eq!(x.items(), y.items());
        }
    }

    #[test]
    fn mix_shares_and_arrivals_behave() {
        let m = builtin_manifest();
        let mix = FamilyMix::parse("70/20/10").unwrap();
        let mut g = TrafficGen::new(3, mix, Arrival::Burst, &m, 16).unwrap();
        let reqs = g.take(400);
        assert_eq!(g.emitted(), 400);
        let recsys = reqs.iter().filter(|r| r.family() == Family::Recsys).count();
        let nlp = reqs.iter().filter(|r| r.family() == Family::Nlp).count();
        let cv = reqs.iter().filter(|r| r.family() == Family::Cv).count();
        assert_eq!(recsys + nlp + cv, 400);
        // the empirical mix tracks the configured one
        assert!((recsys as f64 / 400.0 - 0.7).abs() < 0.08, "recsys {recsys}");
        assert!((nlp as f64 / 400.0 - 0.2).abs() < 0.08, "nlp {nlp}");
        // burst: everything at t=0
        assert!(reqs.iter().all(|r| r.arrival_s() == 0.0));
        // poisson: strictly increasing arrivals
        let mut g = TrafficGen::new(3, mix, Arrival::Poisson { rate_qps: 100.0 }, &m, 16).unwrap();
        let reqs = g.take(50);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s() > w[0].arrival_s());
        }
        // a recsys payload matches the requested batch
        let item_counts: Vec<usize> = reqs
            .iter()
            .filter(|r| r.family() == Family::Recsys)
            .map(|r| r.items())
            .collect();
        assert!(item_counts.iter().all(|&b| b == 16));
    }

    #[test]
    fn invalid_poisson_rate_rejected() {
        let m = builtin_manifest();
        assert!(TrafficGen::new(1, FamilyMix::default(), Arrival::Poisson { rate_qps: 0.0 }, &m, 16)
            .is_err());
    }
}
