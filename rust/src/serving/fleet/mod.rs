//! Fleet layer: multi-card request scheduling and replica placement (§IV,
//! §VI-B) plus sim-driven capacity planning (Fig. 1).
//!
//! The paper's node packs six low-power cards behind one host and serves a
//! *mix* of model families from it — recommendation, NLP and CV traffic
//! have wildly different per-request costs, so how requests are balanced
//! across the cards decides how much of the node's capacity a server
//! actually delivers. This module reproduces that layer on top of the
//! card-aware runtime:
//!
//! * [`replica`] — a replica manager that places N replicas of each model
//!   family onto cards through [`crate::runtime::Engine::prepare_on`],
//!   under a [`replica::Placement`] policy (`pack`, `spread`, and
//!   `sls-affine`, which keeps the DLRM SLS shards card-pinned exactly as
//!   [`crate::runtime::device::Node::place`] does today — Fig. 6 left);
//! * [`router`] — dispatches the mixed request stream to replicas under a
//!   [`router::RoutePolicy`] (round-robin, least-outstanding, or
//!   latency-aware over the sim backend's modeled per-run costs), with a
//!   bounded per-card queue and SLA admission control (shed when queue
//!   depth × modeled cost exceeds the budget). Transfer segments contend on
//!   a per-card [`crate::sim::transfer::LinkOccupancy`] accumulator, so two
//!   requests landing on one card serialize their PCIe traffic;
//! * [`traffic`] — a deterministic mixed-traffic generator
//!   ([`FleetRequest`] streams with a configurable family mix and arrival
//!   pattern), replacing the single-family loops the three servers use;
//! * [`plan`] — Fig. 1 capacity planning driven by the fleet's *measured*
//!   per-node QPS on the mixed trace instead of a single-model simulation.
//!
//! Metrics follow the engine's clock like everywhere else in [`crate::serving`]:
//! on [`Clock::Modeled`] (`--backend sim`) every latency, span and
//! utilization figure is computed from the deterministic routing plan — the
//! numbers are bit-identical across runs and across worker counts — while
//! the worker pool still executes every admitted request's real numerics.

pub mod plan;
pub mod replica;
pub mod router;
pub mod traffic;

pub use replica::{Placement, ReplicaManager};
pub use router::{
    BatchTicket, Decision, NodePlanner, RoutePlan, RoutePolicy, RouteStep, ShedCause, ShedCounts,
};
pub use traffic::{Arrival, FamilyMix, TrafficGen};

use crate::graph::models::ModelId;
use crate::obs::{StageStats, Tracer};
use crate::runtime::{Clock, Engine};
use crate::serving::ServerMetrics;
use crate::util::error::{bail, err, Result};
use crate::util::stats::Histogram;
use crate::util::threadpool::ThreadPool;
use crate::workloads::{CvRequest, NlpRequest, RecsysRequest};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// The three model families the node serves concurrently (§II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Recsys,
    Nlp,
    Cv,
}

impl Family {
    pub const ALL: [Family; 3] = [Family::Recsys, Family::Nlp, Family::Cv];

    pub fn name(self) -> &'static str {
        match self {
            Family::Recsys => "recsys",
            Family::Nlp => "nlp",
            Family::Cv => "cv",
        }
    }

    /// Stable index into per-family arrays (mix shares, round-robin
    /// cursors, metric accumulators).
    pub fn index(self) -> usize {
        match self {
            Family::Recsys => 0,
            Family::Nlp => 1,
            Family::Cv => 2,
        }
    }

    /// The Table I model this family's SLA derives from.
    pub fn model_id(self) -> ModelId {
        match self {
            Family::Recsys => ModelId::RecsysComplex,
            Family::Nlp => ModelId::XlmR,
            Family::Cv => ModelId::ResNeXt101,
        }
    }

    /// Table I latency budget for the family, seconds.
    pub fn latency_budget_s(self) -> f64 {
        self.model_id().latency_budget_s()
    }
}

/// One request of the mixed stream, stamped with its arrival time (the
/// router consumes streams in nondecreasing arrival order).
#[derive(Debug, Clone)]
pub enum FleetRequest {
    Recsys { arrival_s: f64, req: RecsysRequest },
    Nlp { arrival_s: f64, req: NlpRequest },
    Cv { arrival_s: f64, req: CvRequest },
}

impl FleetRequest {
    pub fn family(&self) -> Family {
        match self {
            FleetRequest::Recsys { .. } => Family::Recsys,
            FleetRequest::Nlp { .. } => Family::Nlp,
            FleetRequest::Cv { .. } => Family::Cv,
        }
    }

    pub fn arrival_s(&self) -> f64 {
        match self {
            FleetRequest::Recsys { arrival_s, .. }
            | FleetRequest::Nlp { arrival_s, .. }
            | FleetRequest::Cv { arrival_s, .. } => *arrival_s,
        }
    }

    /// Items this request carries (recsys: its batch rows; nlp: one
    /// sentence; cv: its image batch).
    pub fn items(&self) -> usize {
        match self {
            FleetRequest::Recsys { req, .. } => {
                req.dense.shape().first().copied().unwrap_or(1)
            }
            FleetRequest::Nlp { .. } => 1,
            FleetRequest::Cv { req, .. } => req.image.shape().first().copied().unwrap_or(1),
        }
    }
}

/// Queue-depth-triggered dynamic batch growth — the reactive policy the
/// event-heap core unlocks. A queued NLP/CV request opens a growth window
/// until its modeled start; while the card's queue depth is at least
/// `depth_hi`, later same-shape requests merge into the window at
/// `marginal` × the solo compute cost instead of queueing their full cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DynamicBatch {
    /// Minimum queue depth on the card before a merge is allowed (the
    /// queue-pressure trigger; below it requests serve solo for latency).
    pub depth_hi: usize,
    /// Cap on members per grown batch (compiled batch variants bound it).
    pub max_batch: usize,
    /// Marginal compute cost of each member beyond the first, as a
    /// fraction of the solo cost (batching amortizes weight traffic —
    /// §IV-C; 1.0 would mean batching wins nothing).
    pub marginal: f64,
}

impl Default for DynamicBatch {
    fn default() -> DynamicBatch {
        DynamicBatch { depth_hi: 2, max_batch: 4, marginal: 0.55 }
    }
}

/// Fleet-wide knobs: how many replicas to place, where, and when to shed.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Replicas per family (recsys dense partitions, NLP nets, CV nets).
    /// The DLRM SLS shards are shared by every recsys replica.
    pub replicas: usize,
    pub placement: Placement,
    /// DLRM serving batch (must match a compiled sls/dense variant).
    pub recsys_batch: usize,
    /// DLRM dense precision ("int8" | "fp32").
    pub recsys_precision: String,
    /// Bounded per-card queue: a request whose primary card already holds
    /// this many outstanding segments is shed.
    pub max_queue: usize,
    /// SLA admission control: shed when (queue depth + 1) × modeled request
    /// cost exceeds this budget. `None` disables the SLA check (the
    /// bounded queue still applies).
    pub sla_budget_s: Option<f64>,
    /// Seed for the event heap's same-instant tie-breaks
    /// ([`crate::sim::des::EventHeap`]). Runs sharing a seed and a trace
    /// are bit-identical.
    pub des_seed: u64,
    /// Dynamic batch growth; `None` (the default) routes every request as
    /// its own segment, exactly as the static planner did.
    pub dynamic_batch: Option<DynamicBatch>,
}

impl FleetConfig {
    /// Vet this fleet plan statically before any DES run: SLA budget vs
    /// the modeled per-family floor, NIC line rate vs the wire bytes
    /// `offered_qps` implies, and structural mistakes (zero replicas,
    /// zero queue bounds, batch windows that never open). Convenience
    /// wrapper over [`crate::analysis::lint_deployment`].
    pub fn lint(
        &self,
        cfg: &crate::config::Config,
        mix: FamilyMix,
        offered_qps: Option<f64>,
    ) -> Result<crate::analysis::Report> {
        crate::analysis::lint_deployment(
            cfg,
            &crate::analysis::DeploySpec { fleet: self, mix, offered_qps },
        )
    }
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            // four replicas per family on the six-card node straddle the
            // SLS-heavy and light cards, which is exactly where routing
            // policy starts to matter
            replicas: 4,
            placement: Placement::SlsAffine,
            recsys_batch: 16,
            recsys_precision: "int8".to_string(),
            max_queue: 1024,
            sla_budget_s: None,
            des_seed: 0xFB1A_0DE5,
            dynamic_batch: None,
        }
    }
}

/// Per-family slice of a fleet run.
#[derive(Debug, Clone)]
pub struct FamilyMetrics {
    pub family: Family,
    pub metrics: ServerMetrics,
    pub offered: usize,
    pub shed: usize,
}

/// Per-card slice of a fleet run. `busy_s` is the compute time the card
/// spent on this run's segments (modeled on the sim clock); requests are
/// attributed to their *primary* card (the dense card for recsys).
#[derive(Debug, Clone)]
pub struct CardMetrics {
    pub card: usize,
    pub metrics: ServerMetrics,
    pub busy_s: f64,
}

impl CardMetrics {
    /// Fraction of the run span the card's compute was occupied.
    pub fn utilization(&self, span_s: f64) -> f64 {
        if span_s <= 0.0 {
            0.0
        } else {
            (self.busy_s / span_s).min(1.0)
        }
    }
}

/// Everything a fleet run reports: node totals plus the per-family and
/// per-card breakdowns, and the shed accounting
/// (`node.completed + shed == offered` always holds).
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    pub policy: RoutePolicy,
    pub node: ServerMetrics,
    pub per_family: Vec<FamilyMetrics>,
    pub per_card: Vec<CardMetrics>,
    pub offered: usize,
    pub shed: usize,
    /// `shed` split by cause (`shed_causes.total() == shed` always holds).
    pub shed_causes: ShedCounts,
}

impl FleetMetrics {
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.offered.max(1) as f64
    }

    pub fn node_qps(&self) -> f64 {
        self.node.qps()
    }
}

/// The fleet: a replica set over the engine's cards plus routing knobs.
pub struct Fleet {
    engine: Arc<Engine>,
    replicas: ReplicaManager,
    cfg: FleetConfig,
}

impl Fleet {
    /// Place the replica set onto the engine's node per `cfg.placement`.
    pub fn new(engine: Arc<Engine>, cfg: FleetConfig) -> Result<Fleet> {
        let replicas = ReplicaManager::new(&engine, &cfg)?;
        Ok(Fleet { engine, replicas, cfg })
    }

    pub fn clock(&self) -> Clock {
        self.engine.clock()
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn replicas(&self) -> &ReplicaManager {
        &self.replicas
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Route the stream without executing any numerics — the sim-driven
    /// planning path (capacity sizing, policy sweeps). Requires the
    /// modeled clock: on a wall-clock backend there is nothing truthful to
    /// report without running the requests.
    pub fn route(&self, reqs: &[FleetRequest], policy: RoutePolicy) -> Result<FleetMetrics> {
        self.route_traced(reqs, policy, None)
    }

    /// [`Fleet::route`] with an optional tracing sink ([`crate::obs`]).
    /// `None` is bit-identical to [`Fleet::route`]; `Some` additionally
    /// records occupancy timelines and per-request spans.
    pub fn route_traced(
        &self,
        reqs: &[FleetRequest],
        policy: RoutePolicy,
        tracer: Option<&mut Tracer>,
    ) -> Result<FleetMetrics> {
        if self.engine.clock() != Clock::Modeled {
            bail!(
                "fleet route-only planning needs a modeled clock (--backend sim); \
                 use serve() on wall-clock backends"
            );
        }
        let plan = router::plan_traced(&self.replicas, reqs, policy, &self.cfg, tracer)?;
        let latencies: Vec<f64> = plan
            .planned
            .iter()
            .filter_map(|p| p.route.as_ref().map(|r| r.latency_s))
            .collect();
        Ok(self.assemble(&plan, &latencies, plan.span_s, &plan.busy_s, policy))
    }

    /// Serve the stream: plan the routing, then execute every admitted
    /// request's real numerics with `workers` in flight. On the modeled
    /// clock all metrics come from the plan (deterministic across runs and
    /// worker counts); on wall clocks they are measured around each
    /// request's execution.
    pub fn serve(
        self: &Arc<Self>,
        reqs: Vec<FleetRequest>,
        policy: RoutePolicy,
        workers: usize,
    ) -> Result<FleetMetrics> {
        let plan = router::plan(&self.replicas, &reqs, policy, &self.cfg)?;
        let (measured, measured_span) = self.execute(Arc::new(reqs), &plan, workers.max(1))?;
        match self.engine.clock() {
            Clock::Modeled => {
                let latencies: Vec<f64> = plan
                    .planned
                    .iter()
                    .filter_map(|p| p.route.as_ref().map(|r| r.latency_s))
                    .collect();
                Ok(self.assemble(&plan, &latencies, plan.span_s, &plan.busy_s, policy))
            }
            Clock::Wall => {
                // attribute measured time to each request's primary card
                let mut busy = vec![0.0f64; self.replicas.cards];
                let mut k = 0usize;
                for p in &plan.planned {
                    if let Some(r) = &p.route {
                        busy[r.card] += measured[k];
                        k += 1;
                    }
                }
                Ok(self.assemble(&plan, &measured, measured_span, &busy, policy))
            }
        }
    }

    /// Build the metric structure from per-admitted-request latencies (in
    /// plan order), the run span, and per-card busy time.
    fn assemble(
        &self,
        plan: &RoutePlan,
        latencies: &[f64],
        span_s: f64,
        busy_s: &[f64],
        policy: RoutePolicy,
    ) -> FleetMetrics {
        let clock = self.engine.clock();
        let cards = self.replicas.cards;
        let mk = || ServerMetrics {
            latency: Histogram::latency(),
            completed: 0,
            items: 0,
            wall_s: span_s,
            clock,
            stages: StageStats::default(),
            windows: None,
        };
        let mut node = mk();
        let mut families: Vec<FamilyMetrics> = Family::ALL
            .iter()
            .map(|&f| FamilyMetrics { family: f, metrics: mk(), offered: 0, shed: 0 })
            .collect();
        let mut per_card: Vec<CardMetrics> = (0..cards)
            .map(|c| CardMetrics { card: c, metrics: mk(), busy_s: busy_s[c] })
            .collect();
        let mut k = 0usize;
        for p in &plan.planned {
            let fam = &mut families[p.family.index()];
            fam.offered += 1;
            match &p.route {
                None => fam.shed += 1,
                Some(r) => {
                    let dt = latencies[k];
                    k += 1;
                    node.latency.add(dt);
                    node.completed += 1;
                    node.items += p.items;
                    node.stages.add(&r.stage);
                    fam.metrics.latency.add(dt);
                    fam.metrics.completed += 1;
                    fam.metrics.items += p.items;
                    fam.metrics.stages.add(&r.stage);
                    let card = &mut per_card[r.card];
                    card.metrics.latency.add(dt);
                    card.metrics.completed += 1;
                    card.metrics.items += p.items;
                    card.metrics.stages.add(&r.stage);
                }
            }
        }
        let offered = plan.planned.len();
        let shed = offered - node.completed;
        FleetMetrics {
            policy,
            node,
            per_family: families,
            per_card,
            offered,
            shed,
            shed_causes: plan.shed,
        }
    }

    /// Execute the admitted requests' numerics over a worker pool; returns
    /// the measured per-request seconds (in plan/admission order) and the
    /// wall span of the whole fan-out.
    fn execute(
        self: &Arc<Self>,
        reqs: Arc<Vec<FleetRequest>>,
        plan: &RoutePlan,
        workers: usize,
    ) -> Result<(Vec<f64>, f64)> {
        // (request index, decision) for every admitted request, plan order
        let admitted: Arc<Vec<(usize, Decision)>> = Arc::new(
            plan.planned
                .iter()
                .enumerate()
                .filter_map(|(i, p)| p.route.as_ref().map(|r| (i, r.decision)))
                .collect(),
        );
        let n = admitted.len();
        if n == 0 {
            return Ok((Vec::new(), 0.0));
        }
        let wall0 = Instant::now();
        let pool = ThreadPool::new(workers.min(n));
        let next = Arc::new(AtomicUsize::new(0));
        let failed = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Result<Vec<(usize, f64)>>>();
        for _ in 0..workers.min(n) {
            let me = Arc::clone(self);
            let reqs = Arc::clone(&reqs);
            let admitted = Arc::clone(&admitted);
            let next = Arc::clone(&next);
            let failed = Arc::clone(&failed);
            let tx = tx.clone();
            pool.execute(move || {
                let mut out = Vec::new();
                let res = loop {
                    if failed.load(Ordering::Relaxed) {
                        break Ok(());
                    }
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= n {
                        break Ok(());
                    }
                    let (i, decision) = admitted[k];
                    let t0 = Instant::now();
                    match me.execute_one(&reqs[i], decision) {
                        Ok(()) => out.push((k, t0.elapsed().as_secs_f64())),
                        Err(e) => {
                            failed.store(true, Ordering::Relaxed);
                            break Err(e);
                        }
                    }
                };
                let _ = tx.send(res.map(|()| out));
            });
        }
        drop(tx);
        let mut measured = vec![0.0f64; n];
        let mut seen = 0usize;
        let mut first_err = None;
        for res in rx.iter() {
            match res {
                Ok(chunk) => {
                    seen += chunk.len();
                    for (k, dt) in chunk {
                        measured[k] = dt;
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if seen != n {
            return Err(err!(
                "fleet worker exited without reporting ({seen} of {n} requests executed)"
            ));
        }
        Ok((measured, wall0.elapsed().as_secs_f64()))
    }

    /// Run one admitted request's numerics on its assigned replica — the
    /// per-node execution step the cluster tier reuses after its own
    /// two-tier planning pass.
    pub fn execute_one(&self, req: &FleetRequest, decision: Decision) -> Result<()> {
        match (req, decision) {
            (FleetRequest::Recsys { req, .. }, Decision::Recsys { replica }) => {
                self.replicas.run_recsys(replica, req).map(|_| ())
            }
            (FleetRequest::Nlp { req, .. }, Decision::Nlp { replica, bucket }) => {
                self.replicas.run_nlp(replica, bucket, req).map(|_| ())
            }
            (FleetRequest::Cv { req, .. }, Decision::Cv { replica }) => {
                self.replicas.run_cv(replica, req).map(|_| ())
            }
            (r, d) => Err(err!(
                "fleet plan routed a {} request with a mismatched decision {d:?}",
                r.family().name()
            )),
        }
    }
}
