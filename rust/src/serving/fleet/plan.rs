//! Sim-driven capacity planning (Fig. 1) over the fleet router.
//!
//! The paper's capacity story is "how many of these servers does a demand
//! curve require"; answering it honestly needs the throughput one node
//! actually delivers under the *mixed* production trace — replica
//! placement, routing policy and cross-request contention included — not a
//! single model's isolated simulation. This module measures exactly that:
//! route a deterministic mixed trace through the fleet on the modeled
//! clock, take the node's measured QPS, and feed it into the shared Fig. 1
//! series arithmetic ([`crate::capacity::series_from_qps`]).

use crate::capacity::{cpu_qps_per_server, series_from_qps, CapacityPoint, GrowthScenario};
use crate::config::Config;
use crate::serving::fleet::{Arrival, Family, FamilyMix, Fleet, RoutePolicy, TrafficGen};
use crate::util::error::{bail, Result};

/// Seed for the planning trace — fixed so capacity numbers are
/// reproducible run to run.
pub const PLAN_TRAFFIC_SEED: u64 = 0xF1EE_7001;

/// One fleet-measured capacity projection.
#[derive(Debug, Clone)]
pub struct FleetCapacityReport {
    pub mix: FamilyMix,
    pub policy: RoutePolicy,
    /// Measured node throughput on the mixed trace, **items**/sec — same
    /// unit as the CPU side and the original Fig. 1 arithmetic (a recsys
    /// request carries a whole batch of items).
    pub node_items_per_s: f64,
    /// Shed fraction of the measuring run (0 under the default admission
    /// knobs — a shedding node is not delivering its nominal capacity).
    pub shed_rate: f64,
    pub points: Vec<CapacityPoint>,
}

/// Measure one node's mixed-trace throughput through the fleet router and
/// project the Fig. 1 series from it. Takes a prebuilt [`Fleet`] so mix /
/// scenario sweeps pay replica placement once; requires a modeled-clock
/// engine (`--backend sim`). The trace is routed, not executed, so sweeps
/// stay cheap.
pub fn plan_capacity(
    fleet: &Fleet,
    mix: FamilyMix,
    policy: RoutePolicy,
    scenario: &GrowthScenario,
    cfg: &Config,
    requests: usize,
) -> Result<FleetCapacityReport> {
    let mut traffic = TrafficGen::new(
        PLAN_TRAFFIC_SEED,
        mix,
        Arrival::Burst,
        fleet.engine().manifest(),
        fleet.config().recsys_batch,
    )?;
    let reqs = traffic.take(requests.max(1));
    let metrics = fleet.route(&reqs, policy)?;
    // both sides of the series in items/s (the original Fig. 1 unit):
    // a fleet recsys request carries recsys_batch items, nlp/cv carry one
    let node_items_per_s = metrics.node.items_per_s();
    if !(node_items_per_s > 0.0) {
        bail!("fleet measured no node throughput ({} requests admitted)", metrics.node.completed);
    }
    let cpu = cpu_mixed_items_per_s(mix, cfg, fleet.config().recsys_batch);
    Ok(FleetCapacityReport {
        mix,
        policy,
        node_items_per_s,
        shed_rate: metrics.shed_rate(),
        points: series_from_qps(scenario, node_items_per_s, cpu),
    })
}

/// CPU-only per-server throughput on the same mix, **items**/sec: the
/// item-weighted harmonic mean of the per-family CPU rates. A mixed
/// request stream delivers `share_f × items_f` items per request drawn, at
/// `items_f / rate_f` seconds each family — so mixed items/s is total
/// items over total time. `recsys_items` is the recsys batch the fleet
/// trace carries per request (nlp/cv requests carry one item).
pub fn cpu_mixed_items_per_s(mix: FamilyMix, cfg: &Config, recsys_items: usize) -> f64 {
    let mut items_per_req = 0.0;
    let mut s_per_req = 0.0;
    for f in Family::ALL {
        let share = mix.share(f);
        if share <= 0.0 {
            continue;
        }
        let items = match f {
            Family::Recsys => recsys_items.max(1) as f64,
            Family::Nlp | Family::Cv => 1.0,
        };
        let rate = cpu_qps_per_server(f.model_id(), cfg);
        if rate > 0.0 {
            items_per_req += share * items;
            s_per_req += share * items / rate;
        }
    }
    if s_per_req > 0.0 {
        items_per_req / s_per_req
    } else {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_mixed_items_per_s_is_between_the_family_extremes() {
        let cfg = Config::default();
        let mix = FamilyMix::default();
        let mixed = cpu_mixed_items_per_s(mix, &cfg, 16);
        let each: Vec<f64> =
            Family::ALL.iter().map(|f| cpu_qps_per_server(f.model_id(), &cfg)).collect();
        let lo = each.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = each.iter().cloned().fold(0.0, f64::max);
        assert!(mixed >= lo && mixed <= hi, "mixed {mixed} outside [{lo}, {hi}]");
        // a pure-recsys mix degenerates to the recsys items/s, independent
        // of the per-request item count
        for items in [1, 16, 64] {
            let pure =
                cpu_mixed_items_per_s(FamilyMix::new(1.0, 0.0, 0.0).unwrap(), &cfg, items);
            let recsys = cpu_qps_per_server(Family::Recsys.model_id(), &cfg);
            assert!((pure - recsys).abs() / recsys < 1e-12, "items {items}");
        }
    }
}
