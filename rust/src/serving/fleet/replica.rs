//! Replica manager: place N replicas of each model family onto cards.
//!
//! The paper's node serves every family at once: the DLRM SLS shards are
//! model-parallel (one shard per card, Fig. 6 left) while dense partitions
//! and whole-model NLP/CV nets replicate data-parallel across cards
//! (§VI-B). [`ReplicaManager`] reproduces both axes through
//! [`crate::runtime::Engine::prepare_on`]: one shared SLS shard set, plus
//! `replicas` independently placed copies of the DLRM dense partition, the
//! XLM-R bucket nets and the CV trunk. Every prepared model carries its
//! modeled per-run cost split ([`ModeledCost`]) so the router can price
//! candidate placements; on wall-clock backends a uniform placeholder cost
//! keeps the planner functional (metrics are then measured, not modeled).

use crate::runtime::artifact::table_index;
use crate::runtime::{Clock, Engine, ModeledCost, Precision, PrepareOptions, PreparedModel};
use crate::numerics::weights::WeightGen;
use crate::numerics::HostTensor;
use crate::serving::batcher::{bucket_for, pad_batch, NlpBatch};
use crate::serving::fleet::FleetConfig;
use crate::serving::WEIGHT_SEED;
use crate::util::error::{bail, err, Context, Result};
use crate::workloads::{CvRequest, NlpRequest, RecsysRequest};
use std::sync::Arc;

/// Placeholder planning cost on wall-clock backends: uniform per run, so
/// the policies degrade to queue balancing (the honest thing to do without
/// a cost model).
const WALL_FALLBACK: ModeledCost =
    ModeledCost { compute_s: 1e-3, transfer_s: 0.0, dram_occupancy: 1.0 };

/// Where replicas land on the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Everything on card 0 — the degenerate baseline that shows why
    /// placement matters at all.
    Pack,
    /// One global round-robin over all cards, SLS shards included (shards
    /// lose their card affinity).
    Spread,
    /// SLS shard `k` stays pinned to card `k mod N` exactly like
    /// [`crate::runtime::device::Node::place`] (Fig. 6 left); everything
    /// else round-robins. The production default.
    SlsAffine,
}

impl Placement {
    pub const ALL: [Placement; 3] = [Placement::Pack, Placement::Spread, Placement::SlsAffine];

    pub fn name(self) -> &'static str {
        match self {
            Placement::Pack => "pack",
            Placement::Spread => "spread",
            Placement::SlsAffine => "sls-affine",
        }
    }

    pub fn parse(s: &str) -> Result<Placement> {
        Ok(match s {
            "pack" => Placement::Pack,
            "spread" => Placement::Spread,
            "sls-affine" | "affine" => Placement::SlsAffine,
            other => bail!(
                "unknown placement '{other}' (valid: pack, spread, sls-affine)"
            ),
        })
    }
}

/// One DLRM SLS shard, shared by all recsys replicas.
pub struct SlsShard {
    /// Global table ids this shard owns.
    pub tables: Vec<usize>,
    pub card: usize,
    pub cost: ModeledCost,
    model: Arc<PreparedModel>,
}

/// One DLRM dense-partition replica.
pub struct RecsysReplica {
    pub card: usize,
    pub cost: ModeledCost,
    model: Arc<PreparedModel>,
}

/// One XLM-R replica: every compiled batch-1 bucket net on one card.
pub struct NlpReplica {
    pub card: usize,
    /// (bucket, per-run cost, net), ascending by bucket.
    nets: Vec<(usize, ModeledCost, Arc<PreparedModel>)>,
}

impl NlpReplica {
    /// Cost of serving one sentence in `bucket` on this replica (the
    /// stored value is the modeled cost on modeled clocks, the uniform
    /// placeholder on wall clocks). `None` when the replica has no net for
    /// the bucket — the router treats that as unserviceable rather than
    /// silently pricing it with a placeholder.
    pub fn cost(&self, bucket: usize) -> Option<ModeledCost> {
        self.nets.iter().find(|(b, _, _)| *b == bucket).map(|(_, c, _)| *c)
    }
}

/// One CV trunk replica (batch 1).
pub struct CvReplica {
    pub card: usize,
    pub cost: ModeledCost,
    model: Arc<PreparedModel>,
}

/// The placed replica set.
pub struct ReplicaManager {
    pub placement: Placement,
    /// Cards on the node (replica `card` fields index this range).
    pub cards: usize,
    pub sls: Vec<SlsShard>,
    pub recsys: Vec<RecsysReplica>,
    pub nlp: Vec<NlpReplica>,
    pub cv: Vec<CvReplica>,
    /// Compiled NLP sequence buckets, ascending.
    pub buckets: Vec<usize>,
    pub recsys_batch: usize,
    num_tables: usize,
    embed_dim: usize,
    d_model: usize,
}

/// Deterministic placement cursor shared by every non-pinned replica.
struct Placer {
    placement: Placement,
    cards: usize,
    cursor: usize,
}

impl Placer {
    fn next(&mut self, shard: Option<usize>) -> usize {
        match (self.placement, shard) {
            (Placement::Pack, _) => 0,
            (Placement::SlsAffine, Some(k)) => k % self.cards,
            _ => {
                let c = self.cursor % self.cards;
                self.cursor += 1;
                c
            }
        }
    }
}

impl ReplicaManager {
    /// Load + place the full replica set for `cfg` on the engine's node.
    pub fn new(engine: &Arc<Engine>, cfg: &FleetConfig) -> Result<ReplicaManager> {
        if cfg.replicas == 0 {
            bail!("fleet needs at least one replica per family");
        }
        let cards = engine.device_count();
        let modeled = engine.clock() == Clock::Modeled;
        let mut placer = Placer { placement: cfg.placement, cards, cursor: 0 };
        let manifest = engine.manifest();
        let num_tables = manifest.config_usize("dlrm", "num_tables")?;
        let embed_dim = manifest.config_usize("dlrm", "embed_dim")?;
        let d_model = manifest.config_usize("xlmr", "d_model")?;

        // cost of a prepared model, with the wall-clock fallback; a modeled
        // clock without a cost is an invalid state, same guard as the servers
        let cost_of = |m: &PreparedModel| -> Result<ModeledCost> {
            match m.modeled_cost() {
                Some(c) => Ok(c),
                None if modeled => Err(err!(
                    "backend reports a modeled clock but {} has no modeled cost",
                    m.art.name
                )),
                None => Ok(WALL_FALLBACK),
            }
        };

        // recsys precision: "int8" selects the pre-quantized dense artifact
        // and quantizes the SLS tables row-wise at prepare, same as
        // RecsysServer
        let recsys_prec = Precision::parse(&cfg.recsys_precision)?;
        let recsys_opts = PrepareOptions { precision: recsys_prec };

        // --- DLRM SLS shards (shared, one per compiled shard) ------------
        let mut shard_arts: Vec<_> = manifest
            .select("dlrm", "sls")
            .into_iter()
            .filter(|a| a.batch == cfg.recsys_batch)
            .cloned()
            .collect();
        if shard_arts.is_empty() {
            bail!("no dlrm sls shards for batch {} in the manifest", cfg.recsys_batch);
        }
        shard_arts.sort_by_key(|a| a.shard.unwrap_or(usize::MAX));
        let mut sls = Vec::new();
        for art in shard_arts {
            let shard_idx = art
                .shard
                .ok_or_else(|| err!("sls artifact {} carries no shard index", art.name))?;
            let tables: Vec<usize> = art
                .inputs
                .iter()
                .filter(|s| s.name.starts_with("idx"))
                .map(|s| table_index(&s.name, "idx"))
                .collect::<Result<_>>()
                .with_context(|| format!("artifact {}", art.name))?;
            if tables.is_empty() {
                bail!("sls artifact {} declares no idx inputs", art.name);
            }
            // same load-time guard as RecsysServer::new: a shard naming a
            // table past the model's count must fail here, not panic in
            // run_recsys's per-table indexing
            if let Some(&t) = tables.iter().find(|&&t| t >= num_tables) {
                bail!(
                    "sls artifact {} references table {t} but configs.dlrm.num_tables is \
                     {num_tables}",
                    art.name
                );
            }
            let card = placer.next(Some(shard_idx));
            let weights = WeightGen::new(WEIGHT_SEED).weights_for(&art);
            let model = Arc::new(engine.prepare_on_with(art, weights, card, recsys_opts)?);
            let cost = cost_of(&model)?;
            sls.push(SlsShard { tables, card, cost, model });
        }

        // --- DLRM dense replicas -----------------------------------------
        let dense_suffix = match recsys_prec {
            Precision::F32 => "fp32",
            Precision::Int8 => "int8",
        };
        let dense_name = format!("dlrm_dense_b{}_{}", cfg.recsys_batch, dense_suffix);
        let dense_art = manifest.get(&dense_name)?.clone();
        let mut recsys = Vec::new();
        for _ in 0..cfg.replicas {
            let card = placer.next(None);
            let weights = WeightGen::new(WEIGHT_SEED).weights_for(&dense_art);
            let model =
                Arc::new(engine.prepare_on_with(dense_art.clone(), weights, card, recsys_opts)?);
            let cost = cost_of(&model)?;
            recsys.push(RecsysReplica { card, cost, model });
        }

        // --- XLM-R replicas (batch-1 bucket nets) ------------------------
        let mut nlp_arts: Vec<_> = manifest
            .select("xlmr", "full")
            .into_iter()
            .filter(|a| a.batch == 1)
            .cloned()
            .collect();
        if nlp_arts.is_empty() {
            bail!("no batch-1 xlmr artifacts in the manifest");
        }
        nlp_arts.sort_by_key(|a| a.seq.unwrap_or(usize::MAX));
        let mut buckets = Vec::new();
        for art in &nlp_arts {
            let seq = art.seq.ok_or_else(|| err!("xlmr artifact {} missing seq", art.name))?;
            if !buckets.contains(&seq) {
                buckets.push(seq);
            }
        }
        let mut nlp = Vec::new();
        for _ in 0..cfg.replicas {
            let card = placer.next(None);
            let mut nets = Vec::new();
            for art in &nlp_arts {
                let weights = WeightGen::new(WEIGHT_SEED).weights_for(art);
                let model = Arc::new(engine.prepare_on(art.clone(), weights, card)?);
                let cost = cost_of(&model)?;
                nets.push((art.seq.unwrap_or(0), cost, model));
            }
            nlp.push(NlpReplica { card, nets });
        }

        // --- CV replicas (batch 1) ---------------------------------------
        let cv_art = manifest
            .select("cv", "full")
            .into_iter()
            .find(|a| a.batch == 1)
            .cloned()
            .ok_or_else(|| err!("no batch-1 cv artifact in the manifest"))?;
        let mut cv = Vec::new();
        for _ in 0..cfg.replicas {
            let card = placer.next(None);
            let weights = WeightGen::new(WEIGHT_SEED).weights_for(&cv_art);
            let model = Arc::new(engine.prepare_on(cv_art.clone(), weights, card)?);
            let cost = cost_of(&model)?;
            cv.push(CvReplica { card, cost, model });
        }

        Ok(ReplicaManager {
            placement: cfg.placement,
            cards,
            sls,
            recsys,
            nlp,
            cv,
            buckets,
            recsys_batch: cfg.recsys_batch,
            num_tables,
            embed_dim,
            d_model,
        })
    }

    /// Modeled cost of one whole recsys request on dense replica `ri`: the
    /// SLS stage is the slowest shard (cards run concurrently, Fig. 6
    /// left), then the dense partition.
    pub fn recsys_request_cost_s(&self, ri: usize) -> f64 {
        let sls = self.sls.iter().map(|s| s.cost.total_s()).fold(0.0, f64::max);
        sls + self.recsys[ri].cost.total_s()
    }

    /// Smallest compiled bucket that fits a sentence of `len` tokens.
    pub fn nlp_bucket_for(&self, len: usize) -> Option<usize> {
        bucket_for(len, &self.buckets)
    }

    /// Full DLRM inference on dense replica `ri` (sequential shard walk —
    /// the fleet's parallelism is across requests, not within one). Shares
    /// the server path's marshalling/scatter helpers so the two request
    /// paths cannot diverge.
    pub fn run_recsys(&self, ri: usize, req: &RecsysRequest) -> Result<HostTensor> {
        crate::serving::check_recsys_table_arity(req, self.num_tables)?;
        let b = self.recsys_batch;
        let d = self.embed_dim;
        let mut sparse = vec![0f32; b * self.num_tables * d];
        for shard in &self.sls {
            let out = shard.model.run_refs(&crate::serving::sls_shard_inputs(req, &shard.tables))?;
            let pooled = out[0].as_f32().ok_or_else(|| err!("sls output not f32"))?;
            crate::serving::scatter_sls_shard(
                &mut sparse,
                pooled,
                &shard.tables,
                b,
                self.num_tables,
                d,
            );
        }
        let sparse = HostTensor::f32(sparse, &[b, self.num_tables, d]);
        let mut out = self.recsys[ri]
            .model
            .run_refs(&[&req.dense, &sparse])
            .context("dense partition")?;
        Ok(out.swap_remove(0))
    }

    /// One sentence through replica `ri`'s net for `bucket`; returns the
    /// pooled embedding.
    pub fn run_nlp(&self, ri: usize, bucket: usize, req: &NlpRequest) -> Result<Vec<f32>> {
        let replica = &self.nlp[ri];
        let net = replica
            .nets
            .iter()
            .find(|(b, _, _)| *b == bucket)
            .map(|(_, _, m)| m)
            .ok_or_else(|| err!("nlp replica {ri} has no net for bucket {bucket}"))?;
        let batch = NlpBatch { requests: vec![req.clone()], bucket };
        let (ids, lens) = pad_batch(&batch, 1);
        let out = net.run(&[
            HostTensor::i32(ids, &[1, bucket]),
            HostTensor::i32(lens, &[1]),
        ])?;
        let pooled = out[0].as_f32().ok_or_else(|| err!("pooled not f32"))?;
        Ok(pooled[..self.d_model].to_vec())
    }

    /// One image batch through CV replica `ri`; returns (logits, embedding).
    pub fn run_cv(&self, ri: usize, req: &CvRequest) -> Result<(HostTensor, HostTensor)> {
        let mut out = self.cv[ri].model.run_refs(&[&req.image])?;
        let emb = out.pop().ok_or_else(|| err!("cv output missing embedding"))?;
        let logits = out.pop().ok_or_else(|| err!("cv output missing logits"))?;
        Ok((logits, emb))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_parse_roundtrip() {
        for p in Placement::ALL {
            assert_eq!(Placement::parse(p.name()).unwrap(), p);
        }
        assert!(Placement::parse("best-fit").is_err());
    }

    #[test]
    fn placer_policies() {
        let mut pack = Placer { placement: Placement::Pack, cards: 6, cursor: 0 };
        assert_eq!(pack.next(Some(3)), 0);
        assert_eq!(pack.next(None), 0);

        let mut spread = Placer { placement: Placement::Spread, cards: 3, cursor: 0 };
        // one global cursor, shards included
        assert_eq!(spread.next(Some(5)), 0);
        assert_eq!(spread.next(None), 1);
        assert_eq!(spread.next(None), 2);
        assert_eq!(spread.next(None), 0);

        let mut affine = Placer { placement: Placement::SlsAffine, cards: 4, cursor: 0 };
        assert_eq!(affine.next(Some(2)), 2);
        assert_eq!(affine.next(Some(6)), 2); // wraps
        // the shard pins do not advance the round-robin cursor
        assert_eq!(affine.next(None), 0);
        assert_eq!(affine.next(None), 1);
    }
}
