//! Cluster tier: multi-node serving behind a datacenter router (Fig. 1,
//! §VII).
//!
//! The paper deploys accelerator nodes as a *fleet behind a routing tier*:
//! Fig. 1 sizes how many whole servers production traffic needs, and
//! §VII's operating lessons — imbalance, stragglers, capacity headroom —
//! are about many nodes, not one. This module is that tier on top of the
//! per-node fleet layer:
//!
//! * a [`Cluster`] holds N nodes, each a full [`Fleet`] (its own engine,
//!   replica set and card router) built from its own — possibly
//!   heterogeneous — [`NodeSpec`], so vendor-mix *tiers* compose with
//!   vendor-mix *cards*;
//! * requests ingress over each node's NIC: [`WireModel`] prices the
//!   request/response bytes (embedding index tensors in, fp16 outputs
//!   out) and a per-node [`crate::sim::transfer::NicOccupancy`] serializes
//!   them, so cluster throughput can become network-bound even while every
//!   card sits idle;
//! * the node router ([`router`]) picks a node per request
//!   (round-robin / join-shortest-queue / weighted-by-modeled-capacity)
//!   and composes with the existing per-node card router — two-tier
//!   dispatch through [`crate::serving::fleet::NodePlanner`];
//! * [`scenario`] injects node **drain** and **fail** events at trace
//!   timestamps: a failed node's in-flight work is shed, traffic
//!   re-routes, and the availability hit is recorded per node;
//! * [`plan`] extends the fleet's Fig. 1 arithmetic to datacenter scale:
//!   how many N-card nodes (plus failure headroom) carry Q QPS of a
//!   70/20/10 mix within the SLA — verified by simulating the
//!   single-node-failure scenario against the recommendation.
//!
//! Everything runs on the deterministic modeled clock: routing, NIC
//! serialization and scenario handling are a pure planning pass, so
//! metrics are bit-identical across runs and worker counts while the
//! worker pool still executes every admitted request's real numerics.

pub mod plan;
pub mod router;
pub mod scenario;

pub use router::{ClusterPlan, ClusterPlanned, NodePolicy, NodeReport, Outcome};
pub use scenario::{parse_events, EventKind, NodeEvent, Scenario};

use crate::config::{Config, TransferConfig};
use crate::obs::{StageStats, Tracer};
use crate::platform::NodeSpec;
use crate::runtime::artifact::Manifest;
use crate::runtime::{Clock, Engine, SimBackend};
use crate::serving::fleet::replica::ReplicaManager;
use crate::serving::fleet::{
    Family, FamilyMetrics, Fleet, FleetConfig, FleetRequest, RoutePolicy, ShedCounts,
};
use crate::serving::ServerMetrics;
use crate::util::error::{bail, err, Result};
use crate::util::stats::Histogram;
use crate::util::threadpool::ThreadPool;
use crate::workloads::AVG_LOOKUP_FRACTION;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Request/response wire sizes per family, priced once from the manifest
/// shapes and the §VI-C transfer flags (partial index tensors and fp16
/// dense features shrink the ingress exactly like they shrink the PCIe
/// upload — the bytes that cross the NIC are the same bytes that later
/// cross the switch).
#[derive(Debug, Clone)]
pub struct WireModel {
    /// One recsys request (the fleet's serving batch): per-table index
    /// prefixes + lengths + dense features.
    recsys_in: usize,
    /// fp16 score per item.
    recsys_out: usize,
    /// Pooled fp16 embedding.
    nlp_out: usize,
    /// fp16 pixels per image.
    cv_in_per_image: usize,
    /// fp16 logits per image.
    cv_out_per_image: usize,
}

impl WireModel {
    pub fn new(m: &Manifest, t: &TransferConfig, recsys_batch: usize) -> Result<WireModel> {
        let num_tables = m.config_usize("dlrm", "num_tables")?;
        let max_lookups = m.config_usize("dlrm", "max_lookups")?;
        let used = if t.partial_tensors {
            (((max_lookups as f64) * AVG_LOOKUP_FRACTION).ceil() as usize).clamp(1, max_lookups)
        } else {
            max_lookups
        };
        let dense_in = m.config_usize("dlrm", "dense_in")?;
        let dense_elem = if t.fp16_dense_inputs { 2 } else { 4 };
        let recsys_in = num_tables * (recsys_batch * used * 4 + recsys_batch * 4)
            + recsys_batch * dense_in * dense_elem;
        let d_model = m.config_usize("xlmr", "d_model")?;
        let image = m.config_usize("cv", "image")?;
        let classes = m.config_usize("cv", "classes")?;
        Ok(WireModel {
            recsys_in,
            recsys_out: recsys_batch * 2,
            nlp_out: d_model * 2,
            cv_in_per_image: image * image * 3 * 2,
            cv_out_per_image: classes * 2,
        })
    }

    /// (ingress, egress) bytes for one request.
    pub fn bytes(&self, req: &FleetRequest) -> (usize, usize) {
        match req {
            FleetRequest::Recsys { .. } => (self.recsys_in, self.recsys_out),
            // token ids + a length word
            FleetRequest::Nlp { req, .. } => (req.tokens.len() * 4 + 4, self.nlp_out),
            FleetRequest::Cv { req, .. } => {
                let b = req.image.shape().first().copied().unwrap_or(1);
                (b * self.cv_in_per_image, b * self.cv_out_per_image)
            }
        }
    }
}

/// One member of the tier: its hardware spec, its fleet (engine + replica
/// set + card router), and the routing signal the weighted policy prices
/// nodes with.
pub struct ClusterNode {
    pub spec: NodeSpec,
    pub fleet: Arc<Fleet>,
    /// Mean modeled request cost per family *on this node's cards* —
    /// slower (vendor-mix) nodes carry larger costs, which is exactly what
    /// weighted-by-modeled-capacity balances on.
    pub fam_cost_s: [f64; 3],
}

impl ClusterNode {
    pub fn replicas(&self) -> &ReplicaManager {
        self.fleet.replicas()
    }
}

/// Mean modeled request cost per family over a node's replica set.
fn family_cost_estimates(r: &ReplicaManager) -> [f64; 3] {
    fn mean(xs: &[f64]) -> f64 {
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }
    let recsys: Vec<f64> = (0..r.recsys.len()).map(|i| r.recsys_request_cost_s(i)).collect();
    let mut nlp = Vec::new();
    for rep in &r.nlp {
        for &b in &r.buckets {
            if let Some(c) = rep.cost(b) {
                nlp.push(c.total_s());
            }
        }
    }
    let cv: Vec<f64> = r.cv.iter().map(|c| c.cost.total_s()).collect();
    [mean(&recsys), mean(&nlp), mean(&cv)]
}

/// Per-node slice of a cluster run.
#[derive(Debug, Clone)]
pub struct NodeMetrics {
    pub node: usize,
    pub metrics: ServerMetrics,
    /// Requests the node router sent here (admitted or shed at admission).
    pub offered: usize,
    pub shed_admission: usize,
    pub shed_failed: usize,
    /// Modeled card-compute seconds (failure-shed work included — the
    /// cards burned that time before the node died).
    pub busy_s: f64,
    pub nic_rx_busy_s: f64,
    pub nic_tx_busy_s: f64,
    pub drained_at_s: Option<f64>,
    pub failed_at_s: Option<f64>,
}

impl NodeMetrics {
    /// Fraction of the run span this node accepted traffic — the
    /// availability hit of a drain/fail event.
    pub fn availability(&self, span_s: f64) -> f64 {
        match self.failed_at_s.or(self.drained_at_s) {
            None => 1.0,
            Some(t) if span_s > 0.0 => (t / span_s).clamp(0.0, 1.0),
            Some(_) => 0.0,
        }
    }
}

/// Everything a cluster run reports. The conservation invariant holds by
/// construction: `cluster.completed + shed() == offered`.
#[derive(Debug, Clone)]
pub struct ClusterMetrics {
    pub node_policy: NodePolicy,
    pub card_policy: RoutePolicy,
    pub cluster: ServerMetrics,
    pub per_node: Vec<NodeMetrics>,
    pub per_family: Vec<FamilyMetrics>,
    pub offered: usize,
    /// Shed by a node's own admission control (bounded queue / SLA / no
    /// serving bucket) — the "SLA shed" the capacity planner drives to 0.
    pub shed_admission: usize,
    /// `shed_admission` split by cause (`shed_causes.total() ==
    /// shed_admission`).
    pub shed_causes: ShedCounts,
    /// In flight on a node when it failed.
    pub shed_failed: usize,
    /// No node available to route to.
    pub shed_unroutable: usize,
}

impl ClusterMetrics {
    pub fn shed(&self) -> usize {
        self.shed_admission + self.shed_failed + self.shed_unroutable
    }

    pub fn shed_rate(&self) -> f64 {
        self.shed() as f64 / self.offered.max(1) as f64
    }

    pub fn cluster_qps(&self) -> f64 {
        self.cluster.qps()
    }
}

/// The tier: N nodes plus the shared wire model and per-node fleet knobs.
pub struct Cluster {
    nodes: Vec<ClusterNode>,
    fleet_cfg: FleetConfig,
    wire: WireModel,
}

impl Cluster {
    /// Build one engine + fleet per node spec. Every node runs the sim
    /// backend (the tier is a modeled-clock subsystem; per-request
    /// numerics still execute for real through [`Cluster::serve`]).
    /// `base` supplies everything except the per-node hardware; `dir` is
    /// the artifacts directory (the builtin manifest serves when absent,
    /// as everywhere else).
    pub fn new(
        dir: &Path,
        base: &Config,
        specs: &[NodeSpec],
        fleet_cfg: FleetConfig,
    ) -> Result<Cluster> {
        if specs.is_empty() {
            bail!("cluster needs at least one node");
        }
        let mut nodes: Vec<ClusterNode> = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            if spec.cards == 0 {
                bail!("cluster node {i}: cards must be > 0");
            }
            if !(spec.nic.bw_bits > 0.0) {
                bail!(
                    "cluster node {i}: nic.bw_bits must be positive (got {})",
                    spec.nic.bw_bits
                );
            }
            // identical specs share one engine + prepared replica set: all
            // per-node scheduling state (planner, NIC occupancy) lives in
            // the router, and execution through the fleet is stateless, so
            // a uniform tier pays for one build instead of N
            if let Some(twin) = nodes.iter().find(|n| n.spec == *spec) {
                let node = ClusterNode {
                    spec: spec.clone(),
                    fleet: Arc::clone(&twin.fleet),
                    fam_cost_s: twin.fam_cost_s,
                };
                nodes.push(node);
                continue;
            }
            let mut cfg = base.clone();
            cfg.node = spec.clone();
            // the §VI-B shard range cannot exceed this node's card count
            cfg.compiler.sls_cards = cfg.compiler.sls_cards.min(spec.cards);
            cfg.cluster = None; // nodes do not nest tiers
            let engine = Arc::new(Engine::auto_with_backend(
                dir,
                Arc::new(SimBackend::new(cfg)),
            )?);
            debug_assert_eq!(engine.clock(), Clock::Modeled);
            let fleet = Arc::new(Fleet::new(engine, fleet_cfg.clone())?);
            let fam_cost_s = family_cost_estimates(fleet.replicas());
            nodes.push(ClusterNode { spec: spec.clone(), fleet, fam_cost_s });
        }
        let wire =
            WireModel::new(nodes[0].fleet.engine().manifest(), &base.transfers, fleet_cfg.recsys_batch)?;
        Ok(Cluster { nodes, fleet_cfg, wire })
    }

    pub fn nodes(&self) -> &[ClusterNode] {
        &self.nodes
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn config(&self) -> &FleetConfig {
        &self.fleet_cfg
    }

    pub fn wire(&self) -> &WireModel {
        &self.wire
    }

    pub fn manifest(&self) -> &Manifest {
        self.nodes[0].fleet.engine().manifest()
    }

    /// Plan the stream without executing numerics (policy sweeps, capacity
    /// probes, scenario studies).
    pub fn route(
        &self,
        reqs: &[FleetRequest],
        node_policy: NodePolicy,
        card_policy: RoutePolicy,
        scenario: &Scenario,
    ) -> Result<ClusterMetrics> {
        self.route_traced(reqs, node_policy, card_policy, scenario, None)
    }

    /// [`Cluster::route`] with an optional tracing sink ([`crate::obs`]):
    /// `Some` records per-request spans plus NIC/link/compute occupancy
    /// timelines; `None` is the zero-cost path with bit-identical metrics.
    pub fn route_traced(
        &self,
        reqs: &[FleetRequest],
        node_policy: NodePolicy,
        card_policy: RoutePolicy,
        scenario: &Scenario,
        tracer: Option<&mut Tracer>,
    ) -> Result<ClusterMetrics> {
        let plan = router::plan_traced(
            &self.nodes,
            reqs,
            node_policy,
            card_policy,
            &self.fleet_cfg,
            scenario,
            &self.wire,
            tracer,
        )?;
        Ok(self.assemble(&plan, node_policy, card_policy))
    }

    /// Plan, then execute every completed request's real numerics on its
    /// assigned node/replica with `workers` in flight. Metrics come from
    /// the plan, so they are bit-identical across runs and worker counts.
    pub fn serve(
        self: &Arc<Self>,
        reqs: Vec<FleetRequest>,
        node_policy: NodePolicy,
        card_policy: RoutePolicy,
        scenario: &Scenario,
        workers: usize,
    ) -> Result<ClusterMetrics> {
        let plan = router::plan(
            &self.nodes,
            &reqs,
            node_policy,
            card_policy,
            &self.fleet_cfg,
            scenario,
            &self.wire,
        )?;
        self.execute(Arc::new(reqs), &plan, workers.max(1))?;
        Ok(self.assemble(&plan, node_policy, card_policy))
    }

    fn assemble(
        &self,
        plan: &ClusterPlan,
        node_policy: NodePolicy,
        card_policy: RoutePolicy,
    ) -> ClusterMetrics {
        let span = plan.span_s;
        let mk = || ServerMetrics {
            latency: Histogram::latency(),
            completed: 0,
            items: 0,
            wall_s: span,
            clock: Clock::Modeled,
            stages: StageStats::default(),
            windows: None,
        };
        let mut cluster = mk();
        let mut per_node: Vec<NodeMetrics> = plan
            .nodes
            .iter()
            .enumerate()
            .map(|(k, r)| NodeMetrics {
                node: k,
                metrics: mk(),
                offered: 0,
                shed_admission: 0,
                shed_failed: 0,
                busy_s: r.busy_s,
                nic_rx_busy_s: r.nic_rx_busy_s,
                nic_tx_busy_s: r.nic_tx_busy_s,
                drained_at_s: r.drained_at_s,
                failed_at_s: r.failed_at_s,
            })
            .collect();
        let mut per_family: Vec<FamilyMetrics> = Family::ALL
            .iter()
            .map(|&f| FamilyMetrics { family: f, metrics: mk(), offered: 0, shed: 0 })
            .collect();
        let (mut shed_admission, mut shed_failed, mut shed_unroutable) = (0usize, 0usize, 0usize);
        let mut shed_causes = ShedCounts::default();
        for p in &plan.planned {
            let fam = &mut per_family[p.family.index()];
            fam.offered += 1;
            match p.outcome {
                Outcome::Completed { node, latency_s, stage, .. } => {
                    cluster.latency.add(latency_s);
                    cluster.completed += 1;
                    cluster.items += p.items;
                    cluster.stages.add(&stage);
                    fam.metrics.latency.add(latency_s);
                    fam.metrics.completed += 1;
                    fam.metrics.items += p.items;
                    fam.metrics.stages.add(&stage);
                    let nm = &mut per_node[node];
                    nm.offered += 1;
                    nm.metrics.latency.add(latency_s);
                    nm.metrics.completed += 1;
                    nm.metrics.items += p.items;
                    nm.metrics.stages.add(&stage);
                }
                Outcome::ShedAdmission { node, cause } => {
                    shed_admission += 1;
                    shed_causes.count(cause);
                    fam.shed += 1;
                    per_node[node].offered += 1;
                    per_node[node].shed_admission += 1;
                }
                Outcome::ShedFailed { node } => {
                    shed_failed += 1;
                    fam.shed += 1;
                    per_node[node].offered += 1;
                    per_node[node].shed_failed += 1;
                }
                Outcome::ShedUnroutable => {
                    shed_unroutable += 1;
                    fam.shed += 1;
                }
            }
        }
        ClusterMetrics {
            node_policy,
            card_policy,
            cluster,
            per_node,
            per_family,
            offered: plan.planned.len(),
            shed_admission,
            shed_causes,
            shed_failed,
            shed_unroutable,
        }
    }

    /// Execute the completed requests' numerics over a worker pool (the
    /// per-node step is [`Fleet::execute_one`]).
    fn execute(
        self: &Arc<Self>,
        reqs: Arc<Vec<FleetRequest>>,
        plan: &ClusterPlan,
        workers: usize,
    ) -> Result<()> {
        let admitted: Arc<Vec<(usize, usize, crate::serving::fleet::Decision)>> = Arc::new(
            plan.planned
                .iter()
                .enumerate()
                .filter_map(|(i, p)| match p.outcome {
                    Outcome::Completed { node, decision, .. } => Some((i, node, decision)),
                    _ => None,
                })
                .collect(),
        );
        let n = admitted.len();
        if n == 0 {
            return Ok(());
        }
        let workers = workers.min(n);
        let pool = ThreadPool::new(workers);
        let next = Arc::new(AtomicUsize::new(0));
        let failed = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Result<usize>>();
        for _ in 0..workers {
            let me = Arc::clone(self);
            let reqs = Arc::clone(&reqs);
            let admitted = Arc::clone(&admitted);
            let next = Arc::clone(&next);
            let failed = Arc::clone(&failed);
            let tx = tx.clone();
            pool.execute(move || {
                let mut done = 0usize;
                let res = loop {
                    if failed.load(Ordering::Relaxed) {
                        break Ok(());
                    }
                    let j = next.fetch_add(1, Ordering::Relaxed);
                    if j >= n {
                        break Ok(());
                    }
                    let (i, node, decision) = admitted[j];
                    match me.nodes[node].fleet.execute_one(&reqs[i], decision) {
                        Ok(()) => done += 1,
                        Err(e) => {
                            failed.store(true, Ordering::Relaxed);
                            break Err(e);
                        }
                    }
                };
                let _ = tx.send(res.map(|()| done));
            });
        }
        drop(tx);
        let mut total = 0usize;
        let mut first_err = None;
        for r in rx.iter() {
            match r {
                Ok(d) => total += d,
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if total != n {
            return Err(err!(
                "cluster worker exited without reporting ({total} of {n} requests executed)"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin::builtin_manifest;
    use crate::workloads::{NlpRequest, RecsysGen};

    #[test]
    fn wire_model_prices_families_from_the_manifest() {
        let m = builtin_manifest();
        let t = TransferConfig::default();
        let w = WireModel::new(&m, &t, 16).unwrap();
        // recsys: 8 tables x (16 x 13 used lookups x 4B + 16 lengths x 4B)
        // + 16 x 256 fp16 dense features
        assert_eq!(w.recsys_in, 8 * (16 * 13 * 4 + 16 * 4) + 16 * 256 * 2);
        assert_eq!(w.recsys_out, 32);
        let mut gen = RecsysGen::from_manifest(1, 16, &m).unwrap();
        let req = FleetRequest::Recsys { arrival_s: 0.0, req: gen.next() };
        assert_eq!(w.bytes(&req), (w.recsys_in, w.recsys_out));
        // nlp scales with the sentence, cv with the image batch
        let nlp = FleetRequest::Nlp {
            arrival_s: 0.0,
            req: NlpRequest { tokens: vec![1; 30], arrival_s: 0.0 },
        };
        assert_eq!(w.bytes(&nlp), (30 * 4 + 4, 256 * 2));
        // turning the §VI-C input optimizations off grows the ingress
        let off = TransferConfig {
            partial_tensors: false,
            fp16_dense_inputs: false,
            ..TransferConfig::default()
        };
        let wo = WireModel::new(&m, &off, 16).unwrap();
        assert!(wo.recsys_in > w.recsys_in);
    }
}
