//! Node failure / drain scenarios (§VII operational lessons).
//!
//! Operating a fleet means operating through node loss: maintenance
//! *drains* a node (it stops taking new traffic but finishes what it has),
//! hardware failure *kills* one (in-flight work is shed on the spot and the
//! availability hit lands in the metrics). A [`Scenario`] is a list of such
//! events at trace timestamps; the cluster router applies each event the
//! moment the request stream reaches its time, so scenario runs stay as
//! bit-reproducible as everything else on the modeled clock.

use crate::util::error::{bail, Result};

/// What happens to the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Stop routing new requests to the node; in-flight work completes
    /// (planned maintenance).
    Drain,
    /// Node dies: no new requests, and everything in flight — admitted but
    /// not yet delivered by `at_s` — is shed and counted against
    /// availability (hardware failure).
    Fail,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Drain => "drain",
            EventKind::Fail => "fail",
        }
    }
}

/// One event: `node` changes state at trace time `at_s`.
#[derive(Debug, Clone, Copy)]
pub struct NodeEvent {
    pub at_s: f64,
    pub node: usize,
    pub kind: EventKind,
}

/// An ordered event list. Construction sorts by time (stable, so two
/// events at the same instant apply in insertion order).
#[derive(Debug, Clone, Default)]
pub struct Scenario {
    events: Vec<NodeEvent>,
}

impl Scenario {
    /// The empty scenario: every node stays up.
    pub fn none() -> Scenario {
        Scenario::default()
    }

    pub fn new(mut events: Vec<NodeEvent>) -> Scenario {
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap_or(std::cmp::Ordering::Equal));
        Scenario { events }
    }

    pub fn events(&self) -> &[NodeEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Reject events naming nodes outside the cluster or non-finite /
    /// negative timestamps before a planning pass consumes them.
    pub fn validate(&self, nodes: usize) -> Result<()> {
        for e in &self.events {
            if e.node >= nodes {
                bail!(
                    "scenario {} event names node {} but the cluster has {nodes} nodes",
                    e.kind.name(),
                    e.node
                );
            }
            if !e.at_s.is_finite() || e.at_s < 0.0 {
                bail!(
                    "scenario {} event for node {} has invalid time {}",
                    e.kind.name(),
                    e.node,
                    e.at_s
                );
            }
        }
        Ok(())
    }
}

/// Parse a CLI event list: `"node@seconds"` entries, comma-separated —
/// e.g. `--fail 0@0.5` or `--drain "1@0.2,3@0.9"`.
pub fn parse_events(kind: EventKind, spec: &str) -> Result<Vec<NodeEvent>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (node, at) = match part.split_once('@') {
            Some(x) => x,
            None => bail!(
                "--{} entries are node@seconds (e.g. 0@0.5); got '{part}'",
                kind.name()
            ),
        };
        let node: usize = node
            .trim()
            .parse()
            .map_err(|_| crate::err!("--{} node index '{node}' is not an integer", kind.name()))?;
        let at_s: f64 = at
            .trim()
            .parse()
            .map_err(|_| crate::err!("--{} time '{at}' is not a number", kind.name()))?;
        out.push(NodeEvent { at_s, node, kind });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_sorts_and_validates() {
        let s = Scenario::new(vec![
            NodeEvent { at_s: 2.0, node: 1, kind: EventKind::Fail },
            NodeEvent { at_s: 0.5, node: 0, kind: EventKind::Drain },
        ]);
        assert_eq!(s.events()[0].node, 0);
        assert_eq!(s.events()[1].node, 1);
        s.validate(2).unwrap();
        let e = s.validate(1).unwrap_err().to_string();
        assert!(e.contains("node 1") && e.contains("1 nodes"), "{e}");
        let bad = Scenario::new(vec![NodeEvent { at_s: -1.0, node: 0, kind: EventKind::Fail }]);
        assert!(bad.validate(2).is_err());
        assert!(Scenario::none().is_empty());
    }

    #[test]
    fn event_parsing() {
        let evs = parse_events(EventKind::Fail, "0@0.5, 2@1.25").unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].node, 0);
        assert!((evs[0].at_s - 0.5).abs() < 1e-12);
        assert_eq!(evs[1].node, 2);
        assert_eq!(evs[1].kind, EventKind::Fail);
        assert!(parse_events(EventKind::Drain, "0:0.5").is_err());
        assert!(parse_events(EventKind::Drain, "x@1").is_err());
        assert!(parse_events(EventKind::Drain, "1@y").is_err());
        assert!(parse_events(EventKind::Drain, "").unwrap().is_empty());
    }
}
