//! Datacenter-scale capacity planning: Fig. 1 in whole nodes, with
//! failure headroom (§VII).
//!
//! The fleet layer answers "what does one node deliver on the mixed
//! trace"; this module turns that into the operator's question — **how
//! many N-card nodes (plus failure headroom h) carry Q QPS of a 70/20/10
//! mix within the SLA** — and then *verifies* its own recommendation by
//! simulating the scenario the headroom exists for: kill one node mid-run
//! at the target load and check that admission ("SLA") shed stays at
//! zero. A planner that only divides two numbers would happily recommend
//! a tier that melts the moment a node dies; this one has to survive its
//! own failure drill.

use crate::capacity::GrowthScenario;
use crate::config::Config;
use crate::serving::cluster::router::NodePolicy;
use crate::serving::cluster::scenario::{EventKind, NodeEvent, Scenario};
use crate::serving::cluster::Cluster;
use crate::serving::fleet::{Arrival, Family, FamilyMix, FleetConfig, RoutePolicy, TrafficGen};
use crate::util::error::{bail, Result};
use std::path::Path;

/// Seed for the planning traces — fixed so capacity answers are
/// reproducible run to run.
pub const PLAN_TRAFFIC_SEED: u64 = 0xC1_7001;

/// Nodes are sized to run at this fraction of their measured saturation
/// throughput, so the tier absorbs arrival bursts and a failed peer's
/// diverted traffic without queues growing past the SLA.
pub const UTILIZATION_TARGET: f64 = 0.7;

/// Fraction of the verification trace's horizon at which the drill kills
/// node 0 (early enough that most of the trace lands on the survivors).
const FAILURE_DRILL_AT: f64 = 0.4;

/// One cluster-level capacity answer.
#[derive(Debug, Clone)]
pub struct ClusterCapacityReport {
    pub mix: FamilyMix,
    pub node_policy: NodePolicy,
    pub card_policy: RoutePolicy,
    /// Measured single-node saturation throughput, requests/sec.
    pub node_qps: f64,
    /// The demand the tier is sized for, requests/sec.
    pub target_qps: f64,
    /// Load-driven node count (target / (node_qps x utilization target)).
    pub nodes_needed: usize,
    pub headroom: usize,
    pub nodes_total: usize,
    /// The failure drill's admission ("SLA") shed — 0 when the headroom
    /// recommendation holds.
    pub sla_shed_after_failure: usize,
    /// In-flight requests lost at the failure instant (availability hit,
    /// not an SLA violation — they were already admitted).
    pub failure_shed: usize,
    /// Requests the drill completed within admission control.
    pub drill_completed: usize,
    /// The acceptance flag: with the recommended tier, killing one node at
    /// target load sheds nothing at admission and leaves nothing
    /// unroutable.
    pub survives_single_node_failure: bool,
    /// Fig. 1 at node granularity: (quarter, demand QPS, nodes incl.
    /// headroom) as demand grows from `target_qps`.
    pub growth: Vec<(usize, f64, usize)>,
}

/// Whole nodes (incl. headroom) needed for each point of a demand curve.
pub fn node_series(
    scenario: &GrowthScenario,
    node_qps: f64,
    headroom: usize,
) -> Vec<(usize, f64, usize)> {
    (0..=scenario.quarters)
        .map(|q| {
            let demand = scenario.demand_at(q);
            let nodes = nodes_for(demand, node_qps) + headroom;
            (q, demand, nodes)
        })
        .collect()
}

fn nodes_for(target_qps: f64, node_qps: f64) -> usize {
    ((target_qps / (node_qps * UTILIZATION_TARGET)).ceil() as usize).max(1)
}

/// Size a tier of `cfg.node` clones for `target_qps` of `mix` traffic and
/// verify the recommendation under a single-node failure drill.
///
/// `target_qps <= 0` sizes for 1.5x one node's measured throughput (a
/// tier that genuinely needs more than one node, the smallest interesting
/// answer). When `fleet_cfg` carries no SLA budget, the tightest Table I
/// family budget is used so "SLA shed" is a real admission criterion, not
/// a vacuous one.
pub fn plan_capacity(
    dir: &Path,
    cfg: &Config,
    fleet_cfg: &FleetConfig,
    mix: FamilyMix,
    node_policy: NodePolicy,
    card_policy: RoutePolicy,
    target_qps: f64,
    headroom: usize,
    requests: usize,
) -> Result<ClusterCapacityReport> {
    let mut fcfg = fleet_cfg.clone();
    if fcfg.sla_budget_s.is_none() {
        fcfg.sla_budget_s = Some(
            Family::ALL
                .iter()
                .map(|f| f.latency_budget_s())
                .fold(f64::INFINITY, f64::min),
        );
    }
    let requests = requests.max(1);

    // 1. measure one node's saturation throughput on a burst of the mix
    let single = Cluster::new(dir, cfg, &[cfg.node.clone()], fcfg.clone())?;
    let mut traffic = TrafficGen::new(
        PLAN_TRAFFIC_SEED,
        mix,
        Arrival::Burst,
        single.manifest(),
        fcfg.recsys_batch,
    )?;
    let reqs = traffic.take(requests);
    let probe = single.route(&reqs, node_policy, card_policy, &Scenario::none())?;
    let node_qps = probe.cluster_qps();
    if !(node_qps > 0.0) {
        bail!(
            "cluster capacity probe measured no single-node throughput \
             ({} of {} requests completed)",
            probe.cluster.completed,
            probe.offered
        );
    }

    // 2. size the tier
    let target_qps = if target_qps > 0.0 { target_qps } else { 1.5 * node_qps };
    let nodes_needed = nodes_for(target_qps, node_qps);
    let nodes_total = nodes_needed + headroom;

    // 3. failure drill: Poisson at the target over the full tier, node 0
    // dies partway through
    let specs = vec![cfg.node.clone(); nodes_total];
    let cluster = Cluster::new(dir, cfg, &specs, fcfg.clone())?;
    let mut traffic = TrafficGen::new(
        PLAN_TRAFFIC_SEED ^ 0x5EED,
        mix,
        Arrival::Poisson { rate_qps: target_qps },
        cluster.manifest(),
        fcfg.recsys_batch,
    )?;
    let reqs = traffic.take(requests);
    let horizon = reqs.last().map(|r| r.arrival_s()).unwrap_or(0.0);
    let drill = Scenario::new(vec![NodeEvent {
        at_s: FAILURE_DRILL_AT * horizon,
        node: 0,
        kind: EventKind::Fail,
    }]);
    let v = cluster.route(&reqs, node_policy, card_policy, &drill)?;
    let survives = v.shed_admission == 0 && v.shed_unroutable == 0;

    // 4. Fig. 1 at node granularity, growing from the target
    let growth_curve = GrowthScenario {
        name: "cluster",
        quarterly_growth: 1.25,
        quarters: 8,
        initial_qps: target_qps,
    };
    Ok(ClusterCapacityReport {
        mix,
        node_policy,
        card_policy,
        node_qps,
        target_qps,
        nodes_needed,
        headroom,
        nodes_total,
        sla_shed_after_failure: v.shed_admission,
        failure_shed: v.shed_failed,
        drill_completed: v.cluster.completed,
        survives_single_node_failure: survives,
        growth: node_series(&growth_curve, node_qps, headroom),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_series_is_monotone_and_carries_headroom() {
        let s = GrowthScenario {
            name: "t",
            quarterly_growth: 1.25,
            quarters: 8,
            initial_qps: 1000.0,
        };
        let series = node_series(&s, 400.0, 2);
        assert_eq!(series.len(), 9);
        for w in series.windows(2) {
            assert!(w[1].1 > w[0].1, "demand must grow");
            assert!(w[1].2 >= w[0].2, "nodes must not shrink");
        }
        // headroom rides on every point
        let bare = node_series(&s, 400.0, 0);
        for (a, b) in series.iter().zip(&bare) {
            assert_eq!(a.2, b.2 + 2);
        }
        // first point: 1000 / (400 * 0.7) = 3.57 -> 4 nodes + 2
        assert_eq!(series[0].2, 6);
    }
}
