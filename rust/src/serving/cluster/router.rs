//! The node-level router: dispatch the mixed stream across whole nodes.
//!
//! Two-tier dispatch: this router picks a *node* for every request, then
//! the node's own card router ([`crate::serving::fleet::router`], reused a
//! request at a time through [`NodePlanner`]) picks the replica and card.
//! Between the tiers sits the NIC: a request's bytes must clear the chosen
//! node's ingress link before its card router even sees it, and its fp16
//! response must clear the egress link before the caller counts it done —
//! so with enough offered load a cluster's throughput is capped by
//! `NicSpec.bw_bits`, not by its cards (the paper's network-bandwidth
//! requirement).
//!
//! Like the fleet router, planning is a deterministic pass over the stream
//! in arrival order: identical inputs give bit-identical plans regardless
//! of worker counts, because workers only execute numerics afterwards.

use crate::serving::cluster::scenario::{EventKind, NodeEvent, Scenario};
use crate::serving::cluster::{ClusterNode, WireModel};
use crate::serving::fleet::router::{self as fleet_router, NodePlanner};
use crate::serving::fleet::{Decision, Family, FleetConfig, FleetRequest, RoutePolicy};
use crate::sim::transfer::NicOccupancy;
use crate::util::error::{bail, Result};

/// Node-selection policy for the top tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodePolicy {
    /// Rotate over the available nodes, blind to load and node speed.
    RoundRobin,
    /// Fewest outstanding segments across the node's cards.
    JoinShortestQueue,
    /// Least *modeled work*: send the request where cumulative assigned
    /// seconds (priced with each node's own per-family modeled cost) stays
    /// smallest. On a heterogeneous tier a slow node accumulates seconds
    /// faster, so it naturally receives fewer requests — capacity-weighted
    /// balancing without hand-set weights.
    WeightedCapacity,
}

impl NodePolicy {
    pub const ALL: [NodePolicy; 3] =
        [NodePolicy::RoundRobin, NodePolicy::JoinShortestQueue, NodePolicy::WeightedCapacity];

    pub fn name(self) -> &'static str {
        match self {
            NodePolicy::RoundRobin => "round-robin",
            NodePolicy::JoinShortestQueue => "join-shortest-queue",
            NodePolicy::WeightedCapacity => "weighted-by-modeled-capacity",
        }
    }

    pub fn parse(s: &str) -> Result<NodePolicy> {
        Ok(match s {
            "round-robin" | "rr" => NodePolicy::RoundRobin,
            "join-shortest-queue" | "jsq" => NodePolicy::JoinShortestQueue,
            "weighted-by-modeled-capacity" | "weighted" | "wc" => NodePolicy::WeightedCapacity,
            other => bail!(
                "unknown node policy '{other}' \
                 (valid: round-robin, join-shortest-queue, weighted-by-modeled-capacity)"
            ),
        })
    }
}

/// What happened to one request of the stream.
#[derive(Debug, Clone, Copy)]
pub enum Outcome {
    /// Routed, served, response delivered back over the node's NIC.
    Completed { node: usize, decision: Decision, latency_s: f64, finish_s: f64 },
    /// The chosen node's card router shed it (bounded queue / SLA / no
    /// serving bucket).
    ShedAdmission { node: usize },
    /// Admitted, but its node failed before the response was delivered.
    ShedFailed { node: usize },
    /// No node was available to route to (everything drained or failed).
    ShedUnroutable,
}

/// One planned request of the cluster pass.
#[derive(Debug, Clone)]
pub struct ClusterPlanned {
    pub family: Family,
    pub arrival_s: f64,
    pub items: usize,
    pub outcome: Outcome,
}

/// Per-node accounting of a cluster plan.
#[derive(Debug, Clone)]
pub struct NodeReport {
    /// Modeled compute seconds the node's cards spent (includes work that
    /// was later shed by a failure — the cards did burn that time).
    pub busy_s: f64,
    pub nic_rx_busy_s: f64,
    pub nic_tx_busy_s: f64,
    pub drained_at_s: Option<f64>,
    pub failed_at_s: Option<f64>,
}

/// The full cluster plan.
#[derive(Debug, Clone)]
pub struct ClusterPlan {
    pub planned: Vec<ClusterPlanned>,
    /// Last delivered response minus first arrival (0 when nothing
    /// completed).
    pub span_s: f64,
    pub nodes: Vec<NodeReport>,
}

/// Mutable per-node planning state.
struct NodeState {
    planner: NodePlanner,
    nic: NicOccupancy,
    up: bool,
    drained_at: Option<f64>,
    failed_at: Option<f64>,
    /// Cumulative modeled seconds routed here (weighted-capacity signal).
    assigned_s: f64,
    /// (planned index, delivery time) of admitted requests — consulted
    /// when the node fails to shed what was still in flight.
    inflight: Vec<(usize, f64)>,
    /// Busy/NIC seconds accumulated before a failure reset the live state.
    busy_snapshot_s: f64,
    nic_rx_snapshot_s: f64,
    nic_tx_snapshot_s: f64,
}

/// Apply one scenario event. Failing a node demotes its undelivered
/// requests to [`Outcome::ShedFailed`] and cold-resets its planner and NIC
/// (what replaces the node starts empty); draining only stops new traffic.
fn apply_event(e: &NodeEvent, state: &mut NodeState, planned: &mut [ClusterPlanned]) {
    match e.kind {
        EventKind::Drain => {
            if state.up {
                state.up = false;
                state.drained_at = Some(e.at_s);
            }
        }
        EventKind::Fail => {
            if state.failed_at.is_some() {
                return;
            }
            state.up = false;
            state.failed_at = Some(e.at_s);
            for &(idx, delivered) in &state.inflight {
                if delivered > e.at_s {
                    if let Outcome::Completed { node, .. } = planned[idx].outcome {
                        planned[idx].outcome = Outcome::ShedFailed { node };
                    }
                }
            }
            state.inflight.clear();
            let busy: f64 = state.planner.busy_s().iter().sum();
            let (rx, tx) = (state.nic.rx_busy_s(), state.nic.tx_busy_s());
            state.busy_snapshot_s += busy;
            state.nic_rx_snapshot_s += rx;
            state.nic_tx_snapshot_s += tx;
            state.planner.reset();
            state.nic.reset();
        }
    }
}

/// Plan the two-tier routing of `reqs` (nondecreasing arrival order) over
/// the cluster, applying `scenario` events as the stream reaches them.
pub fn plan(
    nodes: &[ClusterNode],
    reqs: &[FleetRequest],
    node_policy: NodePolicy,
    card_policy: RoutePolicy,
    cfg: &FleetConfig,
    scenario: &Scenario,
    wire: &WireModel,
) -> Result<ClusterPlan> {
    if nodes.is_empty() {
        bail!("cluster needs at least one node");
    }
    for node in nodes {
        fleet_router::validate(node.replicas(), cfg)?;
    }
    scenario.validate(nodes.len())?;

    let n = nodes.len();
    let mut states: Vec<NodeState> = nodes
        .iter()
        .map(|c| NodeState {
            planner: NodePlanner::new(c.replicas().cards),
            nic: NicOccupancy::new(c.spec.nic.bw_bits),
            up: true,
            drained_at: None,
            failed_at: None,
            assigned_s: 0.0,
            inflight: Vec::new(),
            busy_snapshot_s: 0.0,
            nic_rx_snapshot_s: 0.0,
            nic_tx_snapshot_s: 0.0,
        })
        .collect();
    let events = scenario.events();
    let mut ev = 0usize;
    let mut rr = 0usize;
    let mut planned: Vec<ClusterPlanned> = Vec::with_capacity(reqs.len());
    let mut last_arrival = f64::NEG_INFINITY;

    for (i, req) in reqs.iter().enumerate() {
        let t = req.arrival_s();
        if t < last_arrival {
            bail!(
                "cluster requests must arrive in nondecreasing order \
                 ({t} after {last_arrival})"
            );
        }
        last_arrival = t;
        while ev < events.len() && events[ev].at_s <= t {
            apply_event(&events[ev], &mut states[events[ev].node], &mut planned);
            ev += 1;
        }
        let family = req.family();

        // tier 1: pick a node (every policy breaks ties toward the lowest
        // node id, so the choice is deterministic)
        let pick = match node_policy {
            NodePolicy::RoundRobin => {
                let mut pick = None;
                for step in 0..n {
                    let k = (rr + step) % n;
                    if states[k].up {
                        pick = Some(k);
                        rr = (k + 1) % n;
                        break;
                    }
                }
                pick
            }
            NodePolicy::JoinShortestQueue => {
                let mut best: Option<(usize, usize)> = None;
                for k in 0..n {
                    if !states[k].up {
                        continue;
                    }
                    states[k].planner.prune(t);
                    let d = states[k].planner.outstanding();
                    if best.map_or(true, |(bd, _)| d < bd) {
                        best = Some((d, k));
                    }
                }
                best.map(|(_, k)| k)
            }
            NodePolicy::WeightedCapacity => {
                let mut best: Option<(f64, usize)> = None;
                for k in 0..n {
                    if !states[k].up {
                        continue;
                    }
                    let proj = states[k].assigned_s + nodes[k].fam_cost_s[family.index()];
                    if best.map_or(true, |(bp, _)| proj < bp) {
                        best = Some((proj, k));
                    }
                }
                best.map(|(_, k)| k)
            }
        };

        let outcome = match pick {
            None => Outcome::ShedUnroutable,
            Some(k) => {
                // tier 1.5: the request's bytes serialize on the node NIC
                let (in_bytes, out_bytes) = wire.bytes(req);
                let state = &mut states[k];
                let t_node = state.nic.rx(t, in_bytes);
                // tier 2: the node's own card router
                match state.planner.route_one(nodes[k].replicas(), req, t_node, card_policy, cfg)
                {
                    None => Outcome::ShedAdmission { node: k },
                    Some(r) => {
                        let delivered = state.nic.tx(r.finish_s, out_bytes);
                        state.assigned_s += nodes[k].fam_cost_s[family.index()];
                        state.inflight.push((i, delivered));
                        Outcome::Completed {
                            node: k,
                            decision: r.decision,
                            latency_s: delivered - t,
                            finish_s: delivered,
                        }
                    }
                }
            }
        };
        planned.push(ClusterPlanned { family, arrival_s: t, items: req.items(), outcome });
    }

    // events after the last arrival can still kill in-flight work
    while ev < events.len() {
        apply_event(&events[ev], &mut states[events[ev].node], &mut planned);
        ev += 1;
    }

    let mut max_finish: Option<f64> = None;
    for p in &planned {
        if let Outcome::Completed { finish_s, .. } = p.outcome {
            max_finish = Some(max_finish.map_or(finish_s, |m: f64| m.max(finish_s)));
        }
    }
    let span_s = match (reqs.first(), max_finish) {
        (Some(first), Some(finish)) => (finish - first.arrival_s()).max(0.0),
        _ => 0.0,
    };
    let node_reports = states
        .iter()
        .map(|s| NodeReport {
            busy_s: s.busy_snapshot_s + s.planner.busy_s().iter().sum::<f64>(),
            nic_rx_busy_s: s.nic_rx_snapshot_s + s.nic.rx_busy_s(),
            nic_tx_busy_s: s.nic_tx_snapshot_s + s.nic.tx_busy_s(),
            drained_at_s: s.drained_at,
            failed_at_s: s.failed_at,
        })
        .collect();
    Ok(ClusterPlan { planned, span_s, nodes: node_reports })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_policy_parse_roundtrip() {
        for p in NodePolicy::ALL {
            assert_eq!(NodePolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(NodePolicy::parse("rr").unwrap(), NodePolicy::RoundRobin);
        assert_eq!(NodePolicy::parse("jsq").unwrap(), NodePolicy::JoinShortestQueue);
        assert_eq!(NodePolicy::parse("weighted").unwrap(), NodePolicy::WeightedCapacity);
        assert_eq!(NodePolicy::parse("wc").unwrap(), NodePolicy::WeightedCapacity);
        assert!(NodePolicy::parse("random").is_err());
    }
}
