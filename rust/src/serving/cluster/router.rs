//! The node-level router: dispatch the mixed stream across whole nodes.
//!
//! Two-tier dispatch: this router picks a *node* for every request, then
//! the node's own card router ([`crate::serving::fleet::router`], reused an
//! event at a time through [`NodePlanner`]) picks the replica and card.
//! Between the tiers sits the NIC: a request's bytes must clear the chosen
//! node's ingress link before its card router even sees it, and its fp16
//! response must clear the egress link before the caller counts it done —
//! so with enough offered load a cluster's throughput is capped by
//! `NicSpec.bw_bits`, not by its cards (the paper's network-bandwidth
//! requirement).
//!
//! The whole tier runs on one seeded event heap ([`crate::sim::des`]):
//! scenario events (drain/fail), arrivals, NIC deliveries, card
//! completions and batch-window timers all pop in modeled-time order, so a
//! node failure kills exactly the work that was in flight *at that
//! instant*, and dynamic batch growth composes with the NIC stages
//! unchanged. Identical seeds and traces give bit-identical plans
//! regardless of worker counts, because workers only execute numerics
//! afterwards.

use crate::obs::{RequestTrace, SegKind, SegRecord, StageBreakdown, Tracer};
use crate::serving::cluster::scenario::{EventKind, Scenario};
use crate::serving::cluster::{ClusterNode, WireModel};
use crate::serving::fleet::router::{self as fleet_router, NodePlanner, RouteStep};
use crate::serving::fleet::{Decision, Family, FleetConfig, FleetRequest, RoutePolicy, ShedCause};
use crate::sim::des::{class, EventHeap, EventId};
use crate::sim::transfer::NicOccupancy;
use crate::util::error::{bail, Result};

/// Node-selection policy for the top tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodePolicy {
    /// Rotate over the available nodes, blind to load and node speed.
    RoundRobin,
    /// Fewest outstanding segments across the node's cards.
    JoinShortestQueue,
    /// Least *modeled work*: send the request where cumulative assigned
    /// seconds (priced with each node's own per-family modeled cost) stays
    /// smallest. On a heterogeneous tier a slow node accumulates seconds
    /// faster, so it naturally receives fewer requests — capacity-weighted
    /// balancing without hand-set weights.
    WeightedCapacity,
}

impl NodePolicy {
    pub const ALL: [NodePolicy; 3] =
        [NodePolicy::RoundRobin, NodePolicy::JoinShortestQueue, NodePolicy::WeightedCapacity];

    pub fn name(self) -> &'static str {
        match self {
            NodePolicy::RoundRobin => "round-robin",
            NodePolicy::JoinShortestQueue => "join-shortest-queue",
            NodePolicy::WeightedCapacity => "weighted-by-modeled-capacity",
        }
    }

    pub fn parse(s: &str) -> Result<NodePolicy> {
        Ok(match s {
            "round-robin" | "rr" => NodePolicy::RoundRobin,
            "join-shortest-queue" | "jsq" => NodePolicy::JoinShortestQueue,
            "weighted-by-modeled-capacity" | "weighted" | "wc" => NodePolicy::WeightedCapacity,
            other => bail!(
                "unknown node policy '{other}' \
                 (valid: round-robin, join-shortest-queue, weighted-by-modeled-capacity)"
            ),
        })
    }
}

/// What happened to one request of the stream.
#[derive(Debug, Clone, Copy)]
pub enum Outcome {
    /// Routed, served, response delivered back over the node's NIC.
    Completed {
        node: usize,
        decision: Decision,
        latency_s: f64,
        finish_s: f64,
        /// Stage decomposition of `latency_s`; NIC queueing folds into the
        /// queue residual, wire serialization into `network_s`.
        stage: StageBreakdown,
    },
    /// The chosen node's card router shed it (bounded queue / SLA / no
    /// serving bucket).
    ShedAdmission { node: usize, cause: ShedCause },
    /// Admitted, but its node failed before the response was delivered.
    ShedFailed { node: usize },
    /// No node was available to route to (everything drained or failed).
    ShedUnroutable,
}

/// One planned request of the cluster pass.
#[derive(Debug, Clone)]
pub struct ClusterPlanned {
    pub family: Family,
    pub arrival_s: f64,
    pub items: usize,
    pub outcome: Outcome,
}

/// Per-node accounting of a cluster plan.
#[derive(Debug, Clone)]
pub struct NodeReport {
    /// Modeled compute seconds the node's cards spent (includes work that
    /// was later shed by a failure — the cards did burn that time).
    pub busy_s: f64,
    pub nic_rx_busy_s: f64,
    pub nic_tx_busy_s: f64,
    pub drained_at_s: Option<f64>,
    pub failed_at_s: Option<f64>,
}

/// The full cluster plan.
#[derive(Debug, Clone)]
pub struct ClusterPlan {
    pub planned: Vec<ClusterPlanned>,
    /// Last delivered response minus first arrival (0 when nothing
    /// completed).
    pub span_s: f64,
    pub nodes: Vec<NodeReport>,
}

/// Mutable per-node planning state.
struct NodeState {
    planner: NodePlanner,
    nic: NicOccupancy,
    up: bool,
    drained_at: Option<f64>,
    failed_at: Option<f64>,
    /// Cumulative modeled seconds routed here (weighted-capacity signal).
    assigned_s: f64,
    /// Requests picked for this node but still crossing its ingress NIC —
    /// the card router has not seen them yet, so they are invisible to
    /// `planner.outstanding()`; join-shortest-queue must count them too.
    pending: usize,
    /// Planned indices of requests admitted here and not yet delivered —
    /// what a failure sheds.
    inflight: Vec<usize>,
    /// Busy/NIC seconds accumulated before a failure reset the live state.
    busy_snapshot_s: f64,
    nic_rx_snapshot_s: f64,
    nic_tx_snapshot_s: f64,
}

/// Cluster-tier event payloads (request index, node index).
enum CEv {
    /// Scenario event `j` (index into [`Scenario::events`]) fires.
    Scenario(usize),
    /// Request `i` arrives at the cluster's front door.
    Arrive(usize),
    /// Request `idx`'s bytes cleared `node`'s ingress NIC.
    Deliver { idx: usize, node: usize },
    /// Request `idx`'s card service on `node` finished.
    CardDone { idx: usize, node: usize },
    /// Request `idx`'s response cleared `node`'s egress NIC.
    Delivered { idx: usize, node: usize },
    /// A dynamic-batch growth window on `node` closed (batch started).
    CloseBatch { node: usize, card: usize, gen: u64 },
}

/// Simulate the two-tier routing of `reqs` over the cluster on a seeded
/// event heap ([`FleetConfig::des_seed`]), with `scenario` drain/fail
/// events applied at their modeled instants.
pub fn plan(
    nodes: &[ClusterNode],
    reqs: &[FleetRequest],
    node_policy: NodePolicy,
    card_policy: RoutePolicy,
    cfg: &FleetConfig,
    scenario: &Scenario,
    wire: &WireModel,
) -> Result<ClusterPlan> {
    plan_traced(nodes, reqs, node_policy, card_policy, cfg, scenario, wire, None)
}

/// [`plan`] with an optional tracing sink ([`crate::obs`]). `None` is the
/// zero-cost path — bit-identical outcomes to an untraced run. `Some`
/// additionally records NIC/link/compute occupancy segments (per node) and
/// per-request lifecycle spans; the event schedule is untouched either way.
#[allow(clippy::too_many_arguments)]
pub fn plan_traced(
    nodes: &[ClusterNode],
    reqs: &[FleetRequest],
    node_policy: NodePolicy,
    card_policy: RoutePolicy,
    cfg: &FleetConfig,
    scenario: &Scenario,
    wire: &WireModel,
    mut tracer: Option<&mut Tracer>,
) -> Result<ClusterPlan> {
    if nodes.is_empty() {
        bail!("cluster needs at least one node");
    }
    for node in nodes {
        fleet_router::validate(node.replicas(), cfg)?;
    }
    scenario.validate(nodes.len())?;

    let n = nodes.len();
    let mut states: Vec<NodeState> = nodes
        .iter()
        .map(|c| NodeState {
            planner: NodePlanner::new(c.replicas().cards),
            nic: NicOccupancy::new(c.spec.nic.bw_bits),
            up: true,
            drained_at: None,
            failed_at: None,
            assigned_s: 0.0,
            pending: 0,
            inflight: Vec::new(),
            busy_snapshot_s: 0.0,
            nic_rx_snapshot_s: 0.0,
            nic_tx_snapshot_s: 0.0,
        })
        .collect();
    if tracer.is_some() {
        for s in &mut states {
            s.planner.enable_tape();
        }
    }

    let mut heap: EventHeap<CEv> = EventHeap::new(cfg.des_seed);
    let events = scenario.events();
    for (j, e) in events.iter().enumerate() {
        if !e.at_s.is_finite() {
            bail!("scenario event {j} has a non-finite time {}", e.at_s);
        }
        heap.push_class(e.at_s, class::SCENARIO, CEv::Scenario(j));
    }
    let mut planned: Vec<ClusterPlanned> = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        let t = req.arrival_s();
        if !t.is_finite() {
            bail!("cluster request {i} has a non-finite arrival time {t}");
        }
        planned.push(ClusterPlanned {
            family: req.family(),
            arrival_s: t,
            items: req.items(),
            // placeholder; every request's terminal outcome is written by
            // its own events (or the failure that killed it)
            outcome: Outcome::ShedUnroutable,
        });
        heap.push(t, CEv::Arrive(i));
    }

    // per-request handle to its *next* pending event (NIC delivery, card
    // completion, or response delivery) — what a node failure cancels
    let mut stage_ev: Vec<Option<EventId>> = vec![None; reqs.len()];
    let mut decisions: Vec<Option<Decision>> = vec![None; reqs.len()];
    // per-request card-tier stage attribution, finalized at delivery (NIC
    // queueing becomes the queue residual, wire time becomes network_s)
    let mut stages: Vec<StageBreakdown> = vec![StageBreakdown::default(); reqs.len()];
    let mut card_finish: Vec<f64> = vec![0.0; reqs.len()];
    let mut cards: Vec<usize> = vec![0; reqs.len()];
    let mut rr = 0usize;

    while let Some(e) = heap.pop() {
        let t = e.at_s;
        match e.kind {
            CEv::Scenario(j) => {
                let ev = &events[j];
                let state = &mut states[ev.node];
                match ev.kind {
                    EventKind::Drain => {
                        if state.up {
                            state.up = false;
                            state.drained_at = Some(t);
                        }
                    }
                    EventKind::Fail => {
                        if state.failed_at.is_some() {
                            continue;
                        }
                        state.up = false;
                        state.failed_at = Some(t);
                        // everything still in flight here dies with the node
                        for idx in state.inflight.drain(..) {
                            if let Some(id) = stage_ev[idx].take() {
                                heap.cancel(id);
                            }
                            planned[idx].outcome = Outcome::ShedFailed { node: ev.node };
                        }
                        state.pending = 0;
                        let busy: f64 = state.planner.busy_s().iter().sum();
                        state.busy_snapshot_s += busy;
                        state.nic_rx_snapshot_s += state.nic.rx_busy_s();
                        state.nic_tx_snapshot_s += state.nic.tx_busy_s();
                        state.planner.reset();
                        state.nic.reset();
                    }
                }
            }
            CEv::Arrive(i) => {
                let req = &reqs[i];
                let family = req.family();
                // tier 1: pick a node (every policy breaks ties toward the
                // lowest node id, so the choice is deterministic)
                let pick = match node_policy {
                    NodePolicy::RoundRobin => {
                        let mut pick = None;
                        for step in 0..n {
                            let k = (rr + step) % n;
                            if states[k].up {
                                pick = Some(k);
                                rr = (k + 1) % n;
                                break;
                            }
                        }
                        pick
                    }
                    NodePolicy::JoinShortestQueue => {
                        let mut best: Option<(usize, usize)> = None;
                        for k in 0..n {
                            if !states[k].up {
                                continue;
                            }
                            states[k].planner.prune(t);
                            let d = states[k].planner.outstanding() + states[k].pending;
                            if best.map_or(true, |(bd, _)| d < bd) {
                                best = Some((d, k));
                            }
                        }
                        best.map(|(_, k)| k)
                    }
                    NodePolicy::WeightedCapacity => {
                        let mut best: Option<(f64, usize)> = None;
                        for k in 0..n {
                            if !states[k].up {
                                continue;
                            }
                            let proj = states[k].assigned_s + nodes[k].fam_cost_s[family.index()];
                            if best.map_or(true, |(bp, _)| proj < bp) {
                                best = Some((proj, k));
                            }
                        }
                        best.map(|(_, k)| k)
                    }
                };
                match pick {
                    None => planned[i].outcome = Outcome::ShedUnroutable,
                    Some(k) => {
                        // tier 1.5: the bytes serialize on the node's NIC
                        let (in_bytes, _) = wire.bytes(req);
                        let state = &mut states[k];
                        let rx_from = state.nic.rx_until().max(t);
                        let t_node = state.nic.rx(t, in_bytes);
                        if let Some(tr) = tracer.as_deref_mut() {
                            if t_node > rx_from {
                                tr.seg(SegRecord {
                                    kind: SegKind::NicRx,
                                    node: k,
                                    lane: 0,
                                    start_s: rx_from,
                                    end_s: t_node,
                                    req: i,
                                    dram: 0.0,
                                });
                            }
                        }
                        state.assigned_s += nodes[k].fam_cost_s[family.index()];
                        state.pending += 1;
                        state.inflight.push(i);
                        stage_ev[i] =
                            Some(heap.push(t_node, CEv::Deliver { idx: i, node: k }));
                    }
                }
            }
            CEv::Deliver { idx, node } => {
                stage_ev[idx] = None;
                let state = &mut states[node];
                state.pending -= 1;
                // tier 2: the node's own card router, one event step
                match state.planner.step(
                    nodes[node].replicas(),
                    &reqs[idx],
                    idx,
                    t,
                    card_policy,
                    cfg,
                ) {
                    RouteStep::Shed(cause) => {
                        planned[idx].outcome = Outcome::ShedAdmission { node, cause };
                        state.inflight.retain(|&x| x != idx);
                    }
                    RouteStep::Routed { routed, opened } => {
                        decisions[idx] = Some(routed.decision);
                        stages[idx] = routed.stage;
                        card_finish[idx] = routed.finish_s;
                        cards[idx] = routed.card;
                        stage_ev[idx] = Some(heap.push_class(
                            routed.finish_s,
                            class::COMPLETION,
                            CEv::CardDone { idx, node },
                        ));
                        if let Some(tk) = opened {
                            heap.push_class(
                                tk.start_s,
                                class::TIMER,
                                CEv::CloseBatch { node, card: tk.card, gen: tk.gen },
                            );
                        }
                    }
                    RouteStep::Merged { routed, members } => {
                        decisions[idx] = Some(routed.decision);
                        stages[idx] = routed.stage;
                        card_finish[idx] = routed.finish_s;
                        cards[idx] = routed.card;
                        // the grown batch finishes together: supersede the
                        // members' (still unstarted) card completions
                        for m in members {
                            if let Some(id) = stage_ev[m].take() {
                                heap.cancel(id);
                            }
                            // the member's batch ran longer: extra compute
                            stages[m].compute_s += routed.finish_s - card_finish[m];
                            card_finish[m] = routed.finish_s;
                            stage_ev[m] = Some(heap.push_class(
                                routed.finish_s,
                                class::COMPLETION,
                                CEv::CardDone { idx: m, node },
                            ));
                        }
                        stage_ev[idx] = Some(heap.push_class(
                            routed.finish_s,
                            class::COMPLETION,
                            CEv::CardDone { idx, node },
                        ));
                    }
                }
            }
            CEv::CardDone { idx, node } => {
                let state = &mut states[node];
                state.planner.prune(t);
                // the fp16 response serializes on the egress NIC
                let (_, out_bytes) = wire.bytes(&reqs[idx]);
                let tx_from = state.nic.tx_until().max(t);
                let delivered = state.nic.tx(t, out_bytes);
                if let Some(tr) = tracer.as_deref_mut() {
                    if delivered > tx_from {
                        tr.seg(SegRecord {
                            kind: SegKind::NicTx,
                            node,
                            lane: 0,
                            start_s: tx_from,
                            end_s: delivered,
                            req: idx,
                            dram: 0.0,
                        });
                    }
                }
                stage_ev[idx] = Some(heap.push_class(
                    delivered,
                    class::COMPLETION,
                    CEv::Delivered { idx, node },
                ));
            }
            CEv::Delivered { idx, node } => {
                stage_ev[idx] = None;
                let state = &mut states[node];
                state.inflight.retain(|&x| x != idx);
                let latency_s = t - planned[idx].arrival_s;
                // pure wire time is network; NIC *queueing* (both ways)
                // lands in the queue residual, like any other contention
                let (in_bytes, out_bytes) = wire.bytes(&reqs[idx]);
                let network_s = state.nic.time_s(in_bytes) + state.nic.time_s(out_bytes);
                let s = stages[idx];
                planned[idx].outcome = Outcome::Completed {
                    node,
                    decision: decisions[idx].expect("delivered request must have a decision"),
                    latency_s,
                    finish_s: t,
                    stage: StageBreakdown::attribute(
                        latency_s,
                        s.batch_wait_s,
                        s.transfer_s,
                        s.compute_s,
                        network_s,
                    ),
                };
            }
            CEv::CloseBatch { node, card, gen } => {
                states[node].planner.close_batch(card, gen);
            }
        }
    }

    let first_arrival = planned.iter().map(|p| p.arrival_s).fold(f64::INFINITY, f64::min);
    let mut max_finish: Option<f64> = None;
    for p in &planned {
        if let Outcome::Completed { finish_s, .. } = p.outcome {
            max_finish = Some(max_finish.map_or(finish_s, |m: f64| m.max(finish_s)));
        }
    }
    let span_s = match max_finish {
        Some(finish) if first_arrival.is_finite() => (finish - first_arrival).max(0.0),
        _ => 0.0,
    };
    let node_reports = states
        .iter()
        .map(|s| NodeReport {
            busy_s: s.busy_snapshot_s + s.planner.busy_s().iter().sum::<f64>(),
            nic_rx_busy_s: s.nic_rx_snapshot_s + s.nic.rx_busy_s(),
            nic_tx_busy_s: s.nic_tx_snapshot_s + s.nic.tx_busy_s(),
            drained_at_s: s.drained_at,
            failed_at_s: s.failed_at,
        })
        .collect();
    if let Some(tr) = tracer {
        for (k, s) in states.iter_mut().enumerate() {
            let tape = s.planner.take_tape();
            tr.extend_segs(k, tape);
        }
        for (i, p) in planned.iter().enumerate() {
            let (node, card, finish_s, stage, outcome) = match p.outcome {
                Outcome::Completed { node, finish_s, stage, .. } => {
                    (node, cards[i], finish_s, stage, "completed")
                }
                Outcome::ShedAdmission { node, cause } => {
                    (node, 0, p.arrival_s, StageBreakdown::default(), cause.name())
                }
                Outcome::ShedFailed { node } => {
                    (node, 0, p.arrival_s, StageBreakdown::default(), "shed-failed")
                }
                Outcome::ShedUnroutable => {
                    (0, 0, p.arrival_s, StageBreakdown::default(), "shed-unroutable")
                }
            };
            tr.request(RequestTrace {
                req: i,
                family: p.family.name(),
                node,
                card,
                arrival_s: p.arrival_s,
                finish_s,
                stage,
                outcome,
            });
        }
    }
    Ok(ClusterPlan { planned, span_s, nodes: node_reports })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_policy_parse_roundtrip() {
        for p in NodePolicy::ALL {
            assert_eq!(NodePolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(NodePolicy::parse("rr").unwrap(), NodePolicy::RoundRobin);
        assert_eq!(NodePolicy::parse("jsq").unwrap(), NodePolicy::JoinShortestQueue);
        assert_eq!(NodePolicy::parse("weighted").unwrap(), NodePolicy::WeightedCapacity);
        assert_eq!(NodePolicy::parse("wc").unwrap(), NodePolicy::WeightedCapacity);
        assert!(NodePolicy::parse("random").is_err());
    }
}
