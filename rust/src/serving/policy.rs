//! Policy registry: `*_by_name` lookups for the routing and placement
//! policies, mirroring [`crate::runtime::backend_by_name`].
//!
//! The CLI (`fbia fleet`, `fbia cluster`), the config JSON parser and the
//! [`Simulation`](crate::serving::simulation::Simulation) builder all
//! resolve policy names through this module, so an unknown name fails the
//! same way everywhere: an error listing the valid canonical names. The
//! underlying `parse` methods keep accepting their short aliases (`rr`,
//! `la`, `jsq`, ...) — the registry adds the single source of truth for
//! what exists, not a new grammar.

use crate::serving::cluster::NodePolicy;
use crate::serving::fleet::{Placement, RoutePolicy};
use crate::util::error::{err, Result};

/// Canonical card-router (within-node) policy names.
pub const CARD_POLICY_NAMES: &[&str] =
    &["round-robin", "least-outstanding", "latency-aware"];

/// Canonical node-router (cross-node) policy names.
pub const NODE_POLICY_NAMES: &[&str] =
    &["round-robin", "join-shortest-queue", "weighted-by-modeled-capacity"];

/// Canonical replica-placement policy names.
pub const PLACEMENT_NAMES: &[&str] = &["pack", "spread", "sls-affine"];

/// Resolve a card-routing policy by name (aliases `rr`/`lo`/`la` accepted).
pub fn card_policy_by_name(name: &str) -> Result<RoutePolicy> {
    RoutePolicy::parse(name).map_err(|_| {
        err!(
            "unknown card policy '{name}' (valid policies: {})",
            CARD_POLICY_NAMES.join(", ")
        )
    })
}

/// Resolve a node-routing policy by name (aliases `rr`/`jsq`/`weighted`/`wc`
/// accepted).
pub fn node_policy_by_name(name: &str) -> Result<NodePolicy> {
    NodePolicy::parse(name).map_err(|_| {
        err!(
            "unknown node policy '{name}' (valid policies: {})",
            NODE_POLICY_NAMES.join(", ")
        )
    })
}

/// Resolve a replica placement by name (alias `affine` accepted).
pub fn placement_by_name(name: &str) -> Result<Placement> {
    Placement::parse(name).map_err(|_| {
        err!(
            "unknown placement '{name}' (valid placements: {})",
            PLACEMENT_NAMES.join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_canonical_names_and_aliases() {
        for name in CARD_POLICY_NAMES {
            assert_eq!(card_policy_by_name(name).unwrap().name(), *name);
        }
        for name in NODE_POLICY_NAMES {
            assert_eq!(node_policy_by_name(name).unwrap().name(), *name);
        }
        for name in PLACEMENT_NAMES {
            assert_eq!(placement_by_name(name).unwrap().name(), *name);
        }
        assert_eq!(card_policy_by_name("la").unwrap(), RoutePolicy::LatencyAware);
        assert_eq!(node_policy_by_name("jsq").unwrap(), NodePolicy::JoinShortestQueue);
        assert_eq!(placement_by_name("affine").unwrap(), Placement::SlsAffine);
    }

    #[test]
    fn unknown_names_list_the_valid_set() {
        let e = card_policy_by_name("bogus").unwrap_err().to_string();
        assert!(e.contains("bogus") && e.contains("latency-aware"), "{e}");
        let e = node_policy_by_name("bogus").unwrap_err().to_string();
        assert!(e.contains("bogus") && e.contains("join-shortest-queue"), "{e}");
        let e = placement_by_name("bogus").unwrap_err().to_string();
        assert!(e.contains("bogus") && e.contains("sls-affine"), "{e}");
    }
}
