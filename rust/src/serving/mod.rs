//! Serving stack (§IV-A, §IV-C runtime): the request-path binary logic.
//!
//! Real numerics flow through the engine's execution backend
//! ([`crate::runtime`] — the reference interpreter by default, PJRT with
//! `--features pjrt`); the servers here implement the paper's serving
//! structure — partitioned + pipelined DLRM (Fig. 6), bucket-switched XLM-R
//! (§VI-A), batched CV — over the artifact manifest, with multi-threaded
//! request handling and latency/QPS metrics.

pub mod batcher;

use crate::numerics::weights::WeightGen;
use crate::numerics::HostTensor;
use crate::runtime::artifact::table_index;
use crate::runtime::{Engine, PreparedModel};
use crate::util::error::{err, Context, Result};
use crate::util::stats::Histogram;
use crate::util::threadpool::ThreadPool;
use crate::workloads::RecsysRequest;
use batcher::{Batcher, NlpBatch};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Seed used for artifact weights everywhere (runtime uploads and reference
/// validation must agree).
pub const WEIGHT_SEED: u64 = 0xFB1A_2021;

/// Serving metrics: latency histogram + throughput.
#[derive(Debug, Clone)]
pub struct ServerMetrics {
    pub latency: Histogram,
    pub completed: usize,
    pub items: usize,
    pub wall_s: f64,
}

impl ServerMetrics {
    pub fn qps(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    pub fn items_per_s(&self) -> f64 {
        self.items as f64 / self.wall_s.max(1e-9)
    }
}

/// Fan `n` closed-loop work units out to `workers` pool threads. Each
/// worker pulls the next unit index, times `f(i)`, and accumulates a
/// per-worker latency histogram (merged at the end, so no lock sits on the
/// hot path). `f` returns the number of items the unit served;
/// `sample_per_item` controls whether the unit's latency is recorded once
/// per unit (whole-request models) or once per item (sentence batches).
/// The first error stops the remaining workers (best-effort) and is
/// returned. Result: (latency, units completed, items served).
fn fan_out_workers<F>(
    workers: usize,
    n: usize,
    sample_per_item: bool,
    f: F,
) -> Result<(Histogram, usize, usize)>
where
    F: Fn(usize) -> Result<usize> + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let next = Arc::new(AtomicUsize::new(0));
    let failed = Arc::new(AtomicBool::new(false));
    let pool = ThreadPool::new(workers);
    let (tx, rx) = mpsc::channel::<Result<(Histogram, usize, usize)>>();
    for _ in 0..workers {
        let f = Arc::clone(&f);
        let next = Arc::clone(&next);
        let failed = Arc::clone(&failed);
        let tx = tx.clone();
        pool.execute(move || {
            let mut latency = Histogram::latency();
            let (mut completed, mut items) = (0usize, 0usize);
            let res = loop {
                if failed.load(Ordering::Relaxed) {
                    break Ok(());
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break Ok(());
                }
                let t0 = Instant::now();
                match f(i) {
                    Ok(k) => {
                        let dt = t0.elapsed().as_secs_f64();
                        for _ in 0..if sample_per_item { k } else { 1 } {
                            latency.add(dt);
                        }
                        completed += 1;
                        items += k;
                    }
                    Err(e) => {
                        failed.store(true, Ordering::Relaxed);
                        break Err(e);
                    }
                }
            };
            let _ = tx.send(res.map(|()| (latency, completed, items)));
        });
    }
    drop(tx);
    let mut latency = Histogram::latency();
    let (mut completed, mut items) = (0usize, 0usize);
    let mut first_err = None;
    for res in rx.iter() {
        match res {
            Ok((h, c, k)) => {
                latency.merge(&h);
                completed += c;
                items += k;
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        // a worker that claimed an index but never reported (panicked job)
        // must not surface as silently under-counted metrics
        None if completed != n => {
            Err(err!("worker exited without reporting ({completed} of {n} units completed)"))
        }
        None => Ok((latency, completed, items)),
    }
}

// ---------------------------------------------------------------------------
// DLRM: partitioned + pipelined (Fig. 6)
// ---------------------------------------------------------------------------

/// Sharded, pipelined recommendation server.
pub struct RecsysServer {
    /// (global table ids, prepared shard) per SLS card.
    shards: Vec<(Vec<usize>, Arc<PreparedModel>)>,
    dense: Arc<PreparedModel>,
    /// Pool for intra-request shard fan-out; `None` → shards run
    /// sequentially on the caller's thread.
    sls_pool: Option<ThreadPool>,
    pub batch: usize,
    pub num_tables: usize,
    pub embed_dim: usize,
}

impl RecsysServer {
    /// Load shards + dense for a batch size and precision ("fp32"/"int8"),
    /// with sequential per-card SLS execution.
    pub fn new(engine: Arc<Engine>, batch: usize, precision: &str) -> Result<RecsysServer> {
        RecsysServer::with_threads(engine, batch, precision, 1)
    }

    /// Like [`RecsysServer::new`], but with `threads > 1` the per-card SLS
    /// shards of one request execute in parallel on a dedicated pool — the
    /// paper's six-cards-per-request partitioning (Fig. 6 left) mapped onto
    /// host threads.
    pub fn with_threads(
        engine: Arc<Engine>,
        batch: usize,
        precision: &str,
        threads: usize,
    ) -> Result<RecsysServer> {
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let num_tables = engine.manifest().config_usize("dlrm", "num_tables")?;
        let embed_dim = engine.manifest().config_usize("dlrm", "embed_dim")?;

        let mut shards = Vec::new();
        for art in engine.manifest().select("dlrm", "sls") {
            if art.batch != batch {
                continue;
            }
            // global table ids from the input spec names (idx{t})
            let tables: Vec<usize> = art
                .inputs
                .iter()
                .filter(|s| s.name.starts_with("idx"))
                .map(|s| table_index(&s.name, "idx"))
                .collect::<Result<_>>()
                .with_context(|| format!("artifact {}", art.name))?;
            if tables.is_empty() {
                return Err(err!("sls artifact {} declares no idx inputs", art.name));
            }
            if let Some(&t) = tables.iter().find(|&&t| t >= num_tables) {
                return Err(err!(
                    "sls artifact {} references table {t} but configs.dlrm.num_tables is \
                     {num_tables}",
                    art.name
                ));
            }
            let weights = gen.weights_for(art);
            let prepared = engine.prepare(&art.name, weights)?;
            shards.push((tables, Arc::new(prepared)));
        }
        if shards.is_empty() {
            return Err(err!("no dlrm sls shards for batch {batch} in the manifest"));
        }
        shards.sort_by_key(|(t, _)| t[0]);

        let dense_name = format!("dlrm_dense_b{batch}_{precision}");
        let art = engine.manifest().get(&dense_name)?.clone();
        let weights = gen.weights_for(&art);
        let dense = Arc::new(engine.prepare(&dense_name, weights)?);

        let sls_pool = (threads > 1 && shards.len() > 1)
            .then(|| ThreadPool::new(threads.min(shards.len())));
        Ok(RecsysServer { shards, dense, sls_pool, batch, num_tables, embed_dim })
    }

    /// Run the SLS partition for one request: returns [batch, T, D] pooled.
    /// With a shard pool (see [`RecsysServer::with_threads`]) the per-card
    /// shards execute concurrently; otherwise sequentially.
    pub fn run_sls(&self, req: &RecsysRequest) -> Result<HostTensor> {
        // table count is request data: validate before indexing into it
        if req.indices.len() != self.num_tables || req.lengths.len() != self.num_tables {
            return Err(err!(
                "request carries {} index / {} length tensors but the model has {} tables",
                req.indices.len(),
                req.lengths.len(),
                self.num_tables
            ));
        }
        match &self.sls_pool {
            Some(pool) => self.run_sls_parallel(pool, req),
            None => self.run_sls_sequential(req),
        }
    }

    fn run_sls_sequential(&self, req: &RecsysRequest) -> Result<HostTensor> {
        let b = self.batch;
        let d = self.embed_dim;
        let mut sparse = vec![0f32; b * self.num_tables * d];
        for (tables, shard) in &self.shards {
            let mut inputs: Vec<&HostTensor> = Vec::with_capacity(tables.len() * 2);
            for &t in tables {
                inputs.push(&req.indices[t]);
                inputs.push(&req.lengths[t]);
            }
            let out = shard.run_refs(&inputs)?;
            let pooled = out[0]
                .as_f32()
                .ok_or_else(|| err!("sls output not f32"))?;
            self.scatter_shard(&mut sparse, tables, pooled);
        }
        Ok(HostTensor::f32(sparse, &[b, self.num_tables, d]))
    }

    /// Per-card shards of ONE request in flight together. Shard jobs must be
    /// `'static` for the pool, so they share the prepared model by `Arc` and
    /// clone the small per-table index/length tensors (activations move per
    /// request — §VI-C; the weights stay resident behind the Arc).
    fn run_sls_parallel(&self, pool: &ThreadPool, req: &RecsysRequest) -> Result<HostTensor> {
        let b = self.batch;
        let d = self.embed_dim;
        let (tx, rx) = mpsc::channel::<(usize, Result<Vec<HostTensor>>)>();
        for (si, (tables, shard)) in self.shards.iter().enumerate() {
            let shard = Arc::clone(shard);
            let inputs: Vec<HostTensor> = tables
                .iter()
                .flat_map(|&t| [req.indices[t].clone(), req.lengths[t].clone()])
                .collect();
            let tx = tx.clone();
            pool.execute(move || {
                let _ = tx.send((si, shard.run(&inputs)));
            });
        }
        drop(tx);
        let mut sparse = vec![0f32; b * self.num_tables * d];
        let mut seen = 0usize;
        for (si, res) in rx.iter() {
            let out = res.with_context(|| format!("sls shard {si}"))?;
            let pooled = out[0]
                .as_f32()
                .ok_or_else(|| err!("sls output not f32"))?;
            self.scatter_shard(&mut sparse, &self.shards[si].0, pooled);
            seen += 1;
        }
        if seen != self.shards.len() {
            return Err(err!("sls shard worker exited without reporting"));
        }
        Ok(HostTensor::f32(sparse, &[b, self.num_tables, d]))
    }

    /// Scatter one shard's pooled output [b, n_shard, d] into [b, T, d].
    fn scatter_shard(&self, sparse: &mut [f32], tables: &[usize], pooled: &[f32]) {
        let d = self.embed_dim;
        for bi in 0..self.batch {
            for (si, &t) in tables.iter().enumerate() {
                let src = (bi * tables.len() + si) * d;
                let dst = (bi * self.num_tables + t) * d;
                sparse[dst..dst + d].copy_from_slice(&pooled[src..src + d]);
            }
        }
    }

    /// Run the dense partition: scores [batch, 1].
    pub fn run_dense(&self, dense: &HostTensor, sparse: &HostTensor) -> Result<HostTensor> {
        let mut out = self
            .dense
            .run_refs(&[dense, sparse])
            .context("dense partition")?;
        Ok(out.swap_remove(0))
    }

    /// Full inference for one request.
    pub fn infer(&self, req: &RecsysRequest) -> Result<HostTensor> {
        let sparse = self.run_sls(req)?;
        self.run_dense(&req.dense, &sparse)
    }

    /// Closed-loop serving of `reqs` with cross-request pipelining: request
    /// k's SLS overlaps request k-1's dense (Fig. 6 right). Returns metrics.
    pub fn serve(self: &Arc<Self>, reqs: Vec<RecsysRequest>) -> Result<ServerMetrics> {
        let (tx, rx) = mpsc::sync_channel::<(usize, Instant, HostTensor, HostTensor)>(2);
        let me = Arc::clone(self);
        let producer = std::thread::spawn(move || -> Result<()> {
            for (i, req) in reqs.into_iter().enumerate() {
                let t0 = Instant::now();
                let sparse = me.run_sls(&req)?;
                tx.send((i, t0, req.dense, sparse)).map_err(|_| err!("dense stage gone"))?;
            }
            Ok(())
        });

        let mut latency = Histogram::latency();
        let wall0 = Instant::now();
        let mut completed = 0usize;
        for (_i, t0, dense, sparse) in rx.iter() {
            let _scores = self.run_dense(&dense, &sparse)?;
            latency.add(t0.elapsed().as_secs_f64());
            completed += 1;
        }
        producer.join().map_err(|_| err!("producer panicked"))??;
        let wall_s = wall0.elapsed().as_secs_f64();
        Ok(ServerMetrics { latency, completed, items: completed * self.batch, wall_s })
    }

    /// Closed-loop serving with `workers` whole requests in flight — the
    /// intra-host parallelism knob (`--threads`). Each worker pulls the next
    /// request and runs its full SLS→dense path; per-worker latency
    /// histograms are merged at the end. `workers == 1` is the strictly
    /// sequential single-thread baseline the fig7 thread-scaling points
    /// compare against.
    pub fn serve_workers(
        self: &Arc<Self>,
        reqs: Vec<RecsysRequest>,
        workers: usize,
    ) -> Result<ServerMetrics> {
        let n = reqs.len();
        let wall0 = Instant::now();
        if workers <= 1 {
            let mut latency = Histogram::latency();
            for req in &reqs {
                let t0 = Instant::now();
                self.infer(req)?;
                latency.add(t0.elapsed().as_secs_f64());
            }
            let wall_s = wall0.elapsed().as_secs_f64();
            return Ok(ServerMetrics { latency, completed: n, items: n * self.batch, wall_s });
        }
        let me = Arc::clone(self);
        let reqs = Arc::new(reqs);
        let (latency, completed, items) = fan_out_workers(workers, n, false, move |i| {
            me.infer(&reqs[i]).map(|_| me.batch)
        })?;
        let wall_s = wall0.elapsed().as_secs_f64();
        Ok(ServerMetrics { latency, completed, items, wall_s })
    }
}

// ---------------------------------------------------------------------------
// XLM-R: bucket-switched serving (§VI-A)
// ---------------------------------------------------------------------------

/// NLP server holding one prepared network per (seq bucket, batch) pair and
/// a dynamic batcher.
pub struct NlpServer {
    /// (seq, batch) -> prepared model
    nets: Vec<(usize, usize, Arc<PreparedModel>)>,
    pub buckets: Vec<usize>,
    pub d_model: usize,
}

impl NlpServer {
    pub fn new(engine: Arc<Engine>) -> Result<NlpServer> {
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let mut nets = Vec::new();
        let mut buckets = Vec::new();
        for art in engine.manifest().select("xlmr", "full") {
            let seq = art.seq.ok_or_else(|| err!("xlmr artifact missing seq"))?;
            let weights = gen.weights_for(art);
            let prepared = engine.prepare(&art.name, weights)?;
            nets.push((seq, art.batch, Arc::new(prepared)));
            if !buckets.contains(&seq) {
                buckets.push(seq);
            }
        }
        if nets.is_empty() {
            return Err(err!("no xlmr artifacts in the manifest"));
        }
        buckets.sort_unstable();
        let d_model = engine.manifest().config_usize("xlmr", "d_model")?;
        Ok(NlpServer { nets, buckets, d_model })
    }

    /// Find the prepared net for a bucket with the smallest batch >= n.
    fn net_for(&self, bucket: usize, n: usize) -> Result<(usize, &Arc<PreparedModel>)> {
        self.nets
            .iter()
            .filter(|(s, b, _)| *s == bucket && *b >= n)
            .min_by_key(|(_, b, _)| *b)
            .map(|(_, b, m)| (*b, m))
            .ok_or_else(|| err!("no xlmr net for bucket {bucket} x batch {n}"))
    }

    /// Largest batch every bucket has a compiled variant for — the cap on
    /// `max_batch` in [`NlpServer::serve`]. A batch formed above this would
    /// only fail mid-stream inside `net_for`, so `serve` validates against
    /// it up front.
    pub fn max_supported_batch(&self) -> usize {
        self.buckets
            .iter()
            .map(|&s| {
                self.nets
                    .iter()
                    .filter(|(ns, _, _)| *ns == s)
                    .map(|(_, b, _)| *b)
                    .max()
                    .unwrap_or(0)
            })
            .min()
            .unwrap_or(0)
    }

    /// Run one formed batch; returns pooled embeddings [n, d_model].
    pub fn run_batch(&self, batch: &NlpBatch) -> Result<Vec<Vec<f32>>> {
        let n = batch.requests.len();
        let (rows, net) = self.net_for(batch.bucket, n)?;
        let (ids, lens) = batcher::pad_batch(batch, rows);
        let out = net.run(&[
            HostTensor::i32(ids, &[rows, batch.bucket]),
            HostTensor::i32(lens, &[rows]),
        ])?;
        let pooled = out[0].as_f32().ok_or_else(|| err!("pooled not f32"))?;
        Ok((0..n).map(|i| pooled[i * self.d_model..(i + 1) * self.d_model].to_vec()).collect())
    }

    /// Serve a request stream through the batcher with `workers` batches in
    /// flight. Returns metrics plus the padded-vs-real token accounting
    /// (the batching-efficiency signal). `max_batch` is validated against
    /// the compiled batch variants before any batch forms.
    pub fn serve(
        self: &Arc<Self>,
        reqs: Vec<crate::workloads::NlpRequest>,
        max_batch: usize,
        length_aware: bool,
        workers: usize,
    ) -> Result<(ServerMetrics, f64)> {
        if max_batch == 0 {
            return Err(err!("max_batch must be >= 1"));
        }
        let cap = self.max_supported_batch();
        if max_batch > cap {
            return Err(err!(
                "max_batch {max_batch} exceeds the largest batch compiled for every \
                 bucket ({cap}); compiled (seq, batch) variants: {:?}",
                self.nets.iter().map(|(s, b, _)| (*s, *b)).collect::<Vec<_>>()
            ));
        }
        let wall0 = Instant::now();
        let mut b = Batcher::new(self.buckets.clone(), max_batch, length_aware);

        if workers <= 1 {
            // stream: run each batch as it forms (O(max_batch) memory)
            let mut latency = Histogram::latency();
            let (mut completed, mut items, mut padded, mut real) = (0usize, 0usize, 0usize, 0usize);
            let mut run = |batch: &NlpBatch| -> Result<()> {
                let t0 = Instant::now();
                self.run_batch(batch)?;
                let dt = t0.elapsed().as_secs_f64();
                for _ in 0..batch.requests.len() {
                    latency.add(dt);
                }
                completed += 1;
                items += batch.requests.len();
                padded += batch.padded_tokens();
                real += batch.real_tokens();
                Ok(())
            };
            for r in reqs {
                b.push(r);
                while let Some(batch) = b.pop(false) {
                    run(&batch)?;
                }
            }
            for batch in b.drain() {
                run(&batch)?;
            }
            let wall_s = wall0.elapsed().as_secs_f64();
            let waste = 1.0 - real as f64 / padded.max(1) as f64;
            return Ok((ServerMetrics { latency, completed, items, wall_s }, waste));
        }

        // workers share the formed batches, so materialize them first
        let mut batches = Vec::new();
        for r in reqs {
            b.push(r);
            while let Some(batch) = b.pop(false) {
                batches.push(batch);
            }
        }
        batches.extend(b.drain());
        let (mut padded, mut real) = (0usize, 0usize);
        for batch in &batches {
            padded += batch.padded_tokens();
            real += batch.real_tokens();
        }
        let n = batches.len();
        let me = Arc::clone(self);
        let batches = Arc::new(batches);
        let (latency, completed, items) = fan_out_workers(workers, n, true, move |i| {
            me.run_batch(&batches[i]).map(|_| batches[i].requests.len())
        })?;
        let wall_s = wall0.elapsed().as_secs_f64();
        let waste = 1.0 - real as f64 / padded.max(1) as f64;
        Ok((ServerMetrics { latency, completed, items, wall_s }, waste))
    }
}

// ---------------------------------------------------------------------------
// CV: batched single-card serving
// ---------------------------------------------------------------------------

/// CV trunk server with batch-variant selection.
pub struct CvServer {
    nets: Vec<(usize, Arc<PreparedModel>)>,
    pub image: usize,
    pub classes: usize,
}

impl CvServer {
    pub fn new(engine: Arc<Engine>) -> Result<CvServer> {
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let mut nets = Vec::new();
        for art in engine.manifest().select("cv", "full") {
            let weights = gen.weights_for(art);
            let prepared = engine.prepare(&art.name, weights)?;
            nets.push((art.batch, Arc::new(prepared)));
        }
        if nets.is_empty() {
            return Err(err!("no cv artifacts in the manifest"));
        }
        nets.sort_by_key(|(b, _)| *b);
        Ok(CvServer {
            nets,
            image: engine.manifest().config_usize("cv", "image")?,
            classes: engine.manifest().config_usize("cv", "classes")?,
        })
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.nets.iter().map(|(b, _)| *b).collect()
    }

    /// Classify a batch (image tensor shaped [b, h, w, 3] where b matches a
    /// compiled variant). Returns (logits, embedding).
    pub fn infer(&self, image: &HostTensor) -> Result<(HostTensor, HostTensor)> {
        let b = image.shape()[0];
        let net = self
            .nets
            .iter()
            .find(|(nb, _)| *nb == b)
            .map(|(_, m)| m)
            .ok_or_else(|| err!("no cv net compiled for batch {b}"))?;
        let mut out = net.run_refs(&[image])?;
        let emb = out.pop().ok_or_else(|| err!("cv output missing embedding"))?;
        let logits = out.pop().ok_or_else(|| err!("cv output missing logits"))?;
        Ok((logits, emb))
    }

    /// Closed-loop throughput at a batch size with `workers` requests in
    /// flight (`workers == 1` → sequential baseline).
    pub fn serve(
        self: &Arc<Self>,
        n: usize,
        batch: usize,
        gen: &mut crate::workloads::CvGen,
        workers: usize,
    ) -> Result<ServerMetrics> {
        // batch is part of the request contract: validate against the
        // compiled variants before generating anything
        if !self.nets.iter().any(|(nb, _)| *nb == batch) {
            return Err(err!(
                "no cv net compiled for batch {batch} (variants: {:?})",
                self.batch_sizes()
            ));
        }
        if workers <= 1 {
            // stream requests (O(1) memory regardless of n), excluding
            // generation from the wall clock so this measures the same
            // thing as the threaded branch, which pre-materializes
            let wall0 = Instant::now();
            let mut gen_s = 0.0f64;
            let mut latency = Histogram::latency();
            for _ in 0..n {
                let g0 = Instant::now();
                let req = gen.next(batch);
                gen_s += g0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                self.infer(&req.image)?;
                latency.add(t0.elapsed().as_secs_f64());
            }
            let wall_s = (wall0.elapsed().as_secs_f64() - gen_s).max(0.0);
            return Ok(ServerMetrics { latency, completed: n, items: n * batch, wall_s });
        }
        // workers share the request set, so it must be materialized
        let reqs: Vec<crate::workloads::CvRequest> = (0..n).map(|_| gen.next(batch)).collect();
        let wall0 = Instant::now();
        let me = Arc::clone(self);
        let reqs = Arc::new(reqs);
        let (latency, completed, items) = fan_out_workers(workers, n, false, move |i| {
            me.infer(&reqs[i].image).map(|_| batch)
        })?;
        let wall_s = wall0.elapsed().as_secs_f64();
        Ok(ServerMetrics { latency, completed, items, wall_s })
    }
}

// ---------------------------------------------------------------------------
// Deterministic request inputs for validation / examples
// ---------------------------------------------------------------------------

/// Generate plausible request inputs for any artifact (used by
/// `fbia validate-numerics` and the integration tests): shapes follow the
/// specs, values follow the workload distributions, seeded.
pub fn test_inputs_for(
    manifest: &crate::runtime::artifact::Manifest,
    art: &crate::runtime::artifact::Artifact,
    seed: u64,
) -> Result<Vec<HostTensor>> {
    use crate::runtime::artifact::InputKind;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for spec in &art.inputs {
        if spec.kind != InputKind::Input {
            continue;
        }
        let n = spec.elements();
        let t = if spec.name.starts_with("idx") {
            let rows = manifest.config_usize("dlrm", "rows_per_table")?;
            HostTensor::i32(
                (0..n).map(|_| rng.below(rows as u64) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name.starts_with("len") {
            let max_len = spec.shape.last().copied().unwrap_or(1);
            let cap = manifest.config_usize("dlrm", "max_lookups").unwrap_or(max_len);
            HostTensor::i32(
                (0..n).map(|_| rng.below(cap as u64 + 1) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name == "ids" {
            let vocab = manifest.config_usize("xlmr", "vocab")?;
            HostTensor::i32(
                (0..n).map(|_| rng.below(vocab as u64) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name == "pad_len" {
            let seq = art.seq.unwrap_or(32);
            HostTensor::i32(
                (0..n).map(|_| 1 + rng.below(seq as u64) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name == "image" {
            HostTensor::f32((0..n).map(|_| rng.f32()).collect(), &spec.shape)
        } else {
            // dense features, sparse pooled embeddings, ...
            let mut v = vec![0f32; n];
            rng.fill_normal_f32(&mut v, 1.0);
            HostTensor::f32(v, &spec.shape)
        };
        out.push(t);
    }
    Ok(out)
}
