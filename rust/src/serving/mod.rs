//! Serving stack (§IV-A, §IV-C runtime): the request-path binary logic.
//!
//! Real numerics flow through the engine's execution backend
//! ([`crate::runtime`] — the reference interpreter by default, PJRT with
//! `--features pjrt`); the servers here implement the paper's serving
//! structure — partitioned + pipelined DLRM (Fig. 6), bucket-switched XLM-R
//! (§VI-A), batched CV — over the artifact manifest, with multi-threaded
//! request handling and latency/QPS metrics.
//!
//! Metrics are clocked by the engine's backend ([`Clock`]): wall-clock
//! backends time each request on the host; a [`Clock::Modeled`] backend
//! (`--backend sim`) feeds the same histograms the modeled per-run card
//! latency instead, so serving benches report card-accurate numbers while
//! still executing every request's real numerics.

pub mod batcher;
pub mod cluster;
pub mod fleet;
pub mod policy;
pub mod simulation;

use crate::numerics::arena;
use crate::numerics::weights::WeightGen;
use crate::obs::{StageStats, WindowFeed, WindowedSeries};
use crate::numerics::HostTensor;
use crate::runtime::artifact::table_index;
use crate::runtime::{Clock, Engine, Precision, PrepareOptions, PreparedModel};
use crate::util::error::{err, Context, Result};
use crate::util::stats::Histogram;
use crate::util::threadpool::ThreadPool;
use crate::workloads::RecsysRequest;
use batcher::{Batcher, NlpBatch};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Seed used for artifact weights everywhere (runtime uploads and reference
/// validation must agree).
pub const WEIGHT_SEED: u64 = 0xFB1A_2021;

/// Serving metrics: latency histogram + throughput, stamped with the clock
/// that produced them (host wall time vs modeled card time).
#[derive(Debug, Clone)]
pub struct ServerMetrics {
    pub latency: Histogram,
    pub completed: usize,
    pub items: usize,
    pub wall_s: f64,
    /// Which clock `latency`/`wall_s` are on ([`Clock::Modeled`] for the
    /// sim backend — deterministic, card-accurate; wall otherwise).
    pub clock: Clock,
    /// Per-stage latency attribution ([`crate::obs`]). Populated by the
    /// modeled-clock routing tiers (fleet/cluster); empty for the
    /// wall-clock family servers, whose latency has no modeled stages.
    pub stages: StageStats,
    /// Fixed-width windowed telemetry ([`crate::obs::metrics`]), collected
    /// when [`ServeOptions::window_s`] is set on a streaming
    /// (single-worker) serve path; `None` otherwise.
    pub windows: Option<WindowedSeries>,
}

impl ServerMetrics {
    pub fn qps(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    pub fn items_per_s(&self) -> f64 {
        self.items as f64 / self.wall_s.max(1e-9)
    }
}

/// Unified serving options for the three family servers ([`RecsysServer`],
/// [`NlpServer`], [`CvServer`]): one struct instead of three divergent
/// positional signatures. Build with struct-update syntax over
/// [`ServeOptions::default`]:
///
/// ```ignore
/// let opts = ServeOptions { workers: 4, ..ServeOptions::default() };
/// let metrics = server.serve_with(reqs, &opts)?;
/// ```
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Closed-loop units in flight (whole requests, or formed batches for
    /// NLP). `1` is the single-thread baseline.
    pub workers: usize,
    /// Recsys only: whether a single-worker run uses the Fig. 6
    /// cross-request pipelined path (`true`, the serving default) or the
    /// strictly sequential baseline the thread-scaling benches compare
    /// against (`false`). Ignored when `workers > 1`.
    pub pipeline: bool,
    /// NLP dynamic-batcher cap (validated against the compiled batch
    /// variants). Ignored by the recsys/cv servers, whose batch size is
    /// fixed at construction / per call.
    pub max_batch: usize,
    /// NLP batcher mode: length-aware bucketing (`true`) vs naive FIFO.
    pub length_aware: bool,
    /// When `Some`, serving errors unless the engine's clock matches —
    /// for call sites that only mean anything on one clock (modeled-time
    /// benches, wall-time profiling).
    pub clock: Option<Clock>,
    /// When `Some`, serving errors unless the engine's backend matches.
    pub backend: Option<String>,
    /// When `Some`, serving errors unless the server's models were
    /// prepared at this precision (see [`Precision`] and the servers'
    /// `with_precision` constructors) — for benches that only mean
    /// anything on one numerics path.
    pub precision: Option<Precision>,
    /// When `Some`, the streaming (single-worker) serve paths collect
    /// fixed-width windowed telemetry at this width into
    /// [`ServerMetrics::windows`] — wall seconds on the wall clock,
    /// modeled seconds on the sim backend. Fan-out paths ignore it: their
    /// completion order is scheduler-dependent, and the windowed series is
    /// only reported where it is deterministic.
    pub window_s: Option<f64>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            workers: 1,
            pipeline: true,
            max_batch: 4,
            length_aware: true,
            clock: None,
            backend: None,
            precision: None,
            window_s: None,
        }
    }
}

impl ServeOptions {
    /// Validate the clock/backend/precision expectations against a server.
    fn check(&self, clock: Clock, backend: &str, precision: Precision) -> Result<()> {
        if let Some(want) = self.clock {
            if want != clock {
                return Err(err!(
                    "ServeOptions requires the {} clock but the engine is on the {} clock",
                    want.name(),
                    clock.name()
                ));
            }
        }
        if let Some(want) = &self.backend {
            if want != backend {
                return Err(err!(
                    "ServeOptions requires backend '{want}' but the engine runs '{backend}'"
                ));
            }
        }
        if let Some(want) = self.precision {
            if want != precision {
                return Err(err!(
                    "ServeOptions requires {} serving but the models were prepared at {}",
                    want.name(),
                    precision.name()
                ));
            }
        }
        Ok(())
    }
}

/// Fan `n` closed-loop work units out to `workers` pool threads. Each
/// worker pulls the next unit index, times `f(i)`, and accumulates a
/// per-worker latency histogram (merged at the end, so no lock sits on the
/// hot path). `f` returns the number of items the unit served plus the
/// unit's modeled seconds (used as the latency sample when `clock` is
/// [`Clock::Modeled`]; ignored on the wall clock). `sample_per_item`
/// controls whether the unit's latency is recorded once per unit
/// (whole-request models) or once per item (sentence batches). The first
/// error stops the remaining workers (best-effort) and is returned.
/// Result: (latency, units completed, items served).
fn fan_out_workers<F>(
    workers: usize,
    n: usize,
    sample_per_item: bool,
    clock: Clock,
    f: F,
) -> Result<(Histogram, usize, usize)>
where
    F: Fn(usize) -> Result<(usize, f64)> + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let next = Arc::new(AtomicUsize::new(0));
    let failed = Arc::new(AtomicBool::new(false));
    let pool = ThreadPool::new(workers);
    let (tx, rx) = mpsc::channel::<Result<(Histogram, usize, usize)>>();
    for _ in 0..workers {
        let f = Arc::clone(&f);
        let next = Arc::clone(&next);
        let failed = Arc::clone(&failed);
        let tx = tx.clone();
        pool.execute(move || {
            let mut latency = Histogram::latency();
            let (mut completed, mut items) = (0usize, 0usize);
            let res = loop {
                if failed.load(Ordering::Relaxed) {
                    break Ok(());
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break Ok(());
                }
                let t0 = Instant::now();
                match f(i) {
                    Ok((k, modeled_s)) => {
                        let dt = match clock {
                            Clock::Wall => t0.elapsed().as_secs_f64(),
                            Clock::Modeled => modeled_s,
                        };
                        for _ in 0..if sample_per_item { k } else { 1 } {
                            latency.add(dt);
                        }
                        completed += 1;
                        items += k;
                    }
                    Err(e) => {
                        failed.store(true, Ordering::Relaxed);
                        break Err(e);
                    }
                }
            };
            let _ = tx.send(res.map(|()| (latency, completed, items)));
        });
    }
    drop(tx);
    let mut latency = Histogram::latency();
    let (mut completed, mut items) = (0usize, 0usize);
    let mut first_err = None;
    for res in rx.iter() {
        match res {
            Ok((h, c, k)) => {
                latency.merge(&h);
                completed += c;
                items += k;
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        // a worker that claimed an index but never reported (panicked job)
        // must not surface as silently under-counted metrics
        None if completed != n => {
            Err(err!("worker exited without reporting ({completed} of {n} units completed)"))
        }
        None => Ok((latency, completed, items)),
    }
}

// ---------------------------------------------------------------------------
// DLRM: partitioned + pipelined (Fig. 6)
// ---------------------------------------------------------------------------

/// Table arity is request data, not contract: validate it before indexing
/// per-table tensors. Shared by [`RecsysServer`] and the fleet replicas.
pub(crate) fn check_recsys_table_arity(
    req: &RecsysRequest,
    num_tables: usize,
) -> Result<()> {
    if req.indices.len() != num_tables || req.lengths.len() != num_tables {
        return Err(err!(
            "request carries {} index / {} length tensors but the model has {} tables",
            req.indices.len(),
            req.lengths.len(),
            num_tables
        ));
    }
    Ok(())
}

/// Marshal one request's idx/len tensors for an SLS shard, in the shard's
/// table order — one definition so the server and fleet input layouts
/// cannot diverge. Callers must have validated table arity first.
pub(crate) fn sls_shard_inputs<'a>(
    req: &'a RecsysRequest,
    tables: &[usize],
) -> Vec<&'a HostTensor> {
    let mut inputs = Vec::with_capacity(tables.len() * 2);
    for &t in tables {
        inputs.push(&req.indices[t]);
        inputs.push(&req.lengths[t]);
    }
    inputs
}

/// Scatter one shard's pooled output `[batch, tables.len(), d]` into the
/// request-wide `[batch, num_tables, d]` buffer.
pub(crate) fn scatter_sls_shard(
    sparse: &mut [f32],
    pooled: &[f32],
    tables: &[usize],
    batch: usize,
    num_tables: usize,
    embed_dim: usize,
) {
    let d = embed_dim;
    for bi in 0..batch {
        for (si, &t) in tables.iter().enumerate() {
            let src = (bi * tables.len() + si) * d;
            let dst = (bi * num_tables + t) * d;
            sparse[dst..dst + d].copy_from_slice(&pooled[src..src + d]);
        }
    }
}

/// Modeled per-request costs of the partitioned DLRM path (sim clock): the
/// SLS cards run in parallel, so the SLS stage costs the slowest shard; the
/// dense stage follows (Fig. 6 left). Pipelined serving overlaps the two
/// across requests, so steady-state throughput is set by the bottleneck.
#[derive(Debug, Clone, Copy)]
struct RecsysModeled {
    /// max over shards' modeled run time (cards execute concurrently).
    sls_s: f64,
    dense_s: f64,
}

impl RecsysModeled {
    fn request_s(&self) -> f64 {
        self.sls_s + self.dense_s
    }

    fn bottleneck_s(&self) -> f64 {
        self.sls_s.max(self.dense_s)
    }
}

/// Sharded, pipelined recommendation server.
pub struct RecsysServer {
    /// (global table ids, prepared shard) per SLS card.
    shards: Vec<(Vec<usize>, Arc<PreparedModel>)>,
    dense: Arc<PreparedModel>,
    /// Pool for intra-request shard fan-out; `None` → shards run
    /// sequentially on the caller's thread.
    sls_pool: Option<ThreadPool>,
    /// Which clock metrics are on; `modeled` is `Some` iff [`Clock::Modeled`].
    clock: Clock,
    /// Engine backend name, for [`ServeOptions::backend`] validation.
    backend: String,
    modeled: Option<RecsysModeled>,
    /// Serving precision the models were prepared at.
    precision: Precision,
    pub batch: usize,
    pub num_tables: usize,
    pub embed_dim: usize,
}

impl RecsysServer {
    /// Load shards + dense for a batch size and precision ("fp32"/"int8"),
    /// with sequential per-card SLS execution.
    pub fn new(engine: Arc<Engine>, batch: usize, precision: &str) -> Result<RecsysServer> {
        RecsysServer::with_threads(engine, batch, precision, 1)
    }

    /// Like [`RecsysServer::new`], but with `threads > 1` the per-card SLS
    /// shards of one request execute in parallel on a dedicated pool — the
    /// paper's six-cards-per-request partitioning (Fig. 6 left) mapped onto
    /// host threads.
    pub fn with_threads(
        engine: Arc<Engine>,
        batch: usize,
        precision: &str,
        threads: usize,
    ) -> Result<RecsysServer> {
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let num_tables = engine.manifest().config_usize("dlrm", "num_tables")?;
        let embed_dim = engine.manifest().config_usize("dlrm", "embed_dim")?;
        // "int8" selects the pre-quantized dense artifact AND quantizes the
        // SLS embedding tables row-wise at prepare() (quantize once, serve
        // many — §V-A); "fp32" is the float reference path end to end
        let prec = Precision::parse(precision)?;
        let opts = PrepareOptions { precision: prec };

        let mut shards = Vec::new();
        for art in engine.manifest().select("dlrm", "sls") {
            if art.batch != batch {
                continue;
            }
            // global table ids from the input spec names (idx{t})
            let tables: Vec<usize> = art
                .inputs
                .iter()
                .filter(|s| s.name.starts_with("idx"))
                .map(|s| table_index(&s.name, "idx"))
                .collect::<Result<_>>()
                .with_context(|| format!("artifact {}", art.name))?;
            if tables.is_empty() {
                return Err(err!("sls artifact {} declares no idx inputs", art.name));
            }
            if let Some(&t) = tables.iter().find(|&&t| t >= num_tables) {
                return Err(err!(
                    "sls artifact {} references table {t} but configs.dlrm.num_tables is \
                     {num_tables}",
                    art.name
                ));
            }
            let weights = gen.weights_for(art);
            let prepared = engine.prepare_with(&art.name, weights, opts)?;
            shards.push((tables, Arc::new(prepared)));
        }
        if shards.is_empty() {
            return Err(err!("no dlrm sls shards for batch {batch} in the manifest"));
        }
        shards.sort_by_key(|(t, _)| t[0]);

        let suffix = match prec {
            Precision::F32 => "fp32",
            Precision::Int8 => "int8",
        };
        let dense_name = format!("dlrm_dense_b{batch}_{suffix}");
        let art = engine.manifest().get(&dense_name)?.clone();
        let weights = gen.weights_for(&art);
        let dense = Arc::new(engine.prepare_with(&dense_name, weights, opts)?);

        let sls_pool = (threads > 1 && shards.len() > 1)
            .then(|| ThreadPool::new(threads.min(shards.len())));
        let clock = engine.clock();
        let backend = engine.backend_name().to_string();
        let modeled = match clock {
            Clock::Wall => None,
            Clock::Modeled => {
                // SLS shards are card-pinned and run concurrently: the SLS
                // stage costs the slowest shard, regardless of how the host
                // happens to schedule the numerics
                let sls_s = shards
                    .iter()
                    .map(|(_, s)| {
                        s.modeled_run_s().ok_or_else(|| {
                            err!("backend reports a modeled clock but shard {} has no modeled time", s.art.name)
                        })
                    })
                    .collect::<Result<Vec<f64>>>()?
                    .into_iter()
                    .fold(0.0, f64::max);
                let dense_s = dense
                    .modeled_run_s()
                    .ok_or_else(|| err!("backend reports a modeled clock but the dense partition has no modeled time"))?;
                Some(RecsysModeled { sls_s, dense_s })
            }
        };
        Ok(RecsysServer {
            shards,
            dense,
            sls_pool,
            clock,
            backend,
            modeled,
            precision: prec,
            batch,
            num_tables,
            embed_dim,
        })
    }

    /// The precision this server's models were prepared at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The clock this server's metrics are on.
    pub fn clock(&self) -> Clock {
        self.clock
    }

    /// The engine backend this server executes on.
    pub fn backend_name(&self) -> &str {
        &self.backend
    }

    /// Modeled per-request latency on the simulated node (SLS stage = max
    /// over concurrent cards, then dense). `None` on wall-clock backends.
    pub fn modeled_request_s(&self) -> Option<f64> {
        self.modeled.map(|m| m.request_s())
    }

    /// The cards this server's SLS shards are pinned to, in shard order.
    pub fn shard_devices(&self) -> Vec<usize> {
        self.shards.iter().map(|(_, s)| s.device).collect()
    }

    /// Run the SLS partition for one request: returns [batch, T, D] pooled.
    /// With a shard pool (see [`RecsysServer::with_threads`]) the per-card
    /// shards execute concurrently; otherwise sequentially.
    pub fn run_sls(&self, req: &RecsysRequest) -> Result<HostTensor> {
        check_recsys_table_arity(req, self.num_tables)?;
        match &self.sls_pool {
            Some(pool) => self.run_sls_parallel(pool, req),
            None => self.run_sls_sequential(req),
        }
    }

    fn run_sls_sequential(&self, req: &RecsysRequest) -> Result<HostTensor> {
        let b = self.batch;
        let d = self.embed_dim;
        // arena-backed gather buffer + shape: the sequential path allocates
        // nothing per request once the worker's pools are warm
        let mut sparse = arena::with_arena(|a| a.take(b * self.num_tables * d));
        for (tables, shard) in &self.shards {
            let out = shard.run_refs(&sls_shard_inputs(req, tables))?;
            let pooled = out[0]
                .as_f32()
                .ok_or_else(|| err!("sls output not f32"))?;
            self.scatter_shard(&mut sparse, tables, pooled);
            arena::recycle_outputs(out);
        }
        Ok(arena::with_arena(|a| a.tensor_f32(sparse, &[b, self.num_tables, d])))
    }

    /// Per-card shards of ONE request in flight together. Shard jobs must be
    /// `'static` for the pool, so they share the prepared model by `Arc` and
    /// clone the small per-table index/length tensors (activations move per
    /// request — §VI-C; the weights stay resident behind the Arc).
    fn run_sls_parallel(&self, pool: &ThreadPool, req: &RecsysRequest) -> Result<HostTensor> {
        let b = self.batch;
        let d = self.embed_dim;
        let (tx, rx) = mpsc::channel::<(usize, Result<Vec<HostTensor>>)>();
        for (si, (tables, shard)) in self.shards.iter().enumerate() {
            let shard = Arc::clone(shard);
            let inputs: Vec<HostTensor> = tables
                .iter()
                .flat_map(|&t| [req.indices[t].clone(), req.lengths[t].clone()])
                .collect();
            let tx = tx.clone();
            pool.execute(move || {
                let _ = tx.send((si, shard.run(&inputs)));
            });
        }
        drop(tx);
        let mut sparse = vec![0f32; b * self.num_tables * d];
        let mut seen = 0usize;
        for (si, res) in rx.iter() {
            let out = res.with_context(|| format!("sls shard {si}"))?;
            let pooled = out[0]
                .as_f32()
                .ok_or_else(|| err!("sls output not f32"))?;
            self.scatter_shard(&mut sparse, &self.shards[si].0, pooled);
            arena::recycle_outputs(out);
            seen += 1;
        }
        if seen != self.shards.len() {
            return Err(err!("sls shard worker exited without reporting"));
        }
        Ok(HostTensor::f32(sparse, &[b, self.num_tables, d]))
    }

    /// Scatter one shard's pooled output [b, n_shard, d] into [b, T, d].
    fn scatter_shard(&self, sparse: &mut [f32], tables: &[usize], pooled: &[f32]) {
        scatter_sls_shard(sparse, pooled, tables, self.batch, self.num_tables, self.embed_dim);
    }

    /// Run the dense partition: scores [batch, 1].
    pub fn run_dense(&self, dense: &HostTensor, sparse: &HostTensor) -> Result<HostTensor> {
        let mut out = self
            .dense
            .run_refs(&[dense, sparse])
            .context("dense partition")?;
        let scores = out.swap_remove(0);
        arena::recycle_outputs(out);
        Ok(scores)
    }

    /// Full inference for one request.
    pub fn infer(&self, req: &RecsysRequest) -> Result<HostTensor> {
        let sparse = self.run_sls(req)?;
        let scores = self.run_dense(&req.dense, &sparse)?;
        arena::recycle_tensor(sparse);
        Ok(scores)
    }

    /// Unified entry point (see [`ServeOptions`]): `workers > 1` serves
    /// with that many whole requests in flight; `workers == 1` uses the
    /// Fig. 6 cross-request pipelined path unless `opts.pipeline` is off,
    /// in which case it is the strictly sequential baseline.
    pub fn serve_with(
        self: &Arc<Self>,
        reqs: Vec<RecsysRequest>,
        opts: &ServeOptions,
    ) -> Result<ServerMetrics> {
        opts.check(self.clock, &self.backend, self.precision)?;
        if opts.workers > 1 || !opts.pipeline {
            self.serve_concurrent(reqs, opts.workers.max(1), opts.window_s)
        } else {
            self.serve_pipelined(reqs, opts.window_s)
        }
    }

    /// Deprecated positional forerunner of [`RecsysServer::serve_with`].
    #[deprecated(note = "use serve_with(reqs, &ServeOptions::default())")]
    pub fn serve(self: &Arc<Self>, reqs: Vec<RecsysRequest>) -> Result<ServerMetrics> {
        self.serve_pipelined(reqs, None)
    }

    /// Deprecated positional forerunner of [`RecsysServer::serve_with`]
    /// (`ServeOptions { workers, pipeline: false, .. }`).
    #[deprecated(note = "use serve_with(reqs, &ServeOptions { workers, pipeline: false, .. })")]
    pub fn serve_workers(
        self: &Arc<Self>,
        reqs: Vec<RecsysRequest>,
        workers: usize,
    ) -> Result<ServerMetrics> {
        self.serve_concurrent(reqs, workers, None)
    }

    /// Closed-loop serving of `reqs` with cross-request pipelining: request
    /// k's SLS overlaps request k-1's dense (Fig. 6 right). Returns metrics.
    /// On the modeled clock, the histogram records the modeled per-request
    /// latency and the wall time is the steady-state pipeline span (fill +
    /// bottleneck stage per subsequent request).
    fn serve_pipelined(
        self: &Arc<Self>,
        reqs: Vec<RecsysRequest>,
        window_s: Option<f64>,
    ) -> Result<ServerMetrics> {
        let (tx, rx) = mpsc::sync_channel::<(usize, Instant, HostTensor, HostTensor)>(2);
        let me = Arc::clone(self);
        let producer = std::thread::spawn(move || -> Result<()> {
            for (i, req) in reqs.into_iter().enumerate() {
                let t0 = Instant::now();
                let sparse = me.run_sls(&req)?;
                tx.send((i, t0, req.dense, sparse)).map_err(|_| err!("dense stage gone"))?;
            }
            Ok(())
        });

        let mut latency = Histogram::latency();
        let mut feed = window_s.map(WindowFeed::new);
        let wall0 = Instant::now();
        let mut completed = 0usize;
        for (_i, t0, dense, sparse) in rx.iter() {
            let scores = self.run_dense(&dense, &sparse)?;
            arena::recycle_tensor(scores);
            arena::recycle_tensor(sparse);
            let dt = match self.modeled {
                None => t0.elapsed().as_secs_f64(),
                Some(m) => m.request_s(),
            };
            latency.add(dt);
            if let Some(f) = feed.as_mut() {
                // tandem-queue completion times: fill, then one per
                // bottleneck period (matches the modeled wall below)
                let t_s = match self.modeled {
                    None => wall0.elapsed().as_secs_f64(),
                    Some(m) => m.request_s() + completed as f64 * m.bottleneck_s(),
                };
                f.complete(t_s, dt);
            }
            completed += 1;
        }
        producer.join().map_err(|_| err!("producer panicked"))??;
        let wall_s = match self.modeled {
            None => wall0.elapsed().as_secs_f64(),
            // tandem-queue steady state (sim::exec): first request pays the
            // full path, each further one the bottleneck stage
            Some(m) if completed > 0 => {
                m.request_s() + (completed - 1) as f64 * m.bottleneck_s()
            }
            Some(_) => 0.0,
        };
        Ok(ServerMetrics {
            latency,
            completed,
            items: completed * self.batch,
            wall_s,
            clock: self.clock,
            stages: StageStats::default(),
            windows: feed.map(WindowFeed::finish),
        })
    }

    /// Closed-loop serving with `workers` whole requests in flight — the
    /// intra-host parallelism knob (`--threads`). Each worker pulls the next
    /// request and runs its full SLS→dense path; per-worker latency
    /// histograms are merged at the end. `workers == 1` is the strictly
    /// sequential single-thread baseline the fig7 thread-scaling points
    /// compare against.
    fn serve_concurrent(
        self: &Arc<Self>,
        reqs: Vec<RecsysRequest>,
        workers: usize,
        window_s: Option<f64>,
    ) -> Result<ServerMetrics> {
        let n = reqs.len();
        let clock = self.clock;
        let modeled = self.modeled;
        // modeled wall: n identical requests over `workers` host threads run
        // in ceil(n/w) waves (at most n are ever in flight) — computed up
        // front so it is exact and deterministic
        let modeled_wall = modeled
            .map(|m| n.div_ceil(workers.clamp(1, n.max(1))) as f64 * m.request_s());
        let wall0 = Instant::now();
        if workers <= 1 {
            let mut latency = Histogram::latency();
            let mut feed = window_s.map(WindowFeed::new);
            for (i, req) in reqs.iter().enumerate() {
                let t0 = Instant::now();
                arena::recycle_tensor(self.infer(req)?);
                let dt = match modeled {
                    None => t0.elapsed().as_secs_f64(),
                    Some(m) => m.request_s(),
                };
                latency.add(dt);
                if let Some(f) = feed.as_mut() {
                    let t_s = match modeled {
                        None => wall0.elapsed().as_secs_f64(),
                        Some(m) => (i + 1) as f64 * m.request_s(),
                    };
                    f.complete(t_s, dt);
                }
            }
            let wall_s = modeled_wall.unwrap_or_else(|| wall0.elapsed().as_secs_f64());
            return Ok(ServerMetrics {
                latency,
                completed: n,
                items: n * self.batch,
                wall_s,
                clock,
                stages: StageStats::default(),
                windows: feed.map(WindowFeed::finish),
            });
        }
        let me = Arc::clone(self);
        let reqs = Arc::new(reqs);
        let (latency, completed, items) = fan_out_workers(workers, n, false, clock, move |i| {
            let modeled_s = me.modeled.map(|m| m.request_s()).unwrap_or(0.0);
            me.infer(&reqs[i]).map(|scores| {
                arena::recycle_tensor(scores);
                (me.batch, modeled_s)
            })
        })?;
        let wall_s = modeled_wall.unwrap_or_else(|| wall0.elapsed().as_secs_f64());
        Ok(ServerMetrics {
            latency,
            completed,
            items,
            wall_s,
            clock,
            stages: StageStats::default(),
            windows: None,
        })
    }
}

// ---------------------------------------------------------------------------
// XLM-R: bucket-switched serving (§VI-A)
// ---------------------------------------------------------------------------

/// NLP server holding one prepared network per (seq bucket, batch) pair and
/// a dynamic batcher.
pub struct NlpServer {
    /// (seq, batch) -> prepared model
    nets: Vec<(usize, usize, Arc<PreparedModel>)>,
    clock: Clock,
    /// Engine backend name, for [`ServeOptions::backend`] validation.
    backend: String,
    /// Serving precision the nets were prepared at.
    precision: Precision,
    pub buckets: Vec<usize>,
    pub d_model: usize,
}

impl NlpServer {
    /// f32 reference serving; see [`NlpServer::with_precision`] for int8.
    pub fn new(engine: Arc<Engine>) -> Result<NlpServer> {
        NlpServer::with_precision(engine, Precision::F32)
    }

    /// Prepare every bucket×batch net at `precision`. At [`Precision::Int8`]
    /// the d_model-contraction FC weights quantize row-wise at prepare()
    /// (ffn2 stays f32 under the per-layer error budget) and each net is
    /// accuracy-gated against its f32 reference before serving.
    pub fn with_precision(engine: Arc<Engine>, precision: Precision) -> Result<NlpServer> {
        let opts = PrepareOptions { precision };
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let mut nets = Vec::new();
        let mut buckets = Vec::new();
        for art in engine.manifest().select("xlmr", "full") {
            let seq = art.seq.ok_or_else(|| err!("xlmr artifact missing seq"))?;
            let weights = gen.weights_for(art);
            let prepared = engine.prepare_with(&art.name, weights, opts)?;
            nets.push((seq, art.batch, Arc::new(prepared)));
            if !buckets.contains(&seq) {
                buckets.push(seq);
            }
        }
        if nets.is_empty() {
            return Err(err!("no xlmr artifacts in the manifest"));
        }
        buckets.sort_unstable();
        let d_model = engine.manifest().config_usize("xlmr", "d_model")?;
        let clock = engine.clock();
        if clock == Clock::Modeled {
            // same invalid-state guard as RecsysServer: a modeled clock
            // without modeled run times must fail here, not report 0-latency
            // metrics later
            for (seq, b, net) in &nets {
                if net.modeled_run_s().is_none() {
                    return Err(err!(
                        "backend reports a modeled clock but xlmr net s{seq} b{b} has no modeled time"
                    ));
                }
            }
        }
        let backend = engine.backend_name().to_string();
        Ok(NlpServer { nets, clock, backend, precision, buckets, d_model })
    }

    /// The clock this server's metrics are on.
    pub fn clock(&self) -> Clock {
        self.clock
    }

    /// The engine backend this server executes on.
    pub fn backend_name(&self) -> &str {
        &self.backend
    }

    /// The precision this server's nets were prepared at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Modeled seconds for one formed batch (the selected bucket×batch
    /// net's per-run card time); 0.0 on wall-clock backends.
    fn modeled_batch_s(&self, batch: &NlpBatch) -> f64 {
        self.net_for(batch.bucket, batch.requests.len())
            .ok()
            .and_then(|(_, net)| net.modeled_run_s())
            .unwrap_or(0.0)
    }

    /// Find the prepared net for a bucket with the smallest batch >= n.
    fn net_for(&self, bucket: usize, n: usize) -> Result<(usize, &Arc<PreparedModel>)> {
        self.nets
            .iter()
            .filter(|(s, b, _)| *s == bucket && *b >= n)
            .min_by_key(|(_, b, _)| *b)
            .map(|(_, b, m)| (*b, m))
            .ok_or_else(|| err!("no xlmr net for bucket {bucket} x batch {n}"))
    }

    /// Largest batch every bucket has a compiled variant for — the cap on
    /// `max_batch` in [`NlpServer::serve`]. A batch formed above this would
    /// only fail mid-stream inside `net_for`, so `serve` validates against
    /// it up front.
    pub fn max_supported_batch(&self) -> usize {
        self.buckets
            .iter()
            .map(|&s| {
                self.nets
                    .iter()
                    .filter(|(ns, _, _)| *ns == s)
                    .map(|(_, b, _)| *b)
                    .max()
                    .unwrap_or(0)
            })
            .min()
            .unwrap_or(0)
    }

    /// Run one formed batch; returns pooled embeddings [n, d_model].
    pub fn run_batch(&self, batch: &NlpBatch) -> Result<Vec<Vec<f32>>> {
        let n = batch.requests.len();
        let (rows, net) = self.net_for(batch.bucket, n)?;
        let (ids, lens) = batcher::pad_batch(batch, rows);
        let out = net.run(&[
            HostTensor::i32(ids, &[rows, batch.bucket]),
            HostTensor::i32(lens, &[rows]),
        ])?;
        let pooled = out[0].as_f32().ok_or_else(|| err!("pooled not f32"))?;
        let rows = (0..n)
            .map(|i| pooled[i * self.d_model..(i + 1) * self.d_model].to_vec())
            .collect();
        // run_batch executes and consumes on the same thread, so the output
        // buffers go straight back to this worker's arena
        arena::recycle_outputs(out);
        Ok(rows)
    }

    /// Unified entry point (see [`ServeOptions`]): serve a request stream
    /// through the batcher per `opts` (`max_batch`, `length_aware`,
    /// `workers`). Returns metrics plus the padded-vs-real token waste.
    pub fn serve_with(
        self: &Arc<Self>,
        reqs: Vec<crate::workloads::NlpRequest>,
        opts: &ServeOptions,
    ) -> Result<(ServerMetrics, f64)> {
        opts.check(self.clock, &self.backend, self.precision)?;
        self.serve_batched(reqs, opts.max_batch, opts.length_aware, opts.workers, opts.window_s)
    }

    /// Deprecated positional forerunner of [`NlpServer::serve_with`].
    #[deprecated(note = "use serve_with(reqs, &ServeOptions { max_batch, length_aware, workers, .. })")]
    pub fn serve(
        self: &Arc<Self>,
        reqs: Vec<crate::workloads::NlpRequest>,
        max_batch: usize,
        length_aware: bool,
        workers: usize,
    ) -> Result<(ServerMetrics, f64)> {
        self.serve_batched(reqs, max_batch, length_aware, workers, None)
    }

    /// Serve a request stream through the batcher with `workers` batches in
    /// flight. Returns metrics plus the padded-vs-real token accounting
    /// (the batching-efficiency signal). `max_batch` is validated against
    /// the compiled batch variants before any batch forms.
    fn serve_batched(
        self: &Arc<Self>,
        reqs: Vec<crate::workloads::NlpRequest>,
        max_batch: usize,
        length_aware: bool,
        workers: usize,
        window_s: Option<f64>,
    ) -> Result<(ServerMetrics, f64)> {
        if max_batch == 0 {
            return Err(err!("max_batch must be >= 1"));
        }
        let cap = self.max_supported_batch();
        if max_batch > cap {
            return Err(err!(
                "max_batch {max_batch} exceeds the largest batch compiled for every \
                 bucket ({cap}); compiled (seq, batch) variants: {:?}",
                self.nets.iter().map(|(s, b, _)| (*s, *b)).collect::<Vec<_>>()
            ));
        }
        let clock = self.clock;
        let wall0 = Instant::now();
        let mut b = Batcher::new(self.buckets.clone(), max_batch, length_aware);

        if workers <= 1 {
            // stream: run each batch as it forms (O(max_batch) memory)
            let mut latency = Histogram::latency();
            let mut feed = window_s.map(WindowFeed::new);
            let (mut completed, mut items, mut padded, mut real) = (0usize, 0usize, 0usize, 0usize);
            let mut modeled_total = 0.0f64;
            let mut run = |batch: &NlpBatch| -> Result<()> {
                let t0 = Instant::now();
                self.run_batch(batch)?;
                let dt = match clock {
                    Clock::Wall => t0.elapsed().as_secs_f64(),
                    Clock::Modeled => self.modeled_batch_s(batch),
                };
                modeled_total += dt;
                let finish_s = match clock {
                    Clock::Wall => wall0.elapsed().as_secs_f64(),
                    Clock::Modeled => modeled_total,
                };
                for _ in 0..batch.requests.len() {
                    latency.add(dt);
                    if let Some(f) = feed.as_mut() {
                        f.complete(finish_s, dt);
                    }
                }
                completed += 1;
                items += batch.requests.len();
                padded += batch.padded_tokens();
                real += batch.real_tokens();
                Ok(())
            };
            for r in reqs {
                b.push(r);
                while let Some(batch) = b.pop(false)? {
                    run(&batch)?;
                }
            }
            for batch in b.drain()? {
                run(&batch)?;
            }
            let wall_s = match clock {
                Clock::Wall => wall0.elapsed().as_secs_f64(),
                Clock::Modeled => modeled_total,
            };
            let waste = 1.0 - real as f64 / padded.max(1) as f64;
            return Ok((
                ServerMetrics {
                    latency,
                    completed,
                    items,
                    wall_s,
                    clock,
                    stages: StageStats::default(),
                    windows: feed.map(WindowFeed::finish),
                },
                waste,
            ));
        }

        // workers share the formed batches, so materialize them first
        let mut batches = Vec::new();
        for r in reqs {
            b.push(r);
            while let Some(batch) = b.pop(false)? {
                batches.push(batch);
            }
        }
        batches.extend(b.drain()?);
        let (mut padded, mut real) = (0usize, 0usize);
        // modeled wall computed up front, in batch order, so it is
        // deterministic and independent of which worker ran which batch;
        // batches are heterogeneous, so use the classic makespan bound
        // max(total/w, longest batch) rather than the bare mean
        let (mut modeled_total, mut modeled_longest) = (0.0f64, 0.0f64);
        for batch in &batches {
            padded += batch.padded_tokens();
            real += batch.real_tokens();
            if clock == Clock::Modeled {
                let s = self.modeled_batch_s(batch);
                modeled_total += s;
                modeled_longest = modeled_longest.max(s);
            }
        }
        let n = batches.len();
        let me = Arc::clone(self);
        let batches = Arc::new(batches);
        let (latency, completed, items) = fan_out_workers(workers, n, true, clock, move |i| {
            let modeled_s = me.modeled_batch_s(&batches[i]);
            me.run_batch(&batches[i]).map(|_| (batches[i].requests.len(), modeled_s))
        })?;
        let wall_s = match clock {
            Clock::Wall => wall0.elapsed().as_secs_f64(),
            // at most n batches are ever in flight; no schedule finishes
            // before the longest batch does
            Clock::Modeled => {
                (modeled_total / workers.clamp(1, n.max(1)) as f64).max(modeled_longest)
            }
        };
        let waste = 1.0 - real as f64 / padded.max(1) as f64;
        Ok((
            ServerMetrics {
                latency,
                completed,
                items,
                wall_s,
                clock,
                stages: StageStats::default(),
                windows: None,
            },
            waste,
        ))
    }
}

// ---------------------------------------------------------------------------
// CV: batched single-card serving
// ---------------------------------------------------------------------------

/// CV trunk server with batch-variant selection.
pub struct CvServer {
    nets: Vec<(usize, Arc<PreparedModel>)>,
    clock: Clock,
    /// Engine backend name, for [`ServeOptions::backend`] validation.
    backend: String,
    /// Serving precision the nets were prepared at.
    precision: Precision,
    pub image: usize,
    pub classes: usize,
}

impl CvServer {
    /// f32 reference serving; see [`CvServer::with_precision`] for int8.
    pub fn new(engine: Arc<Engine>) -> Result<CvServer> {
        CvServer::with_precision(engine, Precision::F32)
    }

    /// Prepare every batch variant at `precision` ([`Precision::Int8`]
    /// quantizes the classifier head row-wise at prepare(); conv weights
    /// stay f32 — they are 4-D and outside the row-wise scheme).
    pub fn with_precision(engine: Arc<Engine>, precision: Precision) -> Result<CvServer> {
        let opts = PrepareOptions { precision };
        let mut gen = WeightGen::new(WEIGHT_SEED);
        let mut nets = Vec::new();
        for art in engine.manifest().select("cv", "full") {
            let weights = gen.weights_for(art);
            let prepared = engine.prepare_with(&art.name, weights, opts)?;
            nets.push((art.batch, Arc::new(prepared)));
        }
        if nets.is_empty() {
            return Err(err!("no cv artifacts in the manifest"));
        }
        nets.sort_by_key(|(b, _)| *b);
        let clock = engine.clock();
        if clock == Clock::Modeled {
            // same invalid-state guard as RecsysServer
            for (b, net) in &nets {
                if net.modeled_run_s().is_none() {
                    return Err(err!(
                        "backend reports a modeled clock but cv net b{b} has no modeled time"
                    ));
                }
            }
        }
        Ok(CvServer {
            nets,
            clock,
            backend: engine.backend_name().to_string(),
            precision,
            image: engine.manifest().config_usize("cv", "image")?,
            classes: engine.manifest().config_usize("cv", "classes")?,
        })
    }

    /// The clock this server's metrics are on.
    pub fn clock(&self) -> Clock {
        self.clock
    }

    /// The engine backend this server executes on.
    pub fn backend_name(&self) -> &str {
        &self.backend
    }

    /// The precision this server's nets were prepared at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Modeled seconds per request at a batch size; 0.0 on wall clocks.
    fn modeled_s(&self, batch: usize) -> f64 {
        self.nets
            .iter()
            .find(|(nb, _)| *nb == batch)
            .and_then(|(_, m)| m.modeled_run_s())
            .unwrap_or(0.0)
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.nets.iter().map(|(b, _)| *b).collect()
    }

    /// Classify a batch (image tensor shaped [b, h, w, 3] where b matches a
    /// compiled variant). Returns (logits, embedding).
    pub fn infer(&self, image: &HostTensor) -> Result<(HostTensor, HostTensor)> {
        let b = image.shape()[0];
        let net = self
            .nets
            .iter()
            .find(|(nb, _)| *nb == b)
            .map(|(_, m)| m)
            .ok_or_else(|| err!("no cv net compiled for batch {b}"))?;
        let mut out = net.run_refs(&[image])?;
        let emb = out.pop().ok_or_else(|| err!("cv output missing embedding"))?;
        let logits = out.pop().ok_or_else(|| err!("cv output missing logits"))?;
        arena::recycle_outputs(out);
        Ok((logits, emb))
    }

    /// Unified entry point (see [`ServeOptions`]): closed-loop throughput
    /// for `n` requests at a batch size, with `opts.workers` in flight.
    pub fn serve_with(
        self: &Arc<Self>,
        n: usize,
        batch: usize,
        gen: &mut crate::workloads::CvGen,
        opts: &ServeOptions,
    ) -> Result<ServerMetrics> {
        opts.check(self.clock, &self.backend, self.precision)?;
        self.serve_closed_loop(n, batch, gen, opts.workers, opts.window_s)
    }

    /// Deprecated positional forerunner of [`CvServer::serve_with`].
    #[deprecated(note = "use serve_with(n, batch, gen, &ServeOptions { workers, .. })")]
    pub fn serve(
        self: &Arc<Self>,
        n: usize,
        batch: usize,
        gen: &mut crate::workloads::CvGen,
        workers: usize,
    ) -> Result<ServerMetrics> {
        self.serve_closed_loop(n, batch, gen, workers, None)
    }

    /// Closed-loop throughput at a batch size with `workers` requests in
    /// flight (`workers == 1` → sequential baseline).
    fn serve_closed_loop(
        self: &Arc<Self>,
        n: usize,
        batch: usize,
        gen: &mut crate::workloads::CvGen,
        workers: usize,
        window_s: Option<f64>,
    ) -> Result<ServerMetrics> {
        // batch is part of the request contract: validate against the
        // compiled variants before generating anything
        if !self.nets.iter().any(|(nb, _)| *nb == batch) {
            return Err(err!(
                "no cv net compiled for batch {batch} (variants: {:?})",
                self.batch_sizes()
            ));
        }
        let clock = self.clock;
        let modeled_req_s = self.modeled_s(batch);
        // ceil(n/w) waves of identical requests (at most n in flight)
        let modeled_wall = (clock == Clock::Modeled)
            .then(|| n.div_ceil(workers.clamp(1, n.max(1))) as f64 * modeled_req_s);
        if workers <= 1 {
            // stream requests (O(1) memory regardless of n), excluding
            // generation from the wall clock so this measures the same
            // thing as the threaded branch, which pre-materializes
            let wall0 = Instant::now();
            let mut gen_s = 0.0f64;
            let mut latency = Histogram::latency();
            let mut feed = window_s.map(WindowFeed::new);
            for i in 0..n {
                let g0 = Instant::now();
                let req = gen.next(batch);
                gen_s += g0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let (logits, emb) = self.infer(&req.image)?;
                arena::recycle_tensor(logits);
                arena::recycle_tensor(emb);
                let dt = match clock {
                    Clock::Wall => t0.elapsed().as_secs_f64(),
                    Clock::Modeled => modeled_req_s,
                };
                latency.add(dt);
                if let Some(f) = feed.as_mut() {
                    let t_s = match clock {
                        Clock::Wall => (wall0.elapsed().as_secs_f64() - gen_s).max(0.0),
                        Clock::Modeled => (i + 1) as f64 * modeled_req_s,
                    };
                    f.complete(t_s, dt);
                }
            }
            let wall_s = modeled_wall
                .unwrap_or_else(|| (wall0.elapsed().as_secs_f64() - gen_s).max(0.0));
            return Ok(ServerMetrics {
                latency,
                completed: n,
                items: n * batch,
                wall_s,
                clock,
                stages: StageStats::default(),
                windows: feed.map(WindowFeed::finish),
            });
        }
        // workers share the request set, so it must be materialized
        let reqs: Vec<crate::workloads::CvRequest> = (0..n).map(|_| gen.next(batch)).collect();
        let wall0 = Instant::now();
        let me = Arc::clone(self);
        let reqs = Arc::new(reqs);
        let (latency, completed, items) = fan_out_workers(workers, n, false, clock, move |i| {
            me.infer(&reqs[i].image).map(|(logits, emb)| {
                arena::recycle_tensor(logits);
                arena::recycle_tensor(emb);
                (batch, modeled_req_s)
            })
        })?;
        let wall_s = modeled_wall.unwrap_or_else(|| wall0.elapsed().as_secs_f64());
        Ok(ServerMetrics {
            latency,
            completed,
            items,
            wall_s,
            clock,
            stages: StageStats::default(),
            windows: None,
        })
    }
}

// ---------------------------------------------------------------------------
// Deterministic request inputs for validation / examples
// ---------------------------------------------------------------------------

/// Generate plausible request inputs for any artifact (used by
/// `fbia validate-numerics` and the integration tests): shapes follow the
/// specs, values follow the workload distributions, seeded.
pub fn test_inputs_for(
    manifest: &crate::runtime::artifact::Manifest,
    art: &crate::runtime::artifact::Artifact,
    seed: u64,
) -> Result<Vec<HostTensor>> {
    use crate::runtime::artifact::InputKind;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for spec in &art.inputs {
        if spec.kind != InputKind::Input {
            continue;
        }
        let n = spec.elements();
        let t = if spec.name.starts_with("idx") {
            let rows = manifest.config_usize("dlrm", "rows_per_table")?;
            HostTensor::i32(
                (0..n).map(|_| rng.below(rows as u64) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name.starts_with("len") {
            let max_len = spec.shape.last().copied().unwrap_or(1);
            let cap = manifest.config_usize("dlrm", "max_lookups").unwrap_or(max_len);
            HostTensor::i32(
                (0..n).map(|_| rng.below(cap as u64 + 1) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name == "ids" {
            let vocab = manifest.config_usize("xlmr", "vocab")?;
            HostTensor::i32(
                (0..n).map(|_| rng.below(vocab as u64) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name == "pad_len" {
            let seq = art.seq.unwrap_or(32);
            HostTensor::i32(
                (0..n).map(|_| 1 + rng.below(seq as u64) as i32).collect(),
                &spec.shape,
            )
        } else if spec.name == "image" {
            HostTensor::f32((0..n).map(|_| rng.f32()).collect(), &spec.shape)
        } else {
            // dense features, sparse pooled embeddings, ...
            let mut v = vec![0f32; n];
            rng.fill_normal_f32(&mut v, 1.0);
            HostTensor::f32(v, &spec.shape)
        };
        out.push(t);
    }
    Ok(out)
}
