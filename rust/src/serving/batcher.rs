//! Dynamic batcher with sequence-length buckets (§VI-A padding boundaries,
//! §VII "a smarter batching approach ... combine sentences of similar
//! lengths").
//!
//! Length-aware mode groups sentences by the smallest compiled bucket that
//! fits them, so short sentences never pad to a long sentence's bucket.
//! Naive mode batches FIFO and pads the whole batch to the largest member's
//! bucket — the wasted-compute baseline the paper calls out.

use crate::util::error::{err, Result};
use crate::workloads::NlpRequest;

/// A formed batch: member requests + the bucket they pad to.
#[derive(Debug, Clone)]
pub struct NlpBatch {
    pub requests: Vec<NlpRequest>,
    pub bucket: usize,
}

impl NlpBatch {
    /// Padded token-slots in the batch.
    pub fn padded_tokens(&self) -> usize {
        self.requests.len() * self.bucket
    }

    /// Real token count.
    pub fn real_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens.len()).sum()
    }

    /// Fraction of compute wasted on pad tokens (quadratic attention terms
    /// ignored — this is the paper's "wasted compute on zeros" proxy).
    pub fn waste(&self) -> f64 {
        1.0 - self.real_tokens() as f64 / self.padded_tokens().max(1) as f64
    }
}

/// Pick the smallest bucket that fits `len`; None if it exceeds all buckets
/// (the request must be truncated or rejected upstream).
pub fn bucket_for(len: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= len)
}

/// The batcher.
pub struct Batcher {
    pub buckets: Vec<usize>,
    pub max_batch: usize,
    pub length_aware: bool,
    /// per-bucket queues (length-aware) or one FIFO (naive).
    queues: Vec<Vec<NlpRequest>>,
    fifo: Vec<NlpRequest>,
    /// requests whose length exceeded the largest bucket.
    pub rejected: usize,
}

impl Batcher {
    pub fn new(buckets: Vec<usize>, max_batch: usize, length_aware: bool) -> Self {
        assert!(!buckets.is_empty());
        assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
        let nq = buckets.len();
        Batcher {
            buckets,
            max_batch,
            length_aware,
            queues: vec![Vec::new(); nq],
            fifo: Vec::new(),
            rejected: 0,
        }
    }

    /// Enqueue one request.
    pub fn push(&mut self, r: NlpRequest) {
        match bucket_for(r.tokens.len(), &self.buckets) {
            None => self.rejected += 1,
            Some(b) => {
                if self.length_aware {
                    let qi = self.buckets.iter().position(|&x| x == b).unwrap();
                    self.queues[qi].push(r);
                } else {
                    self.fifo.push(r);
                }
            }
        }
    }

    /// Number of queued requests.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(Vec::len).sum::<usize>() + self.fifo.len()
    }

    /// Form the next batch, if any. `force` drains even sub-max batches
    /// (timeout fired); otherwise only full batches are released.
    ///
    /// Errs when a queued request no longer fits any bucket — that means
    /// the bucket table changed (or was corrupted) after enqueue, and
    /// silently padding to the largest bucket would run the batch on a net
    /// compiled for a shorter sequence, truncating tokens. The queue is
    /// left intact so no request is lost on the error path.
    pub fn pop(&mut self, force: bool) -> Result<Option<NlpBatch>> {
        if self.length_aware {
            // fullest queue first
            let (qi, _) = match self.queues.iter().enumerate().max_by_key(|(_, q)| q.len()) {
                Some(x) => x,
                None => return Ok(None),
            };
            let q = &mut self.queues[qi];
            if q.is_empty() || (!force && q.len() < self.max_batch) {
                return Ok(None);
            }
            let take = q.len().min(self.max_batch);
            let requests: Vec<NlpRequest> = q.drain(..take).collect();
            Ok(Some(NlpBatch { requests, bucket: self.buckets[qi] }))
        } else {
            if self.fifo.is_empty() || (!force && self.fifo.len() < self.max_batch) {
                return Ok(None);
            }
            let take = self.fifo.len().min(self.max_batch);
            // resolve the bucket before draining, so an error leaves the
            // queued requests where they were
            let max_len = self.fifo[..take].iter().map(|r| r.tokens.len()).max().unwrap_or(1);
            let bucket = bucket_for(max_len, &self.buckets).ok_or_else(|| {
                err!(
                    "batcher popped a {take}-request batch whose longest member has \
                     {max_len} tokens, exceeding the largest compiled bucket {} \
                     (buckets {:?}); over-long requests must be rejected at enqueue, \
                     not silently clamped",
                    self.buckets.last().copied().unwrap_or(0),
                    self.buckets
                )
            })?;
            let requests: Vec<NlpRequest> = self.fifo.drain(..take).collect();
            Ok(Some(NlpBatch { requests, bucket }))
        }
    }

    /// Drain everything into batches (end of run).
    pub fn drain(&mut self) -> Result<Vec<NlpBatch>> {
        let mut out = Vec::new();
        while let Some(b) = self.pop(true)? {
            out.push(b);
        }
        Ok(out)
    }
}

/// Pad a batch's token lists into the [batch, bucket] i32 tensor + lengths
/// the XLM-R artifacts expect.
pub fn pad_batch(batch: &NlpBatch, to_rows: usize) -> (Vec<i32>, Vec<i32>) {
    let rows = to_rows.max(batch.requests.len());
    let mut ids = vec![0i32; rows * batch.bucket];
    let mut lens = vec![0i32; rows];
    for (i, r) in batch.requests.iter().enumerate() {
        let n = r.tokens.len().min(batch.bucket);
        ids[i * batch.bucket..i * batch.bucket + n].copy_from_slice(&r.tokens[..n]);
        lens[i] = n as i32;
    }
    (ids, lens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Rng;

    fn req(len: usize) -> NlpRequest {
        NlpRequest { tokens: vec![1; len], arrival_s: 0.0 }
    }

    #[test]
    fn bucket_selection() {
        let b = vec![32, 64, 128];
        assert_eq!(bucket_for(1, &b), Some(32));
        assert_eq!(bucket_for(32, &b), Some(32));
        assert_eq!(bucket_for(33, &b), Some(64));
        assert_eq!(bucket_for(128, &b), Some(128));
        assert_eq!(bucket_for(129, &b), None);
    }

    #[test]
    fn length_aware_separates_buckets() {
        let mut b = Batcher::new(vec![32, 64], 4, true);
        for _ in 0..4 {
            b.push(req(10));
        }
        for _ in 0..2 {
            b.push(req(50));
        }
        let batch = b.pop(false).unwrap().unwrap();
        assert_eq!(batch.bucket, 32);
        assert_eq!(batch.requests.len(), 4);
        assert!(b.pop(false).unwrap().is_none()); // 2 long ones wait for more
        let forced = b.pop(true).unwrap().unwrap();
        assert_eq!(forced.bucket, 64);
    }

    #[test]
    fn naive_pads_to_largest_member() {
        let mut b = Batcher::new(vec![32, 64], 2, false);
        b.push(req(10));
        b.push(req(50));
        let batch = b.pop(false).unwrap().unwrap();
        assert_eq!(batch.bucket, 64); // the short sentence pays 64 slots
        assert!(batch.waste() > 0.5, "{}", batch.waste());
    }

    #[test]
    fn length_aware_wastes_less_than_naive() {
        // §VII: smarter batching combines similar lengths
        let mk = |aware| {
            let mut b = Batcher::new(vec![32, 64, 128], 8, aware);
            let mut rng = Rng::new(1);
            for _ in 0..64 {
                let l = (3.6 + 0.5 * rng.normal()).exp().round() as usize;
                b.push(req(l.clamp(1, 128)));
            }
            let batches = b.drain().unwrap();
            let padded: usize = batches.iter().map(|x| x.padded_tokens()).sum();
            let real: usize = batches.iter().map(|x| x.real_tokens()).sum();
            (real, padded)
        };
        let (real_a, padded_a) = mk(true);
        let (real_n, padded_n) = mk(false);
        assert_eq!(real_a, real_n);
        assert!(padded_a < padded_n, "aware {padded_a} naive {padded_n}");
    }

    #[test]
    fn over_long_requests_rejected() {
        let mut b = Batcher::new(vec![32], 4, true);
        b.push(req(100));
        assert_eq!(b.rejected, 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn force_pop_with_empty_buckets_returns_none() {
        // a timeout fire on an empty batcher must be a no-op, not a panic
        // or an empty batch, in both modes
        for aware in [true, false] {
            let mut b = Batcher::new(vec![32, 64], 4, aware);
            assert!(b.pop(true).unwrap().is_none());
            assert!(b.pop(false).unwrap().is_none());
            assert!(b.drain().unwrap().is_empty());
            // and again after the batcher has cycled through requests
            b.push(req(10));
            assert_eq!(b.drain().unwrap().len(), 1);
            assert!(b.pop(true).unwrap().is_none());
            assert_eq!(b.pending(), 0);
        }
    }

    #[test]
    fn boundary_length_accepted_one_past_rejected() {
        let mut b = Batcher::new(vec![32, 64], 2, true);
        b.push(req(64)); // exactly the largest bucket: kept
        b.push(req(65)); // one past: rejected
        assert_eq!(b.rejected, 1);
        assert_eq!(b.pending(), 1);
        let batch = b.pop(true).unwrap().unwrap();
        assert_eq!(batch.bucket, 64);
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn unforced_pop_never_releases_sub_max_batches() {
        for aware in [true, false] {
            let mut b = Batcher::new(vec![32], 4, aware);
            for _ in 0..3 {
                b.push(req(8));
                assert!(b.pop(false).unwrap().is_none(), "aware={aware}: released a sub-max batch");
            }
            b.push(req(8));
            let batch = b.pop(false).unwrap().unwrap();
            assert_eq!(batch.requests.len(), 4);
            // forced drain releases leftovers at any size
            b.push(req(8));
            assert_eq!(b.pop(true).unwrap().unwrap().requests.len(), 1);
        }
    }

    #[test]
    fn waste_ordering_length_aware_leq_fifo_per_batch_mix() {
        // the §VII ordering holds not just in aggregate but for a bimodal
        // mix engineered to punish FIFO: alternating short/long sentences
        let mk = |aware: bool| {
            let mut b = Batcher::new(vec![32, 128], 4, aware);
            for i in 0..32 {
                b.push(req(if i % 2 == 0 { 8 } else { 120 }));
            }
            let batches = b.drain().unwrap();
            let padded: usize = batches.iter().map(|x| x.padded_tokens()).sum();
            let real: usize = batches.iter().map(|x| x.real_tokens()).sum();
            (real, padded, batches.len())
        };
        let (real_a, padded_a, _) = mk(true);
        let (real_n, padded_n, _) = mk(false);
        assert_eq!(real_a, real_n);
        // FIFO pads every batch to 128 (each holds a long member); aware
        // keeps the shorts at 32
        assert!(padded_a < padded_n, "aware {padded_a} !< fifo {padded_n}");
        let waste_a = 1.0 - real_a as f64 / padded_a as f64;
        let waste_n = 1.0 - real_n as f64 / padded_n as f64;
        assert!(waste_a < waste_n, "aware {waste_a} !< fifo {waste_n}");
    }

    #[test]
    fn inconsistent_bucket_table_errors_instead_of_clamping() {
        // regression: the naive-mode pop used to fall back to the largest
        // bucket when the formed batch fit none — running the batch on a
        // net compiled for a shorter sequence and silently truncating
        // tokens. A corrupted bucket table must surface an error with the
        // request context, and the queue must survive the failed pop.
        let mut b = Batcher::new(vec![32, 64], 2, false);
        b.push(req(50));
        b.buckets = vec![32]; // shrunk behind the batcher's back
        let e = b.pop(true).unwrap_err().to_string();
        assert!(e.contains("50 tokens"), "{e}");
        assert!(e.contains("32"), "{e}");
        assert_eq!(b.pending(), 1, "failed pop must not lose the request");
        // restoring the table lets the same request through, un-truncated
        b.buckets = vec![32, 64];
        let batch = b.pop(true).unwrap().unwrap();
        assert_eq!(batch.bucket, 64);
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn pad_batch_shapes() {
        let batch = NlpBatch { requests: vec![req(3), req(5)], bucket: 8 };
        let (ids, lens) = pad_batch(&batch, 4);
        assert_eq!(ids.len(), 4 * 8);
        assert_eq!(lens, vec![3, 5, 0, 0]);
        assert_eq!(&ids[0..3], &[1, 1, 1]);
        assert_eq!(ids[3], 0);
    }

    /// Property: no request is ever lost or duplicated through the batcher.
    #[test]
    fn prop_conservation() {
        struct LenVec;
        impl Gen for LenVec {
            type Value = Vec<usize>;
            fn generate(&self, rng: &mut Rng) -> Vec<usize> {
                let n = rng.range(0, 60) as usize;
                (0..n).map(|_| rng.range(1, 140) as usize).collect()
            }
            fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
                if v.is_empty() {
                    vec![]
                } else {
                    vec![v[..v.len() / 2].to_vec()]
                }
            }
        }
        check("batcher conservation", 40, &LenVec, |lens| {
            for &aware in &[true, false] {
                let mut b = Batcher::new(vec![32, 64, 128], 7, aware);
                for &l in lens {
                    b.push(req(l));
                }
                let expect_kept = lens.iter().filter(|&&l| l <= 128).count();
                let batches = b.drain().unwrap();
                let total: usize = batches.iter().map(|x| x.requests.len()).sum();
                if total != expect_kept {
                    return Err(format!("aware={aware}: {total} != {expect_kept}"));
                }
                if b.rejected != lens.len() - expect_kept {
                    return Err(format!("rejected {} wrong", b.rejected));
                }
                for batch in &batches {
                    if batch.requests.len() > 7 {
                        return Err("batch too big".into());
                    }
                    for r in &batch.requests {
                        if r.tokens.len() > batch.bucket {
                            return Err(format!(
                                "request len {} exceeds bucket {}",
                                r.tokens.len(),
                                batch.bucket
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
