//! The unified `Simulation` API: one builder over the discrete-event
//! simulation core ([`crate::sim::des`]) for both serving tiers.
//!
//! `fbia fleet` (single node, card-level routing) and `fbia cluster`
//! (multi-node, NIC-limited routing plus drain/fail scenarios) used to
//! drive their planners through different entry points with different
//! shapes. Both tiers now run on the same seeded event heap, and this
//! module gives them the same surface: pick a tier, set policies, hand
//! over a trace, `run()`, read one [`SimReport`].
//!
//! ```ignore
//! let report = Simulation::fleet(fleet)
//!     .card_policy(RoutePolicy::LatencyAware)
//!     .trace(reqs)
//!     .run()?;
//! assert!(report.conserved());
//! ```
//!
//! `run()` is a pure plan on the modeled clock — deterministic for a
//! given `FleetConfig::des_seed`, no numerics executed. Chain
//! `.execute(workers)` to also run every admitted request's real kernels
//! on the engine backend (the metrics stay modeled-clock; execution only
//! validates numerics and exercises the runtime).
//!
//! Event handlers (routing, link/NIC occupancy, SLA shedding, scenario
//! drain/fail, dynamic batch growth) are registered by the tier routers
//! on the shared heap — see `serving::fleet::router` and
//! `serving::cluster::router` for the extension points.

use crate::obs::{
    AlertEvent, MonitorReport, SloSpec, Stage, StageStats, Tracer, WindowedSeries,
};
use crate::serving::cluster::{Cluster, ClusterMetrics, NodePolicy, Scenario};
use crate::serving::fleet::{Fleet, FleetMetrics, FleetRequest, RoutePolicy};
use crate::util::bench::BenchReport;
use crate::util::error::{bail, Result};
use crate::util::json::Json;
use std::sync::Arc;

/// Which tier the simulation drives.
enum Tier {
    Fleet(Arc<Fleet>),
    Cluster(Arc<Cluster>),
}

/// Builder for one simulation run; see the module docs.
pub struct Simulation {
    tier: Tier,
    card_policy: RoutePolicy,
    node_policy: NodePolicy,
    scenario: Scenario,
    trace: Vec<FleetRequest>,
    execute_workers: Option<usize>,
}

impl Simulation {
    /// Simulate the single-node tier: card-level routing across a fleet's
    /// replica set.
    pub fn fleet(fleet: Arc<Fleet>) -> Simulation {
        Simulation {
            tier: Tier::Fleet(fleet),
            card_policy: RoutePolicy::LatencyAware,
            node_policy: NodePolicy::WeightedCapacity,
            scenario: Scenario::none(),
            trace: Vec::new(),
            execute_workers: None,
        }
    }

    /// Simulate the multi-node tier: NIC-limited node routing in front of
    /// per-node card routing.
    pub fn cluster(cluster: Arc<Cluster>) -> Simulation {
        Simulation {
            tier: Tier::Cluster(cluster),
            card_policy: RoutePolicy::LatencyAware,
            node_policy: NodePolicy::WeightedCapacity,
            scenario: Scenario::none(),
            trace: Vec::new(),
            execute_workers: None,
        }
    }

    /// Within-node card-routing policy (both tiers).
    pub fn card_policy(mut self, p: RoutePolicy) -> Simulation {
        self.card_policy = p;
        self
    }

    /// Cross-node routing policy (cluster tier; ignored by the fleet tier).
    pub fn node_policy(mut self, p: NodePolicy) -> Simulation {
        self.node_policy = p;
        self
    }

    /// Drain/fail scenario events (cluster tier only — `run()` rejects a
    /// non-empty scenario on the fleet tier rather than ignoring it).
    pub fn scenario(mut self, s: Scenario) -> Simulation {
        self.scenario = s;
        self
    }

    /// The request trace to simulate (arrival times are modeled seconds).
    pub fn trace(mut self, reqs: Vec<FleetRequest>) -> Simulation {
        self.trace = reqs;
        self
    }

    /// Also execute the admitted requests' real numerics with `workers`
    /// in flight. Without this, `run()` plans only.
    pub fn execute(mut self, workers: usize) -> Simulation {
        self.execute_workers = Some(workers.max(1));
        self
    }

    /// Run the simulation and fold the tier metrics into a [`SimReport`].
    pub fn run(&self) -> Result<SimReport> {
        match &self.tier {
            Tier::Fleet(fleet) => {
                if !self.scenario.is_empty() {
                    bail!(
                        "drain/fail scenarios are a cluster-tier feature; \
                         the fleet tier has no nodes to drain"
                    );
                }
                let m = match self.execute_workers {
                    Some(w) => fleet.serve(self.trace.clone(), self.card_policy, w)?,
                    None => fleet.route(&self.trace, self.card_policy)?,
                };
                Ok(SimReport::from_fleet(m))
            }
            Tier::Cluster(cluster) => {
                let m = match self.execute_workers {
                    Some(w) => cluster.serve(
                        self.trace.clone(),
                        self.node_policy,
                        self.card_policy,
                        &self.scenario,
                        w,
                    )?,
                    None => cluster.route(
                        &self.trace,
                        self.node_policy,
                        self.card_policy,
                        &self.scenario,
                    )?,
                };
                Ok(SimReport::from_cluster(m))
            }
        }
    }

    /// [`Simulation::run`] with tracing ([`crate::obs`]): also returns the
    /// [`Tracer`] holding per-request lifecycle spans and per-card / NIC /
    /// DRAM occupancy timelines. The event schedule is identical to an
    /// untraced run — same seed, same plan, bit-identical report.
    pub fn run_traced(&self) -> Result<(SimReport, Tracer)> {
        if self.execute_workers.is_some() {
            bail!("run_traced() is a planning pass; drop .execute() to trace");
        }
        let mut tracer = Tracer::new();
        let report = match &self.tier {
            Tier::Fleet(fleet) => {
                if !self.scenario.is_empty() {
                    bail!(
                        "drain/fail scenarios are a cluster-tier feature; \
                         the fleet tier has no nodes to drain"
                    );
                }
                let m = fleet.route_traced(&self.trace, self.card_policy, Some(&mut tracer))?;
                SimReport::from_fleet(m)
            }
            Tier::Cluster(cluster) => {
                let m = cluster.route_traced(
                    &self.trace,
                    self.node_policy,
                    self.card_policy,
                    &self.scenario,
                    Some(&mut tracer),
                )?;
                SimReport::from_cluster(m)
            }
        };
        Ok((report, tracer))
    }

    /// [`Simulation::run_traced`] plus windowed telemetry and SLO
    /// monitoring: derives a fixed-width [`WindowedSeries`] from the trace
    /// (so the planner hot loop is untouched — see [`crate::obs::metrics`]),
    /// evaluates `spec`'s burn-rate rules over it, and folds both into the
    /// report (`report.windows` / `report.alerts`) alongside the full
    /// [`MonitorReport`] and the [`Tracer`] for chrome-trace export.
    pub fn run_monitored(
        &self,
        window_s: f64,
        spec: &SloSpec,
    ) -> Result<(SimReport, Tracer, MonitorReport)> {
        let (mut report, tracer) = self.run_traced()?;
        let (cards, nic_ports) = match &self.tier {
            Tier::Fleet(fleet) => (fleet.replicas().cards, 0),
            Tier::Cluster(cluster) => {
                let nodes = cluster.nodes();
                (nodes.iter().map(|n| n.spec.cards).sum(), 2 * nodes.len())
            }
        };
        let series = WindowedSeries::from_tracer(&tracer, window_s, cards, nic_ports);
        let alerts = crate::obs::evaluate(&series, spec);
        report.windows = Some(series.clone());
        report.alerts = alerts.clone();
        Ok((report, tracer, MonitorReport { series, spec: spec.clone(), alerts }))
    }
}

/// The unified result shape both tiers produce: headline numbers up
/// front, the tier's full metrics behind an `Option`.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// "fleet" or "cluster".
    pub tier: &'static str,
    pub card_policy: RoutePolicy,
    /// `Some` for cluster runs; the fleet tier has no node router.
    pub node_policy: Option<NodePolicy>,
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    pub qps: f64,
    pub items_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Modeled span of the run (first arrival to last completion).
    pub span_s: f64,
    /// `shed` split by cause; [`SimReport::conserved`] gates on the sum.
    /// The first three are admission-control causes (both tiers); the last
    /// two are cluster-tier outcomes (node failure, no routable node).
    pub shed_queue_full: usize,
    pub shed_sla: usize,
    pub shed_no_bucket: usize,
    pub shed_failed: usize,
    pub shed_unroutable: usize,
    /// Stage-level latency attribution over the completed requests.
    pub stages: StageStats,
    /// Fixed-width windowed telemetry ([`Simulation::run_monitored`] runs
    /// only); its totals reconcile bit-exactly with the counts above.
    pub windows: Option<WindowedSeries>,
    /// SLO burn-rate alert events (monitored runs only).
    pub alerts: Vec<AlertEvent>,
    /// Full fleet metrics (fleet-tier runs).
    pub fleet: Option<FleetMetrics>,
    /// Full cluster metrics (cluster-tier runs).
    pub cluster: Option<ClusterMetrics>,
}

impl SimReport {
    pub fn from_fleet(m: FleetMetrics) -> SimReport {
        SimReport {
            tier: "fleet",
            card_policy: m.policy,
            node_policy: None,
            offered: m.offered,
            completed: m.node.completed,
            shed: m.shed,
            qps: m.node_qps(),
            items_per_s: m.node.items_per_s(),
            p50_ms: m.node.latency.p50() * 1e3,
            p99_ms: m.node.latency.p99() * 1e3,
            span_s: m.node.wall_s,
            shed_queue_full: m.shed_causes.queue_full,
            shed_sla: m.shed_causes.sla,
            shed_no_bucket: m.shed_causes.no_bucket,
            shed_failed: 0,
            shed_unroutable: 0,
            stages: m.node.stages.clone(),
            windows: None,
            alerts: Vec::new(),
            fleet: Some(m),
            cluster: None,
        }
    }

    pub fn from_cluster(m: ClusterMetrics) -> SimReport {
        SimReport {
            tier: "cluster",
            card_policy: m.card_policy,
            node_policy: Some(m.node_policy),
            offered: m.offered,
            completed: m.cluster.completed,
            shed: m.shed(),
            qps: m.cluster_qps(),
            items_per_s: m.cluster.items_per_s(),
            p50_ms: m.cluster.latency.p50() * 1e3,
            p99_ms: m.cluster.latency.p99() * 1e3,
            span_s: m.cluster.wall_s,
            shed_queue_full: m.shed_causes.queue_full,
            shed_sla: m.shed_causes.sla,
            shed_no_bucket: m.shed_causes.no_bucket,
            shed_failed: m.shed_failed,
            shed_unroutable: m.shed_unroutable,
            stages: m.cluster.stages.clone(),
            windows: None,
            alerts: Vec::new(),
            fleet: None,
            cluster: Some(m),
        }
    }

    /// The conservation invariant every run must satisfy: requests are
    /// neither lost nor double-counted, and the cause split accounts for
    /// every shed request.
    pub fn conserved(&self) -> bool {
        let causes = self.shed_queue_full
            + self.shed_sla
            + self.shed_no_bucket
            + self.shed_failed
            + self.shed_unroutable;
        self.completed + self.shed == self.offered && causes == self.shed
    }

    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.offered.max(1) as f64
    }

    /// Windowed-series conservation: every count series, summed over all
    /// windows, equals the corresponding run total — bit-exactly (these
    /// are integer counts; each request lands in exactly one window).
    /// `true` when no windowed telemetry was collected.
    pub fn windows_reconcile(&self) -> bool {
        match &self.windows {
            None => true,
            Some(s) => {
                let t = s.totals();
                t.offered == self.offered as u64
                    && t.completed == self.completed as u64
                    && t.shed() == self.shed as u64
                    && t.shed_queue_full == self.shed_queue_full as u64
                    && t.shed_sla == self.shed_sla as u64
                    && t.shed_no_bucket == self.shed_no_bucket as u64
                    && t.shed_failed == self.shed_failed as u64
                    && t.shed_unroutable == self.shed_unroutable as u64
            }
        }
    }

    /// Mean seconds attributed to `stage` over the completed requests.
    pub fn stage_mean_s(&self, stage: Stage) -> f64 {
        self.stages.mean(stage)
    }

    /// Bridge into the shared `BENCH_*.json` schema. The shed-cause split
    /// and the stage breakdown ride along as `extra` detail objects.
    pub fn bench_report(&self, name: &str, backend: &str) -> BenchReport {
        let mut r = BenchReport::new(name, backend, "modeled");
        r.offered = self.offered;
        r.completed = self.completed;
        r.shed = self.shed;
        r.qps = self.qps;
        r.p50_ms = self.p50_ms;
        r.p99_ms = self.p99_ms;
        let mut r = r
            .with(
                "shed_causes",
                Json::obj(vec![
                    ("queue_full", Json::num(self.shed_queue_full as f64)),
                    ("sla", Json::num(self.shed_sla as f64)),
                    ("no_bucket", Json::num(self.shed_no_bucket as f64)),
                    ("failed", Json::num(self.shed_failed as f64)),
                    ("unroutable", Json::num(self.shed_unroutable as f64)),
                ]),
            )
            .with("stages", self.stages.to_json());
        if let Some(w) = &self.windows {
            r = r.with("windows", w.to_json()).with(
                "alerts",
                Json::arr(self.alerts.iter().map(AlertEvent::to_json).collect()),
            );
        }
        r
    }
}
