//! Per-worker scratch arena for the reference serving hot path.
//!
//! The PR 7 profile of `RefPrepared::run` was dominated not by arithmetic
//! but by allocator traffic: every op allocated a fresh `Vec<f32>` per
//! request. The arena recycles those buffers per worker thread, so
//! steady-state serving performs **zero heap allocations per request**:
//!
//! - [`Arena::take`]/[`Arena::give`] hand out and reclaim `Vec<f32>`
//!   scratch buffers LIFO. Because one prepared model issues the same
//!   deterministic sequence of takes per request, buffer capacities
//!   converge after the first few requests and `take` stops allocating.
//! - Activations effectively ping-pong between the two top-of-stack
//!   buffers; [`Arena::reserve`] pre-sizes them from the evaluator's
//!   peak-activation bound
//!   ([`crate::numerics::validate::peak_scratch_bytes`], the interpreter
//!   analogue of the static analyzer's
//!   [`crate::analysis::memory::peak_activation_bytes`] sweep, computed
//!   once at `prepare()`), so even the first request avoids most growth.
//! - Output tensors come from [`take_outputs`] and return through
//!   [`recycle_outputs`]: the serving loops hand their consumed
//!   `Vec<HostTensor>` back to the worker's arena instead of dropping it.
//!
//! The arena is thread-local ([`with_arena`]) — serving workers never
//! contend on it, and a `PreparedModel` stays `Send + Sync` with no locks
//! on the hot path. Buffers are plain `Vec`s, so nothing here is `unsafe`;
//! "arena" refers to the recycling discipline, not raw bump allocation.

use super::HostTensor;
use std::cell::RefCell;

/// Recycling pool of scratch buffers for one worker thread.
#[derive(Default)]
pub struct Arena {
    free_f32: Vec<Vec<f32>>,
    free_i32: Vec<Vec<i32>>,
    free_str: Vec<String>,
    free_shapes: Vec<Vec<usize>>,
    free_outputs: Vec<Vec<HostTensor>>,
}

impl Arena {
    pub fn new() -> Self {
        Arena::default()
    }

    /// Take a zeroed f32 buffer of exactly `len` elements. Reuses the most
    /// recently returned buffer (LIFO), growing it only if its capacity is
    /// short — after warm-up this never allocates.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free_f32.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a scratch buffer to the pool.
    pub fn give(&mut self, v: Vec<f32>) {
        self.free_f32.push(v);
    }

    /// Pre-size the ping-pong activation buffers: ensure at least two free
    /// buffers of `bytes` capacity each (the analyzer's peak-activation
    /// bound). Idempotent; never shrinks.
    pub fn reserve(&mut self, bytes: usize) {
        let elems = bytes / std::mem::size_of::<f32>();
        for slot in 0..2 {
            match self.free_f32.get_mut(slot) {
                Some(v) => {
                    if v.capacity() < elems {
                        v.reserve(elems - v.len());
                    }
                }
                None => self.free_f32.push(Vec::with_capacity(elems)),
            }
        }
    }

    /// Take an empty i32 scratch (capacity recycled) — the activation
    /// quantization buffer of `quant_fc_into`.
    pub fn take_i32(&mut self) -> Vec<i32> {
        let mut v = self.free_i32.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return an i32 scratch to the pool.
    pub fn give_i32(&mut self, v: Vec<i32>) {
        self.free_i32.push(v);
    }

    /// Take an empty usize scratch (MLP width lists) — shares the shape
    /// pool, since shapes are the other usize vecs in flight.
    pub fn take_usize(&mut self) -> Vec<usize> {
        let mut v = self.free_shapes.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a usize scratch to the (shape) pool.
    pub fn give_usize(&mut self, v: Vec<usize>) {
        self.free_shapes.push(v);
    }

    /// Take an empty name scratch — weight names are formatted into pooled
    /// `String`s so per-request lookups allocate nothing after warm-up.
    pub fn take_str(&mut self) -> String {
        let mut s = self.free_str.pop().unwrap_or_default();
        s.clear();
        s
    }

    /// Return a name scratch to the pool.
    pub fn give_str(&mut self, s: String) {
        self.free_str.push(s);
    }

    /// Build an f32 output tensor with a pooled shape vec (the shape copy
    /// would otherwise be the one allocation left per output tensor).
    pub fn tensor_f32(&mut self, data: Vec<f32>, shape: &[usize]) -> HostTensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        let mut s = self.free_shapes.pop().unwrap_or_default();
        s.clear();
        s.extend_from_slice(shape);
        HostTensor::F32(data, s)
    }

    /// Take a (cleared) output-tensor list shell.
    pub fn take_outputs(&mut self) -> Vec<HostTensor> {
        let mut v = self.free_outputs.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Reclaim a consumed output list: f32 payloads and shape vecs go back
    /// to their pools, the shell to the output pool. Non-f32 tensors are
    /// dropped.
    pub fn reclaim_outputs(&mut self, mut outs: Vec<HostTensor>) {
        for t in outs.drain(..) {
            if let HostTensor::F32(buf, shape) = t {
                self.give(buf);
                self.free_shapes.push(shape);
            }
        }
        self.free_outputs.push(outs);
    }

    /// Reclaim a single consumed tensor (payload + shape vec). Non-f32
    /// tensors are dropped.
    pub fn reclaim_tensor(&mut self, t: HostTensor) {
        if let HostTensor::F32(buf, shape) = t {
            self.give(buf);
            self.free_shapes.push(shape);
        }
    }

    /// Number of pooled scratch buffers (test introspection).
    pub fn pooled(&self) -> usize {
        self.free_f32.len()
    }
}

thread_local! {
    static TL_ARENA: RefCell<Arena> = RefCell::new(Arena::new());
}

/// Run `f` with this thread's arena. Do not call re-entrantly (the
/// reference eval path takes the arena once at its entry point and passes
/// `&mut Arena` down).
pub fn with_arena<R>(f: impl FnOnce(&mut Arena) -> R) -> R {
    TL_ARENA.with(|a| f(&mut a.borrow_mut()))
}

/// Hand a consumed output list back to this thread's arena — called by the
/// serving loops once a request's outputs have been read, closing the
/// zero-allocation cycle.
pub fn recycle_outputs(outs: Vec<HostTensor>) {
    with_arena(|a| a.reclaim_outputs(outs));
}

/// Single-tensor form of [`recycle_outputs`], for call sites that consume
/// one output tensor by value.
pub fn recycle_tensor(t: HostTensor) {
    with_arena(|a| a.reclaim_tensor(t));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_returned_buffer() {
        let mut a = Arena::new();
        let v = a.take(128);
        let p = v.as_ptr();
        a.give(v);
        let v2 = a.take(64); // smaller fits in the same allocation
        assert_eq!(v2.as_ptr(), p);
        assert_eq!(v2.len(), 64);
        assert!(v2.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn take_zeroes_recycled_contents() {
        let mut a = Arena::new();
        let mut v = a.take(8);
        v.iter_mut().for_each(|x| *x = 7.0);
        a.give(v);
        assert!(a.take(8).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn reserve_preallocates_two_buffers() {
        let mut a = Arena::new();
        a.reserve(1024);
        assert_eq!(a.pooled(), 2);
        let v = a.take(256); // 1024 bytes
        assert!(v.capacity() >= 256);
        a.give(v);
        a.reserve(512); // idempotent, never shrinks
        assert_eq!(a.pooled(), 2);
    }

    #[test]
    fn outputs_round_trip() {
        let mut a = Arena::new();
        let mut outs = a.take_outputs();
        outs.push(HostTensor::f32(a.take(16), &[16]));
        a.reclaim_outputs(outs);
        assert_eq!(a.pooled(), 1);
        let outs2 = a.take_outputs();
        assert!(outs2.is_empty());
    }

    #[test]
    fn thread_local_recycle() {
        let before = with_arena(|a| a.pooled());
        recycle_outputs(vec![HostTensor::f32(vec![0.0; 4], &[4])]);
        assert_eq!(with_arena(|a| a.pooled()), before + 1);
    }
}
