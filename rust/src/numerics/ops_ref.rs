//! Reference ops — the Rust mirror of `python/compile/kernels/ref.py`.
//!
//! Used by the numerics validator (§V-C) to check PJRT artifact outputs, and
//! by the serving integration tests as ground truth. All row-major f32.
//!
//! Ops whose access pattern is driven by *request data* (embedding indices)
//! return `Result`: a malformed request must surface as a rejected inference,
//! never as a panic in the serving hot path.

use crate::util::error::{bail, Result};
use crate::util::threadpool::ThreadPool;
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};

/// Multiply-add count above which `fc` tiles its output rows across the
/// shared kernel pool. Small GEMMs (DLRM dense layers at serving batch
/// sizes) stay on the caller's thread — the fan-out overhead would dominate;
/// big ones (XLM-R projections/FFN at batch×seq rows) parallelize.
const FC_PARALLEL_MIN_MADDS: usize = 1 << 22;

/// Shared pool for intra-kernel tiling (sized to the host, created lazily).
/// Jobs are leaf work — they never submit further jobs — so kernels called
/// from serving worker threads cannot deadlock on it.
fn kernel_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(threads.clamp(2, 8))
    })
}

/// y = x @ w^T + b. x: [m,k], w: [n,k], b: [n] → y: [m,n].
///
/// Large calls are tiled across output rows on [`kernel_pool`] (the
/// ROADMAP's "parallelism inside single kernels" item). Each output element
/// is computed by exactly the same accumulation loop as [`fc_serial`], so
/// the result is bit-identical regardless of tile count — the determinism
/// the §V-C validation story depends on.
pub fn fc(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), n * k);
    assert_eq!(b.len(), n);
    let tiles = kernel_pool().threads().min(m);
    if m * k * n < FC_PARALLEL_MIN_MADDS || tiles < 2 {
        return fc_serial(x, w, b, m, k, n);
    }
    // Jobs must be 'static: share one copy of w/b by Arc and give each tile
    // its own rows of x. One O(m·k + n·k) copy per call, amortized by the
    // O(m·k·n) GEMM this branch only runs for.
    let w = Arc::new(w.to_vec());
    let b = Arc::new(b.to_vec());
    let chunk = m.div_ceil(tiles);
    let (tx, rx) = mpsc::channel::<(usize, Vec<f32>)>();
    let mut submitted = 0usize;
    for t in 0..tiles {
        let (r0, r1) = (t * chunk, ((t + 1) * chunk).min(m));
        if r0 >= r1 {
            continue;
        }
        let xt = x[r0 * k..r1 * k].to_vec();
        let (w, b, tx) = (Arc::clone(&w), Arc::clone(&b), tx.clone());
        kernel_pool().execute(move || {
            let _ = tx.send((r0, fc_serial(&xt, &w, &b, r1 - r0, k, n)));
        });
        submitted += 1;
    }
    drop(tx);
    let mut y = vec![0f32; m * n];
    let mut received = 0usize;
    for (r0, rows) in rx.iter() {
        y[r0 * n..r0 * n + rows.len()].copy_from_slice(&rows);
        received += 1;
    }
    assert_eq!(received, submitted, "fc tile worker exited without reporting");
    y
}

/// Single-thread reference `fc` — the fallback for small GEMMs and the
/// per-tile kernel of the parallel path (so both compute identical bits).
pub fn fc_serial(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), n * k);
    assert_eq!(b.len(), n);
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            let xi = &x[i * k..(i + 1) * k];
            let wj = &w[j * k..(j + 1) * k];
            for t in 0..k {
                acc += xi[t] * wj[t];
            }
            y[i * n + j] = acc + b[j];
        }
    }
    y
}

/// Quantized FC matching `ref.quant_fc`: dynamic symmetric activation
/// quantization + int32 GEMM + float epilogue.
pub fn quant_fc(
    x: &[f32],
    wq: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(wq.len(), n * k);
    let absmax = x.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-8);
    let xs = absmax / 127.0;
    let xq: Vec<i32> = x.iter().map(|&v| (v / xs).round().clamp(-127.0, 127.0) as i32).collect();
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        let row = &xq[i * k..(i + 1) * k];
        let rowsum: i32 = row.iter().sum();
        for j in 0..n {
            let wj = &wq[j * k..(j + 1) * k];
            let mut acc: i32 = 0;
            for t in 0..k {
                acc += row[t] * wj[t] as i32;
            }
            let acc_f = acc as f32 + rowsum as f32 * zp[j];
            y[i * n + j] = acc_f * (xs * scale[j]) + bias[j];
        }
    }
    y
}

/// SparseLengthsSum: table [rows, dim], indices [batch, max_len],
/// lengths [batch] → pooled [batch, dim]. Tail indices are masked.
///
/// Indices and lengths come straight from the request, so they are data,
/// not contract: an out-of-range (or negative) index is an `Err`, not a
/// panic. Shapes are contract (pre-validated by the engine) and stay
/// asserts.
pub fn sls(
    table: &[f32],
    dim: usize,
    indices: &[i32],
    lengths: &[i32],
    batch: usize,
    max_len: usize,
) -> Result<Vec<f32>> {
    assert_eq!(indices.len(), batch * max_len);
    assert_eq!(lengths.len(), batch);
    let rows = table.len() / dim;
    let mut out = vec![0f32; batch * dim];
    for b in 0..batch {
        let l = (lengths[b].max(0) as usize).min(max_len);
        for j in 0..l {
            let idx = indices[b * max_len + j];
            if idx < 0 || idx as usize >= rows {
                bail!(
                    "sls: embedding index {idx} out of range for table with {rows} rows \
                     (batch row {b}, lookup {j})"
                );
            }
            let idx = idx as usize;
            let row = &table[idx * dim..(idx + 1) * dim];
            for d in 0..dim {
                out[b * dim + d] += row[d];
            }
        }
    }
    Ok(out)
}

/// ReLU in place.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.max(0.0);
    }
}

/// Sigmoid in place.
pub fn sigmoid(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = 1.0 / (1.0 + (-*v).exp());
    }
}

/// GeLU (tanh approximation, matching ref.py).
pub fn gelu(x: &mut [f32]) {
    for v in x.iter_mut() {
        let x3 = *v * *v * *v;
        *v = 0.5 * *v * (1.0 + (0.7978845608028654 * (*v + 0.044715 * x3)).tanh());
    }
}

/// LayerNorm over the last dim: x [rows, d].
pub fn layernorm(x: &mut [f32], gamma: &[f32], beta: &[f32], rows: usize, d: usize, eps: f32) {
    for r in 0..rows {
        let row = &mut x[r * d..(r + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for i in 0..d {
            row[i] = (row[i] - mu) * inv * gamma[i] + beta[i];
        }
    }
}

/// Row-wise softmax: x [rows, d].
pub fn softmax(x: &mut [f32], rows: usize, d: usize) {
    for r in 0..rows {
        let row = &mut x[r * d..(r + 1) * d];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut s = 0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
}

/// Scaled dot-product attention over [heads, seq, hd].
pub fn attention(q: &[f32], k: &[f32], v: &[f32], heads: usize, seq: usize, hd: usize) -> Vec<f32> {
    let mut out = vec![0f32; heads * seq * hd];
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0f32; seq * seq];
    for h in 0..heads {
        let qh = &q[h * seq * hd..];
        let kh = &k[h * seq * hd..];
        let vh = &v[h * seq * hd..];
        for i in 0..seq {
            for j in 0..seq {
                let mut acc = 0f32;
                for t in 0..hd {
                    acc += qh[i * hd + t] * kh[j * hd + t];
                }
                scores[i * seq + j] = acc * scale;
            }
        }
        softmax(&mut scores, seq, seq);
        for i in 0..seq {
            for t in 0..hd {
                let mut acc = 0f32;
                for j in 0..seq {
                    acc += scores[i * seq + j] * vh[j * hd + t];
                }
                out[h * seq * hd + i * hd + t] = acc;
            }
        }
    }
    out
}

/// DLRM dot interaction (ref.py::dot_interaction): dense [b, d] +
/// sparse [b, f-1, d] → [b, d + f(f-1)/2].
pub fn dot_interaction(dense: &[f32], sparse: &[f32], batch: usize, d: usize, num_sparse: usize) -> Vec<f32> {
    let f = num_sparse + 1;
    let pairs = f * (f - 1) / 2;
    let out_dim = d + pairs;
    let mut out = vec![0f32; batch * out_dim];
    let mut feats = vec![0f32; f * d];
    for b in 0..batch {
        // assemble [f, d]: dense row then sparse rows
        feats[..d].copy_from_slice(&dense[b * d..(b + 1) * d]);
        for s in 0..num_sparse {
            let src = &sparse[(b * num_sparse + s) * d..(b * num_sparse + s + 1) * d];
            feats[(s + 1) * d..(s + 2) * d].copy_from_slice(src);
        }
        let o = &mut out[b * out_dim..(b + 1) * out_dim];
        o[..d].copy_from_slice(&feats[..d]);
        // upper-triangular pairwise dots, (i, j) with i < j, row-major like
        // jnp.triu_indices
        let mut p = d;
        for i in 0..f {
            for j in (i + 1)..f {
                let mut acc = 0f32;
                for t in 0..d {
                    acc += feats[i * d + t] * feats[j * d + t];
                }
                o[p] = acc;
                p += 1;
            }
        }
    }
    out
}

/// 2D convolution, NHWC x HWIO → NHWC, SAME padding.
///
/// Large calls tile their **output channels** across [`kernel_pool`] (same
/// FLOP threshold as [`fc`]); every output element is computed by exactly
/// the accumulation loop of [`conv2d_serial`], so results are bit-identical
/// at any tile count — the CV counterpart of the fc tiling determinism
/// contract.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
) -> Vec<f32> {
    let oh = h.div_ceil(stride);
    let ow = wd.div_ceil(stride);
    let cing = cin / groups;
    let madds = n * oh * ow * cout * kh * kw * cing;
    let tiles = kernel_pool().threads().min(cout);
    if madds < FC_PARALLEL_MIN_MADDS || tiles < 2 {
        return conv2d_serial(x, w, b, n, h, wd, cin, kh, kw, cout, stride, groups);
    }
    // Jobs must be 'static: share x/w/b by Arc (one copy per call,
    // amortized by the O(madds) work this branch only runs for); each tile
    // computes a contiguous co range and is scattered back channel-wise.
    let x = Arc::new(x.to_vec());
    let w = Arc::new(w.to_vec());
    let b = Arc::new(b.to_vec());
    let chunk = cout.div_ceil(tiles);
    let (tx, rx) = mpsc::channel::<(usize, usize, Vec<f32>)>();
    let mut submitted = 0usize;
    for t in 0..tiles {
        let (c0, c1) = (t * chunk, ((t + 1) * chunk).min(cout));
        if c0 >= c1 {
            continue;
        }
        let (x, w, b, tx) = (Arc::clone(&x), Arc::clone(&w), Arc::clone(&b), tx.clone());
        kernel_pool().execute(move || {
            let tile =
                conv2d_ch_range(&x, &w, &b, n, h, wd, cin, kh, kw, cout, stride, groups, c0, c1);
            let _ = tx.send((c0, c1, tile));
        });
        submitted += 1;
    }
    drop(tx);
    let mut y = vec![0f32; n * oh * ow * cout];
    let mut received = 0usize;
    for (c0, c1, tile) in rx.iter() {
        let span = c1 - c0;
        for pix in 0..n * oh * ow {
            y[pix * cout + c0..pix * cout + c1].copy_from_slice(&tile[pix * span..(pix + 1) * span]);
        }
        received += 1;
    }
    assert_eq!(received, submitted, "conv2d tile worker exited without reporting");
    y
}

/// Single-thread reference `conv2d` — the fallback for small convolutions
/// and the shape the §V-C validation story pins (the tiled path computes
/// identical bits through [`conv2d_ch_range`]).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_serial(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
) -> Vec<f32> {
    // the full-range tile's layout is exactly the full output
    conv2d_ch_range(x, w, b, n, h, wd, cin, kh, kw, cout, stride, groups, 0, cout)
}

/// One output-channel tile `[co0, co1)` of the convolution, laid out
/// `[n, oh, ow, co1-co0]`. Both the serial and the tiled `conv2d` paths
/// compute every element through this one loop, which is what makes tiling
/// bit-exact: per element the accumulation order never changes.
#[allow(clippy::too_many_arguments)]
fn conv2d_ch_range(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
    co0: usize,
    co1: usize,
) -> Vec<f32> {
    let oh = h.div_ceil(stride);
    let ow = wd.div_ceil(stride);
    let cing = cin / groups;
    let coutg = cout / groups;
    let span = co1 - co0;
    // SAME padding offsets
    let pad_h = ((oh - 1) * stride + kh).saturating_sub(h) / 2;
    let pad_w = ((ow - 1) * stride + kw).saturating_sub(wd) / 2;
    let mut y = vec![0f32; n * oh * ow * span];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for co in co0..co1 {
                    let g = co / coutg;
                    let mut acc = b[co];
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            for ci in 0..cing {
                                let xi = x[((ni * h + iy as usize) * wd + ix as usize) * cin
                                    + g * cing
                                    + ci];
                                let wi = w[((ky * kw + kx) * cing + ci) * cout + co];
                                acc += xi * wi;
                            }
                        }
                    }
                    y[((ni * oh + oy) * ow + ox) * span + (co - co0)] = acc;
                }
            }
        }
    }
    y
}

/// Global average pool NHWC → [n, c].
pub fn global_avgpool(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut y = vec![0f32; n * c];
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0f32;
            for yi in 0..h {
                for xi in 0..w {
                    acc += x[((ni * h + yi) * w + xi) * c + ci];
                }
            }
            y[ni * c + ci] = acc * inv;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::quant::quantize_rowwise_int8;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn fc_identity() {
        // w = I, b = 0 -> y = x
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![0.0, 0.0];
        assert_eq!(fc(&x, &w, &b, 2, 2, 2), x);
    }

    #[test]
    fn fc_parallel_bit_identical_to_serial() {
        // large enough to cross FC_PARALLEL_MIN_MADDS -> tiled path
        let (m, k, n) = (64, 256, 512);
        assert!(m * k * n >= FC_PARALLEL_MIN_MADDS);
        let mut rng = Rng::new(11);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let serial = fc_serial(&x, &w, &b, m, k, n);
        // bitwise equal, and stable across repeated parallel runs
        for _ in 0..3 {
            assert_eq!(fc(&x, &w, &b, m, k, n), serial);
        }
    }

    #[test]
    fn fc_small_falls_back_to_serial() {
        let (m, k, n) = (3, 8, 5);
        let mut rng = Rng::new(13);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        assert_eq!(fc(&x, &w, &b, m, k, n), fc_serial(&x, &w, &b, m, k, n));
    }

    #[test]
    fn fc_parallel_safe_under_concurrent_callers() {
        // serving workers call fc concurrently; tiles from different calls
        // interleave on the shared pool and must not cross-talk
        let (m, k, n) = (64, 256, 512);
        let mut rng = Rng::new(17);
        let x = std::sync::Arc::new(randv(&mut rng, m * k));
        let w = std::sync::Arc::new(randv(&mut rng, n * k));
        let b = std::sync::Arc::new(randv(&mut rng, n));
        let expect = fc_serial(&x, &w, &b, m, k, n);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (x, w, b, e) =
                    (Arc::clone(&x), Arc::clone(&w), Arc::clone(&b), expect.clone());
                std::thread::spawn(move || assert_eq!(fc(&x, &w, &b, m, k, n), e))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn quant_fc_close_to_fp() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (4, 32, 16);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let q = quantize_rowwise_int8(&w, n, k);
        let yq = quant_fc(&x, &q.q, &q.scale, &q.zp, &b, m, k, n);
        let yf = fc(&x, &w, &b, m, k, n);
        for (a, e) in yq.iter().zip(&yf) {
            assert!((a - e).abs() < 0.35, "{a} vs {e}");
        }
    }

    #[test]
    fn sls_masks_tail() {
        let table = vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]; // 3 rows, dim 2
        let indices = vec![0, 1, 2, 2]; // batch 2, max_len 2
        let lengths = vec![2, 1];
        let out = sls(&table, 2, &indices, &lengths, 2, 2).unwrap();
        assert_eq!(out, vec![3.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn sls_rejects_out_of_range_index() {
        let table = vec![0.0; 3 * 2]; // 3 rows, dim 2
        let indices = vec![0, 3]; // 3 is one past the last row
        let lengths = vec![2];
        let err = sls(&table, 2, &indices, &lengths, 1, 2).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn sls_rejects_negative_index() {
        let table = vec![0.0; 3 * 2];
        let indices = vec![-1, 0];
        let lengths = vec![2];
        assert!(sls(&table, 2, &indices, &lengths, 1, 2).is_err());
    }

    #[test]
    fn sls_masked_tail_index_not_checked() {
        // garbage beyond `lengths[b]` is masked, so it must not error
        let table = vec![1.0, 1.0, 2.0, 2.0];
        let indices = vec![0, 9999];
        let lengths = vec![1];
        let out = sls(&table, 2, &indices, &lengths, 1, 2).unwrap();
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn layernorm_normalizes() {
        let mut rng = Rng::new(7);
        let mut x = randv(&mut rng, 4 * 16);
        let g = vec![1.0; 16];
        let b = vec![0.0; 16];
        layernorm(&mut x, &g, &b, 4, 16, 1e-5);
        for r in 0..4 {
            let row = &x[r * 16..(r + 1) * 16];
            let mu: f32 = row.iter().sum::<f32>() / 16.0;
            assert!(mu.abs() < 1e-5, "{mu}");
        }
    }

    #[test]
    fn attention_constant_v() {
        let mut rng = Rng::new(9);
        let (h, s, d) = (2, 8, 4);
        let q = randv(&mut rng, h * s * d);
        let k = randv(&mut rng, h * s * d);
        let v = vec![2.5f32; h * s * d];
        let out = attention(&q, &k, &v, h, s, d);
        for &o in &out {
            assert!((o - 2.5).abs() < 1e-5, "{o}");
        }
    }

    #[test]
    fn dot_interaction_shape_and_dense_passthrough() {
        let mut rng = Rng::new(11);
        let (b, d, ns) = (3, 8, 5);
        let dense = randv(&mut rng, b * d);
        let sparse = randv(&mut rng, b * ns * d);
        let out = dot_interaction(&dense, &sparse, b, d, ns);
        let f = ns + 1;
        assert_eq!(out.len(), b * (d + f * (f - 1) / 2));
        for bi in 0..b {
            let od = d + f * (f - 1) / 2;
            assert_eq!(&out[bi * od..bi * od + d], &dense[bi * d..(bi + 1) * d]);
        }
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 conv with identity weights preserves input
        let x = vec![1.0, 2.0, 3.0, 4.0]; // n1 h2 w2 c1
        let w = vec![1.0]; // 1x1x1x1
        let b = vec![0.0];
        let y = conv2d(&x, &w, &b, 1, 2, 2, 1, 1, 1, 1, 1, 1);
        assert_eq!(y, x);
    }

    #[test]
    fn conv2d_stride_downsamples() {
        let x = vec![1.0; 1 * 4 * 4 * 1];
        let w = vec![1.0];
        let b = vec![0.0];
        let y = conv2d(&x, &w, &b, 1, 4, 4, 1, 1, 1, 1, 2, 1);
        assert_eq!(y.len(), 4); // 2x2
    }

    #[test]
    fn conv2d_parallel_bit_identical_to_serial() {
        // large enough to cross FC_PARALLEL_MIN_MADDS -> tiled path
        let (n, h, wd, cin, cout, k, groups) = (1, 16, 16, 64, 64, 3, 1);
        assert!(n * h * wd * cout * k * k * (cin / groups) >= FC_PARALLEL_MIN_MADDS);
        let mut rng = Rng::new(21);
        let x = randv(&mut rng, n * h * wd * cin);
        let w = randv(&mut rng, k * k * (cin / groups) * cout);
        let b = randv(&mut rng, cout);
        let serial = conv2d_serial(&x, &w, &b, n, h, wd, cin, k, k, cout, 1, groups);
        // bitwise equal, and stable across repeated parallel runs
        for _ in 0..3 {
            assert_eq!(conv2d(&x, &w, &b, n, h, wd, cin, k, k, cout, 1, groups), serial);
        }
    }

    #[test]
    fn conv2d_grouped_strided_parallel_matches_serial() {
        // grouped conv with stride, above the threshold: tile boundaries
        // cut across groups and the strided output grid
        let (n, h, wd, cin, cout, k, groups, stride) = (1, 32, 32, 128, 128, 3, 8, 2);
        let (oh, ow) = (h.div_ceil(stride), wd.div_ceil(stride));
        assert!(n * oh * ow * cout * k * k * (cin / groups) >= FC_PARALLEL_MIN_MADDS);
        let mut rng = Rng::new(23);
        let x = randv(&mut rng, n * h * wd * cin);
        let w = randv(&mut rng, k * k * (cin / groups) * cout);
        let b = randv(&mut rng, cout);
        let serial = conv2d_serial(&x, &w, &b, n, h, wd, cin, k, k, cout, stride, groups);
        assert_eq!(conv2d(&x, &w, &b, n, h, wd, cin, k, k, cout, stride, groups), serial);
        // an unaligned channel tile agrees element-wise with the full run
        let tile = conv2d_ch_range(&x, &w, &b, n, h, wd, cin, k, k, cout, stride, groups, 3, 11);
        for pix in 0..n * oh * ow {
            assert_eq!(&tile[pix * 8..(pix + 1) * 8], &serial[pix * cout + 3..pix * cout + 11]);
        }
    }

    #[test]
    fn conv2d_small_falls_back_to_serial() {
        let (n, h, wd, cin, cout) = (1, 4, 4, 3, 5);
        let mut rng = Rng::new(25);
        let x = randv(&mut rng, n * h * wd * cin);
        let w = randv(&mut rng, 3 * 3 * cin * cout);
        let b = randv(&mut rng, cout);
        assert_eq!(
            conv2d(&x, &w, &b, n, h, wd, cin, 3, 3, cout, 1, 1),
            conv2d_serial(&x, &w, &b, n, h, wd, cin, 3, 3, cout, 1, 1)
        );
    }

    #[test]
    fn global_avgpool_means() {
        let x = vec![1.0, 3.0, 5.0, 7.0]; // n1 h2 w2 c1
        let y = global_avgpool(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![4.0]);
    }

    #[test]
    fn gelu_matches_known_values() {
        let mut x = vec![0.0f32, 1.0, -1.0];
        gelu(&mut x);
        assert!(x[0].abs() < 1e-7);
        assert!((x[1] - 0.8412).abs() < 1e-3, "{}", x[1]);
        assert!((x[2] + 0.1588).abs() < 1e-3, "{}", x[2]);
    }
}
